"""Layer-1 Pallas kernels: tiled Gaussian-kernel block evaluation.

The paper's compute hot-spot is evaluating blocks of the kernel matrix
`K(X_I, X_J)` (leverage-score formulas, FALKON matvecs). On TPU the
natural mapping (DESIGN.md §Hardware-Adaptation) is:

* the cross term `X @ Y^T` on the **MXU** systolic array,
* row norms / subtraction / `exp` on the **VPU**,
* everything fused in one kernel so the `(bm, bn)` output tile and both
  input slabs live in **VMEM** — the HBM<->VMEM schedule a CUDA version
  would write with threadblocks is expressed with `BlockSpec`s over a
  `(M/bm, N/bn)` grid.

VMEM budget at the default `bm = bn = 128`, `d = 32`, f32:
inputs 2 * 128*32*4 B = 32 KiB, output 128*128*4 B = 64 KiB - far below
the ~16 MiB/core budget, leaving room for double buffering.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels are verified through the interpreter and the
AOT artifacts are the interpreter-lowered HLO (plain HLO ops).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile geometry shared with the rust runtime (see
# rust/src/runtime/): T x T output tiles built from bm x bn blocks.
TILE = 256
BLOCK = 128
FEATURE_DIM = 32


def _rbf_block_kernel(x_ref, y_ref, g_ref, o_ref):
    """One (bm, bn) output block: full fused distance + exp."""
    x = x_ref[...]                                     # (bm, d)   VMEM
    y = y_ref[...]                                     # (bn, d)   VMEM
    g = g_ref[0]
    xx = jnp.sum(x * x, axis=1, keepdims=True)         # (bm, 1)   VPU
    yy = jnp.sum(y * y, axis=1, keepdims=True).T       # (1, bn)   VPU
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(xx + yy - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-g * d2)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def rbf_block(x, y, gamma, *, bm=BLOCK, bn=BLOCK):
    """Gaussian kernel block `K(x, y)` via a tiled Pallas kernel.

    Args:
        x: (m, d) f32, m divisible by bm.
        y: (n, d) f32, n divisible by bn.
        gamma: scalar 1/(2 sigma^2) (traced - one artifact serves every
            bandwidth).
    Returns:
        (m, n) f32 kernel block.
    """
    m, d = x.shape
    n = y.shape[0]
    g = jnp.asarray(gamma, jnp.float32).reshape(1)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _rbf_block_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),   # row slab
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),   # col slab
            pl.BlockSpec((1,), lambda i, j: (0,)),        # gamma
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, y, g)


def _rbf_matvec_kernel(x_ref, y_ref, v_ref, g_ref, o_ref):
    """One bm-row block of `K(x, y) @ v` - K never leaves VMEM."""
    x = x_ref[...]                                     # (bm, d)
    y = y_ref[...]                                     # (n, d) full slab
    v = v_ref[...]                                     # (n,)
    g = g_ref[0]
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    k = jnp.exp(-g * jnp.maximum(xx + yy - 2.0 * cross, 0.0))
    o_ref[...] = k @ v


@functools.partial(jax.jit, static_argnames=("bm",))
def rbf_matvec(x, y, v, gamma, *, bm=BLOCK):
    """Fused `K(x, y) @ v` (the FALKON `K_nM v` streaming primitive)."""
    m, d = x.shape
    n = y.shape[0]
    g = jnp.asarray(gamma, jnp.float32).reshape(1)
    return pl.pallas_call(
        _rbf_matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        interpret=True,
    )(x, y, v, g)


def _rbf_matvec_t_kernel(x_ref, y_ref, u_ref, g_ref, o_ref):
    """Accumulate one row-slab's contribution to `K^T @ u`."""
    i = pl.program_id(0)
    x = x_ref[...]                                     # (bm, d)
    y = y_ref[...]                                     # (n, d)
    u = u_ref[...]                                     # (bm,)
    g = g_ref[0]
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    k = jnp.exp(-g * jnp.maximum(xx + yy - 2.0 * cross, 0.0))
    contrib = k.T @ u                                  # (n,)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(i > 0)
    def _accum():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("bm",))
def rbf_matvec_t(x, y, u, gamma, *, bm=BLOCK):
    """Fused `K(x, y)^T @ u` (the FALKON `K_nM^T u` primitive)."""
    m, d = x.shape
    n = y.shape[0]
    g = jnp.asarray(gamma, jnp.float32).reshape(1)
    return pl.pallas_call(
        _rbf_matvec_t_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        interpret=True,
    )(x, y, u, g)
