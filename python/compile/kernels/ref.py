"""Pure-jnp reference oracle for the Pallas kernels.

Every Pallas kernel in this package has a line-for-line mathematical
counterpart here; pytest asserts `assert_allclose` between the two across
shape/bandwidth sweeps (hypothesis). The oracle is also what the L2 model
functions are checked against.
"""

import jax.numpy as jnp


def rbf_block(x, y, gamma):
    """Gaussian kernel block: K[i,j] = exp(-gamma * ||x_i - y_j||^2).

    Args:
        x: (m, d) float array.
        y: (n, d) float array.
        gamma: scalar, 1/(2 sigma^2).
    Returns:
        (m, n) kernel block.
    """
    xx = jnp.sum(x * x, axis=1, keepdims=True)        # (m, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T      # (1, n)
    cross = x @ y.T                                   # (m, n)
    d2 = jnp.maximum(xx + yy - 2.0 * cross, 0.0)
    return jnp.exp(-gamma * d2)


def rbf_matvec(x, y, v, gamma):
    """Fused `K(x, y) @ v` without materializing K outside the tile."""
    return rbf_block(x, y, gamma) @ v


def rbf_matvec_t(x, y, u, gamma):
    """Fused `K(x, y)^T @ u`."""
    return rbf_block(x, y, gamma).T @ u
