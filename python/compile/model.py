"""Layer-2 JAX compute graphs — the functions the rust coordinator calls
through their AOT-compiled artifacts.

Each public function here composes the Layer-1 Pallas kernels
(`kernels.rbf`) into the exact primitives the BLESS / FALKON hot paths
need. `aot.py` lowers each of them once, at fixed tile shapes, to HLO
text; the rust runtime (rust/src/runtime/) pads dynamic shapes up to the
tile contract and assembles results.

Nothing in this module runs at serving time - python is build-time only.
"""

import jax.numpy as jnp

from .kernels import rbf


def kernel_tile(x, y, gamma):
    """`K(x, y)` for one (T, D) x (T, D) tile -> (T, T).

    Used by the rust side for `K_JJ`, `K_JU` and leverage-score cross
    blocks. Zero-padded feature columns are exact (they contribute 0 to
    the squared distance); padded rows produce garbage rows/cols the rust
    side slices away.
    """
    return rbf.rbf_block(x, y, gamma)


def kernel_matvec_tile(x, y, v, gamma):
    """`K(x, y) @ v` for one tile -> (T,).

    FALKON's `K_nM v` streaming step. Zero-padded entries of `v` nullify
    padded center columns, so padding is exact here too.
    """
    return rbf.rbf_matvec(x, y, v, gamma)


def kernel_matvec_t_tile(x, y, u, gamma):
    """`K(x, y)^T @ u` for one tile -> (T,).

    FALKON's `K_nM^T u` accumulation step; zero-padded entries of `u`
    nullify padded data rows.
    """
    return rbf.rbf_matvec_t(x, y, u, gamma)


def kernel_fused_normal_tile(x, y, v, gamma):
    """`K^T (K v)` for one row tile -> (T,): one kernel-block evaluation
    serves both products (the FALKON CG hot loop, Eq. 16's nMt term)."""
    k = rbf.rbf_block(x, y, gamma)
    return k.T @ (k @ v)


def degree_tile(x, y, gamma):
    """Row sums of the kernel block -> (T,). Used for diagnostics and the
    uniform-sampling d_inf estimates."""
    k = rbf.rbf_block(x, y, gamma)
    return jnp.sum(k, axis=1)
