"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Writes one `<name>.hlo.txt` per primitive plus `manifest.json`
describing shapes/dtypes, which the rust runtime validates at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.rbf import FEATURE_DIM, TILE


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def primitives(tile: int, d: int):
    """The artifact set: name -> (function, example_args)."""
    f32 = jnp.float32
    mat = jax.ShapeDtypeStruct((tile, d), f32)
    vec = jax.ShapeDtypeStruct((tile,), f32)
    scl = jax.ShapeDtypeStruct((), f32)
    return {
        "rbf_block": (model.kernel_tile, (mat, mat, scl)),
        "rbf_matvec": (model.kernel_matvec_tile, (mat, mat, vec, scl)),
        "rbf_matvec_t": (model.kernel_matvec_t_tile, (mat, mat, vec, scl)),
        "rbf_fused_normal": (model.kernel_fused_normal_tile, (mat, mat, vec, scl)),
        "rbf_degree": (model.degree_tile, (mat, mat, scl)),
    }


def spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--tile", type=int, default=TILE, help="tile size T")
    ap.add_argument("--dim", type=int, default=FEATURE_DIM, help="feature dim D")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "tile": args.tile,
        "feature_dim": args.dim,
        "jax_version": jax.__version__,
        "artifacts": {},
    }
    for name, (fn, example) in primitives(args.tile, args.dim).items():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [spec_json(s) for s in example],
            "chars": len(text),
        }
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
