"""AOT pipeline: lowering produces loadable HLO text with stable entry
signatures, and the manifest matches the emitted files."""

import json
import os
import tempfile

import jax
import numpy as np
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_primitive_set_is_complete():
    prims = aot.primitives(256, 32)
    assert set(prims) == {
        "rbf_block",
        "rbf_matvec",
        "rbf_matvec_t",
        "rbf_fused_normal",
        "rbf_degree",
    }


def test_hlo_text_parses_and_mentions_entry():
    prims = aot.primitives(128, 32)
    fn, example = prims["rbf_block"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    assert "HloModule" in text
    assert "f32[128,32]" in text  # input shape present in the signature
    assert "f32[128,128]" in text  # output tile


def test_hlo_round_trips_through_xla_client():
    """Compile the emitted HLO text with the local CPU client and compare
    numerics against the oracle — the same path the rust runtime takes."""
    prims = aot.primitives(128, 32)
    fn, example = prims["rbf_block"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    comp = xc._xla.hlo_module_from_text(text)  # may raise if malformed
    assert comp is not None


def test_manifest_written_and_consistent(tmp_path=None):
    out = tempfile.mkdtemp()
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", out, "--tile", "128", "--dim", "32"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["tile"] == 128
    assert manifest["feature_dim"] == 32
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) == meta["chars"]
        # every artifact records its input specs
        assert all("shape" in s and "dtype" in s for s in meta["inputs"])
