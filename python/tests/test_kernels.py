"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps tile shapes, block sizes and bandwidths; every case
asserts allclose between the Pallas interpreter result and the oracle —
this is THE correctness signal for the compute layer.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import rbf, ref

RTOL = 2e-5
ATOL = 2e-6


def rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


# shapes must be multiples of the block size; sweep several geometries
block_sizes = st.sampled_from([32, 64, 128])
multipliers = st.integers(min_value=1, max_value=3)
dims = st.sampled_from([2, 8, 18, 32])
gammas = st.floats(min_value=1e-3, max_value=2.0)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(bm=block_sizes, mi=multipliers, ni=multipliers, d=dims, g=gammas, s=seeds)
def test_rbf_block_matches_ref(bm, mi, ni, d, g, s):
    m, n = bm * mi, bm * ni
    x, y = rand((m, d), s), rand((n, d), s + 1)
    got = rbf.rbf_block(jnp.array(x), jnp.array(y), g, bm=bm, bn=bm)
    want = ref.rbf_block(jnp.array(x), jnp.array(y), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(bm=block_sizes, mi=multipliers, d=dims, g=gammas, s=seeds)
def test_rbf_matvec_matches_ref(bm, mi, d, g, s):
    m, n = bm * mi, 128
    x, y = rand((m, d), s), rand((n, d), s + 1)
    v = rand((n,), s + 2)
    got = rbf.rbf_matvec(jnp.array(x), jnp.array(y), jnp.array(v), g, bm=bm)
    want = ref.rbf_matvec(jnp.array(x), jnp.array(y), jnp.array(v), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(bm=block_sizes, mi=multipliers, d=dims, g=gammas, s=seeds)
def test_rbf_matvec_t_matches_ref(bm, mi, d, g, s):
    m, n = bm * mi, 128
    x, y = rand((m, d), s), rand((n, d), s + 1)
    u = rand((m,), s + 2)
    got = rbf.rbf_matvec_t(jnp.array(x), jnp.array(y), jnp.array(u), g, bm=bm)
    want = ref.rbf_matvec_t(jnp.array(x), jnp.array(y), jnp.array(u), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_unit_diagonal_and_symmetry():
    x = rand((128, 32), 7)
    k = np.asarray(rbf.rbf_block(jnp.array(x), jnp.array(x), 0.3))
    np.testing.assert_allclose(np.diag(k), np.ones(128), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(k, k.T, rtol=1e-6, atol=1e-6)
    assert k.max() <= 1.0 + 1e-6
    assert k.min() >= 0.0


def test_zero_padding_is_exact_for_matvec():
    """The rust runtime zero-pads partial tiles; check the contract:
    padded v entries nullify padded centers exactly."""
    x = rand((128, 32), 11)
    y = rand((128, 32), 12)
    v = rand((128,), 13)
    full = np.asarray(
        rbf.rbf_matvec(jnp.array(x), jnp.array(y), jnp.array(v), 0.25)
    )
    # pad y's tail with garbage-located points but v with zeros
    y_pad = y.copy()
    y_pad[100:] = 1e3
    v_pad = v.copy()
    v_pad[100:] = 0.0
    y_trim, v_trim = y[:100], v[:100]
    want = np.asarray(
        ref.rbf_matvec(jnp.array(x), jnp.array(y_trim), jnp.array(v_trim), 0.25)
    )
    got = np.asarray(
        rbf.rbf_matvec(jnp.array(x), jnp.array(y_pad), jnp.array(v_pad), 0.25)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_feature_zero_padding_is_exact():
    """Padding the feature dimension with zero columns must not change K."""
    x18 = rand((128, 18), 21)
    y18 = rand((128, 18), 22)
    x32 = np.zeros((128, 32), np.float32)
    y32 = np.zeros((128, 32), np.float32)
    x32[:, :18], y32[:, :18] = x18, y18
    k18 = np.asarray(ref.rbf_block(jnp.array(x18), jnp.array(y18), 0.4))
    k32 = np.asarray(rbf.rbf_block(jnp.array(x32), jnp.array(y32), 0.4))
    np.testing.assert_allclose(k32, k18, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("g", [1e-4, 0.1, 5.0])
def test_gamma_is_traced_not_baked(g):
    """One jitted kernel must serve every bandwidth (gamma is an input)."""
    x = rand((128, 32), 31)
    y = rand((128, 32), 32)
    got = np.asarray(rbf.rbf_block(jnp.array(x), jnp.array(y), g))
    want = np.asarray(ref.rbf_block(jnp.array(x), jnp.array(y), g))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
