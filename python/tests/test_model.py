"""L2 correctness: model.py compute graphs vs dense oracles."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


def test_kernel_tile_shape_and_value():
    x, y = rand((256, 32), 0), rand((256, 32), 1)
    k = np.asarray(model.kernel_tile(jnp.array(x), jnp.array(y), 0.2))
    assert k.shape == (256, 256)
    want = np.asarray(ref.rbf_block(jnp.array(x), jnp.array(y), 0.2))
    np.testing.assert_allclose(k, want, rtol=2e-5, atol=2e-6)


def test_fused_normal_tile_equals_two_step():
    x, y = rand((256, 32), 2), rand((256, 32), 3)
    v = rand((256,), 4)
    fused = np.asarray(
        model.kernel_fused_normal_tile(jnp.array(x), jnp.array(y), jnp.array(v), 0.2)
    )
    k = np.asarray(ref.rbf_block(jnp.array(x), jnp.array(y), 0.2))
    want = k.T @ (k @ v)
    np.testing.assert_allclose(fused, want, rtol=1e-3, atol=1e-3)


def test_degree_tile_is_row_sums():
    x, y = rand((256, 32), 5), rand((256, 32), 6)
    deg = np.asarray(model.degree_tile(jnp.array(x), jnp.array(y), 0.2))
    k = np.asarray(ref.rbf_block(jnp.array(x), jnp.array(y), 0.2))
    np.testing.assert_allclose(deg, k.sum(axis=1), rtol=1e-4, atol=1e-4)


def test_matvec_round_trip_consistency():
    """matvec_t(x, y, matvec(x, y, v)) == K^T K v."""
    x, y = rand((256, 32), 7), rand((256, 32), 8)
    v = rand((256,), 9)
    kv = model.kernel_matvec_tile(jnp.array(x), jnp.array(y), jnp.array(v), 0.15)
    ktkv = np.asarray(
        model.kernel_matvec_t_tile(jnp.array(x), jnp.array(y), kv, 0.15)
    )
    k = np.asarray(ref.rbf_block(jnp.array(x), jnp.array(y), 0.15))
    np.testing.assert_allclose(ktkv, k.T @ (k @ np.asarray(v)), rtol=1e-3, atol=1e-3)
