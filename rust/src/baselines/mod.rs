//! Comparison baselines from Table 1 of the paper: uniform sampling [5],
//! exact RLS sampling, Two-Pass sampling [6], Recursive-RLS [9] and
//! SQUEAK [8]. All return the same [`WeightedSet`] shape as BLESS so the
//! downstream consumers (Figure-1 accuracy harness, FALKON) are agnostic
//! to the sampler. Their kernel-column block products go through the same
//! parallel [`crate::leverage::LsGenerator`] scoring path as BLESS, so
//! every baseline shares the [`crate::util::pool`] speedup — the Table-1
//! timing comparison stays apples-to-apples at any thread count.

mod rrls;
mod squeak;
mod two_pass;

pub use rrls::{rrls, RrlsConfig};
pub use squeak::{squeak, SqueakConfig};
pub use two_pass::{two_pass, TwoPassConfig};

use crate::kernels::KernelEngine;
use crate::leverage::{exact_leverage_scores, WeightedSet};
use crate::rng::Rng;

/// Output of a sampling baseline: the weighted set plus cost accounting.
#[derive(Clone, Debug)]
pub struct SamplerOutput {
    pub set: WeightedSet,
    /// Number of leverage-score evaluations performed (0 for uniform).
    pub score_evals: usize,
}

/// Uniform Nyström sampling [5]: `m` columns without replacement, `A = I`.
///
/// Needs `m ≈ d_∞(λ) ≤ 1/λ` columns for the Eq.-2 guarantee — the gap to
/// `d_eff(λ)` is exactly what leverage-score sampling buys (Table 1).
pub fn uniform(engine: &dyn KernelEngine, lambda: f64, m: usize, rng: &mut Rng) -> SamplerOutput {
    let n = engine.n();
    let m = m.min(n);
    let indices = rng.sample_without_replacement(n, m);
    SamplerOutput { set: WeightedSet::uniform(indices, lambda), score_evals: 0 }
}

/// Exact RLS sampling: `m` multinomial draws from the *exact* leverage
/// scores (Eq. 1). `O(n³)` — the gold standard for accuracy comparisons.
pub fn exact_rls(
    engine: &dyn KernelEngine,
    lambda: f64,
    m: usize,
    rng: &mut Rng,
) -> SamplerOutput {
    let n = engine.n();
    let scores =
        exact_leverage_scores(engine, lambda).expect("exact RLS reference must factor");
    let set = sample_proportional(&(0..n).collect::<Vec<_>>(), &scores, m, n, lambda, rng);
    SamplerOutput { set, score_evals: n }
}

/// Shared tail of every with-replacement leverage sampler: draw `m`
/// columns from `pool` proportionally to `scores`, attaching the
/// importance weights that make Eq. (3) unbiased:
/// `A = (|pool|·m/n)·diag(p_j)` (Alg. 1 line 10 with `R = |pool|`).
pub(crate) fn sample_proportional(
    pool: &[usize],
    scores: &[f64],
    m: usize,
    n: usize,
    lambda: f64,
    rng: &mut Rng,
) -> WeightedSet {
    assert_eq!(pool.len(), scores.len());
    assert!(!pool.is_empty(), "empty candidate pool");
    let total: f64 = scores.iter().sum();
    assert!(total > 0.0, "all-zero scores");
    let picks = rng.multinomial(scores, m);
    let coeff = (pool.len() as f64) * (m as f64) / (n as f64);
    let mut indices = Vec::with_capacity(m);
    let mut weights = Vec::with_capacity(m);
    for &k in &picks {
        indices.push(pool[k]);
        weights.push(coeff * scores[k] / total);
    }
    WeightedSet { indices, weights, lambda }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{LsGenerator, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(51));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn uniform_properties() {
        let eng = engine(100);
        let out = uniform(&eng, 1e-2, 30, &mut Rng::seeded(0));
        assert_eq!(out.set.len(), 30);
        assert_eq!(out.score_evals, 0);
        assert!(out.set.weights.iter().all(|&w| w == 1.0));
        let mut idx = out.set.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 30);
    }

    #[test]
    fn exact_rls_sampling_is_accurate_generator() {
        let eng = engine(250);
        let lambda = 1e-2;
        let out = exact_rls(&eng, lambda, 120, &mut Rng::seeded(1));
        let gen = LsGenerator::new(&eng, &out.set, lambda).unwrap();
        let all: Vec<usize> = (0..250).collect();
        let approx = gen.scores(&all);
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let stats = RAccStats::from_scores(&approx, &exact);
        assert!(stats.mean > 0.7 && stats.mean < 1.6, "mean {}", stats.mean);
    }

    #[test]
    fn sample_proportional_weights_are_m_p_scaled() {
        let mut rng = Rng::seeded(2);
        let pool: Vec<usize> = (0..10).collect();
        let scores = vec![1.0; 10];
        let set = sample_proportional(&pool, &scores, 5, 10, 0.1, &mut rng);
        // |pool| = n = 10, p = 1/10 ⇒ A_jj = 10·5/10 · 1/10 = 0.5
        for &w in &set.weights {
            assert!((w - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn zero_scores_rejected() {
        let mut rng = Rng::seeded(3);
        sample_proportional(&[0, 1], &[0.0, 0.0], 2, 2, 0.1, &mut rng);
    }
}
