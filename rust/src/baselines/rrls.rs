//! Recursive-RLS [Musco & Musco, 2017] — recursive halving: estimate
//! leverage scores of a set from the scores of a uniformly-halved subset,
//! recursing until the base case fits a direct sample. Bernoulli keeps
//! with `p_i = min(q₂·ℓ̃(i), 1)` and inverse-probability weights (our
//! Eq.-3 convention stores `A_ii = p_i`, matching BLESS-R).
//!
//! Cost is dominated by the top level: `n` score evaluations against a
//! dictionary of size `O(d_eff)` ⇒ `O(n·d_eff²)` (Table 1). That
//! full-dataset sweep runs through [`LsGenerator::scores_all`] — the
//! dictionary rows are gathered once per level (the cached-center path)
//! and the `n` cross-kernel columns stream in row tiles.

use super::SamplerOutput;
use crate::kernels::KernelEngine;
use crate::leverage::{LsGenerator, WeightedSet};
use crate::rng::Rng;

/// Parameters of Recursive-RLS.
#[derive(Clone, Debug)]
pub struct RrlsConfig {
    /// Oversampling constant in `p_i = min(q₂·ℓ̃(i,λ), 1)`.
    pub q2: f64,
    /// Recursion base: pools of at most this size are used directly
    /// (uniform weights) instead of recursing further.
    pub base_size: usize,
    /// Floor on every level's kept-set size.
    pub min_m: usize,
}

impl Default for RrlsConfig {
    fn default() -> Self {
        RrlsConfig { q2: 4.0, base_size: 128, min_m: 8 }
    }
}

/// Run Recursive-RLS at regularization `lambda` over the whole dataset.
pub fn rrls(
    engine: &dyn KernelEngine,
    lambda: f64,
    cfg: &RrlsConfig,
    rng: &mut Rng,
) -> SamplerOutput {
    let n = engine.n();
    let pool: Vec<usize> = (0..n).collect();
    let mut evals = 0usize;
    let set = recurse(engine, &pool, lambda, cfg, rng, &mut evals);
    SamplerOutput { set, score_evals: evals }
}

fn recurse(
    engine: &dyn KernelEngine,
    pool: &[usize],
    lambda: f64,
    cfg: &RrlsConfig,
    rng: &mut Rng,
    evals: &mut usize,
) -> WeightedSet {
    if pool.len() <= cfg.base_size {
        return WeightedSet::uniform(pool.to_vec(), lambda);
    }
    // uniform halving (Bernoulli(1/2) per element, as in the original)
    let half: Vec<usize> = pool.iter().copied().filter(|_| rng.bernoulli(0.5)).collect();
    let half = if half.is_empty() { vec![pool[0]] } else { half };
    let inner = recurse(engine, &half, lambda, cfg, rng, evals);

    // score the whole pool against the inner dictionary; the top level
    // (pool = the full dataset) takes the streamed full-sweep path.
    // scores_all returns identity order, so the fast path is only valid
    // for the ascending 0..n pool — which any full-length pool is today
    // (halving is an order-preserving filter of 0..n), guarded below.
    let gen = LsGenerator::new(engine, &inner, lambda).expect("rrls generator must factor");
    let scores = if pool.len() == engine.n() {
        debug_assert!(
            pool.iter().enumerate().all(|(k, &i)| k == i),
            "full-length rrls pool must be the identity ordering"
        );
        gen.scores_all()
    } else {
        gen.scores(pool)
    };
    *evals += pool.len();

    // Bernoulli keeps with p = min(q2·ℓ̃, 1); A_ii = p_i
    let mut indices = Vec::new();
    let mut weights = Vec::new();
    for (k, &i) in pool.iter().enumerate() {
        let p = (cfg.q2 * scores[k]).min(1.0);
        if rng.bernoulli(p) {
            indices.push(i);
            weights.push(p);
        }
    }
    // degenerate-level guard
    let floor = cfg.min_m.min(pool.len());
    let mut k = 0;
    while indices.len() < floor {
        let cand = pool[k % pool.len()];
        if !indices.contains(&cand) {
            indices.push(cand);
            weights.push(1.0);
        }
        k += 1;
    }
    WeightedSet { indices, weights, lambda }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{exact_leverage_scores, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(71));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn output_accurate_generator() {
        let eng = engine(400);
        let lambda = 5e-3;
        let out = rrls(&eng, lambda, &RrlsConfig::default(), &mut Rng::seeded(1));
        out.set.validate().unwrap();
        // top level scores all n points
        assert!(out.score_evals >= 400);
        let gen = LsGenerator::new(&eng, &out.set, lambda).unwrap();
        let stats =
            RAccStats::from_scores(&gen.scores_all(), &exact_leverage_scores(&eng, lambda).unwrap());
        assert!(stats.mean > 0.5 && stats.mean < 2.0, "mean {}", stats.mean);
    }

    #[test]
    fn small_pool_short_circuits() {
        let eng = engine(50);
        let out = rrls(&eng, 1e-2, &RrlsConfig::default(), &mut Rng::seeded(2));
        // n ≤ base_size: uniform pass-through, no score evals
        assert_eq!(out.score_evals, 0);
        assert_eq!(out.set.len(), 50);
    }

    #[test]
    fn distinct_indices() {
        let eng = engine(300);
        let out = rrls(&eng, 1e-2, &RrlsConfig::default(), &mut Rng::seeded(3));
        let mut idx = out.set.indices.clone();
        idx.sort_unstable();
        let before = idx.len();
        idx.dedup();
        assert_eq!(idx.len(), before);
    }
}
