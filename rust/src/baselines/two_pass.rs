//! Two-Pass sampling [El Alaoui & Mahoney, 2015] — the first approximate
//! leverage-score sampler: one uniform pass to build `J₁` of size
//! `≈ q₁/λ`, then one full pass computing `ℓ̃_{J₁}(i, λ)` for **all**
//! `i ∈ [n]` and sampling `J₂` from them. Cost `O(n/λ²)` — the `R·M²`
//! term of §2.2 with `R = n`, `M = 1/λ`.

use super::{sample_proportional, SamplerOutput};
use crate::kernels::KernelEngine;
use crate::leverage::{LsGenerator, WeightedSet};
use crate::rng::Rng;

/// Parameters of Two-Pass sampling.
#[derive(Clone, Debug)]
pub struct TwoPassConfig {
    /// First-pass pool size multiplier: `|J₁| = min(q₁/λ, n)`.
    pub q1: f64,
    /// Final oversampling: `|J₂| = q₂ · d̂_eff`.
    pub q2: f64,
    /// Floor on the output size.
    pub min_m: usize,
}

impl Default for TwoPassConfig {
    fn default() -> Self {
        TwoPassConfig { q1: 2.0, q2: 4.0, min_m: 8 }
    }
}

/// Run Two-Pass sampling at regularization `lambda`.
pub fn two_pass(
    engine: &dyn KernelEngine,
    lambda: f64,
    cfg: &TwoPassConfig,
    rng: &mut Rng,
) -> SamplerOutput {
    let n = engine.n();
    let kappa_sq = engine.kappa_sq();
    // Pass 1: uniform J₁ of size ≈ q₁·κ²/λ (the d_∞ ≤ κ²/λ bound).
    let m1 = ((cfg.q1 * kappa_sq / lambda).ceil() as usize).clamp(cfg.min_m.min(n), n);
    let j1 = rng.sample_without_replacement(n, m1);
    let set1 = WeightedSet::uniform(j1, lambda);

    // Pass 2: score every point against J₁, then multinomial-sample J₂.
    let gen = LsGenerator::new(engine, &set1, lambda).expect("two-pass generator must factor");
    let all: Vec<usize> = (0..n).collect();
    let scores = gen.scores(&all);
    let d_est: f64 = scores.iter().sum();
    let m2 = ((cfg.q2 * d_est).ceil() as usize).clamp(cfg.min_m, n);
    let set = sample_proportional(&all, &scores, m2, n, lambda, rng);
    SamplerOutput { set, score_evals: n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{exact_leverage_scores, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(61));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn output_accurate_and_sized() {
        let eng = engine(300);
        let lambda = 1e-2;
        let out = two_pass(&eng, lambda, &TwoPassConfig::default(), &mut Rng::seeded(1));
        assert_eq!(out.score_evals, 300);
        out.set.validate().unwrap();
        let gen = LsGenerator::new(&eng, &out.set, lambda).unwrap();
        let all: Vec<usize> = (0..300).collect();
        let stats =
            RAccStats::from_scores(&gen.scores(&all), &exact_leverage_scores(&eng, lambda).unwrap());
        assert!(stats.mean > 0.6 && stats.mean < 1.8, "mean {}", stats.mean);
    }

    #[test]
    fn pool_caps_at_n_for_tiny_lambda() {
        let eng = engine(120);
        // q1/λ ≫ n: J₁ must cap at n and the algorithm still works
        let out = two_pass(&eng, 1e-4, &TwoPassConfig::default(), &mut Rng::seeded(2));
        out.set.validate().unwrap();
        assert!(out.set.len() <= 120);
    }
}
