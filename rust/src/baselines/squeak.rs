//! SQUEAK [Calandriello, Lazaric & Valko, 2017] — single-pass
//! merge-and-reduce: partition `[n]` into chunks, maintain a weighted
//! dictionary, and at each step merge the next chunk into the dictionary,
//! re-estimate leverage scores of the merged set against itself
//! (`L_{J∪U}(J∪U, λ) ↦ J'`, Eq. 7), and thin it with shrinking inclusion
//! probabilities `p_i = min(q₂·ℓ̃(i,λ), p_i^{old})`.
//!
//! Cost: `n/c` merges of `O((M+c)³)` ⇒ `O(n·d_eff²)` for chunk size
//! `c ≍ d_eff` (Table 1).

use super::SamplerOutput;
use crate::kernels::KernelEngine;
use crate::leverage::{LsGenerator, WeightedSet};
use crate::rng::Rng;

/// Parameters of SQUEAK.
#[derive(Clone, Debug)]
pub struct SqueakConfig {
    /// Oversampling constant in `p = min(q₂·ℓ̃, 1)`.
    pub q2: f64,
    /// Chunk size `|U_h|`; `None` picks `max(min_m, ⌈q₂·κ²/λ⌉^{1/1}∧n/4)`
    /// heuristically (≈ the expected dictionary size).
    pub chunk: Option<usize>,
    /// Floor on the dictionary size.
    pub min_m: usize,
}

impl Default for SqueakConfig {
    fn default() -> Self {
        SqueakConfig { q2: 4.0, chunk: None, min_m: 8 }
    }
}

/// Run SQUEAK at regularization `lambda` (single pass over a random
/// permutation of the data).
pub fn squeak(
    engine: &dyn KernelEngine,
    lambda: f64,
    cfg: &SqueakConfig,
    rng: &mut Rng,
) -> SamplerOutput {
    let n = engine.n();
    let chunk = cfg
        .chunk
        .unwrap_or_else(|| {
            // heuristic chunk ≈ expected dictionary size, capped for memory
            let guess = (cfg.q2 / lambda).sqrt() * 8.0;
            (guess.ceil() as usize).clamp(cfg.min_m.max(16), (n / 2).max(16))
        })
        .max(1);
    let perm = rng.permutation(n);
    let mut evals = 0usize;

    // D_1 = U_1 with unit weights.
    let first: Vec<usize> = perm.iter().copied().take(chunk.min(n)).collect();
    let mut dict_idx = first;
    let mut dict_p: Vec<f64> = vec![1.0; dict_idx.len()];

    let mut pos = dict_idx.len();
    while pos < n {
        let next_end = (pos + chunk).min(n);
        // merge: dictionary ∪ next chunk (chunk members enter with p = 1)
        let mut merged_idx = dict_idx.clone();
        let mut merged_p = dict_p.clone();
        for &i in &perm[pos..next_end] {
            merged_idx.push(i);
            merged_p.push(1.0);
        }
        pos = next_end;

        // score the merged set against itself (Eq. 7)
        let merged_set =
            WeightedSet { indices: merged_idx.clone(), weights: merged_p.clone(), lambda };
        let gen =
            LsGenerator::new(engine, &merged_set, lambda).expect("squeak generator must factor");
        let scores = gen.scores(&merged_idx);
        evals += merged_idx.len();

        // shrink-only Bernoulli thinning
        let mut new_idx = Vec::new();
        let mut new_p = Vec::new();
        for (k, &i) in merged_idx.iter().enumerate() {
            let p_target = (cfg.q2 * scores[k]).min(1.0).min(merged_p[k]);
            let keep_prob = p_target / merged_p[k];
            if rng.bernoulli(keep_prob) {
                new_idx.push(i);
                new_p.push(p_target);
            }
        }
        // degenerate guard
        let floor = cfg.min_m.min(merged_idx.len());
        let mut k = 0;
        while new_idx.len() < floor {
            let cand = merged_idx[k % merged_idx.len()];
            if !new_idx.contains(&cand) {
                new_idx.push(cand);
                new_p.push(merged_p[k % merged_p.len()]);
            }
            k += 1;
        }
        dict_idx = new_idx;
        dict_p = new_p;
    }

    let set = WeightedSet { indices: dict_idx, weights: dict_p, lambda };
    SamplerOutput { set, score_evals: evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{exact_leverage_scores, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(81));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn output_accurate_generator() {
        let eng = engine(400);
        let lambda = 5e-3;
        let out = squeak(&eng, lambda, &SqueakConfig::default(), &mut Rng::seeded(1));
        out.set.validate().unwrap();
        assert!(out.score_evals >= 400, "single pass must touch every point");
        let gen = LsGenerator::new(&eng, &out.set, lambda).unwrap();
        let all: Vec<usize> = (0..400).collect();
        let stats =
            RAccStats::from_scores(&gen.scores(&all), &exact_leverage_scores(&eng, lambda).unwrap());
        assert!(stats.mean > 0.5 && stats.mean < 2.0, "mean {}", stats.mean);
    }

    #[test]
    fn dictionary_much_smaller_than_n() {
        let eng = engine(500);
        let out = squeak(&eng, 1e-2, &SqueakConfig::default(), &mut Rng::seeded(2));
        assert!(out.set.len() < 500, "dictionary must compress");
        assert!(out.set.len() >= SqueakConfig::default().min_m);
    }

    #[test]
    fn weights_are_valid_probabilities() {
        let eng = engine(300);
        let out = squeak(&eng, 1e-2, &SqueakConfig::default(), &mut Rng::seeded(3));
        for &w in &out.set.weights {
            assert!(w > 0.0 && w <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn explicit_chunk_respected() {
        let eng = engine(200);
        let cfg = SqueakConfig { chunk: Some(50), ..Default::default() };
        let out = squeak(&eng, 1e-2, &cfg, &mut Rng::seeded(4));
        out.set.validate().unwrap();
    }
}
