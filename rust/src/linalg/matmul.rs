//! Typed matrix-multiply facade over the blocked GEMM/SYRK engines.
//!
//! The linalg tier historically grew one free function per
//! transpose × accumulate × triangle combination (`gemm_nt`,
//! `gemm_nt_into`, `gemm_nt_acc`, `gemm_tn`, `syrk_tn`, `syrk_tn_into`,
//! …). [`MatMul`] collapses that sprawl into one descriptor: pick the
//! operand orientation with [`MatMul::nn`]/[`MatMul::nt`]/[`MatMul::tn`],
//! opt into accumulation and/or symmetric lower-triangle output with the
//! builder methods, and run it. Every path routes through the same
//! pool-parallel engines — and through them the runtime-dispatched
//! [`super::dispatch`] micro-kernels — as the legacy free functions, so
//! results are bit-for-bit identical to the wrappers they replace.
//!
//! ```
//! use bless::linalg::{MatMul, Matrix};
//! let a = Matrix::from_fn(5, 3, |i, j| (i + j) as f64);
//! let b = Matrix::from_fn(7, 3, |i, j| (i * 7 + j) as f64 * 0.5);
//! let c = MatMul::nt().run(&a, &b); // A·Bᵀ, no transpose materialized
//! assert_eq!(c.rows(), 5);
//! assert_eq!(c.cols(), 7);
//! let gram = MatMul::tn().lower().run(&a, &a); // AᵀA via the syrk engine
//! assert_eq!(gram.rows(), 3);
//! ```

use super::{gemm, Matrix};

/// Operand orientation of a [`MatMul`]: which sides are read transposed.
///
/// All operands are row-major and no transpose is ever materialized —
/// `Nt`/`Tn` pick engines whose loop order streams the stored layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transpose {
    /// `C = A · B` (A is m×k, B is k×n).
    Nn,
    /// `C = A · Bᵀ` (A is m×k, B is n×k) — the kernel cross-term shape.
    Nt,
    /// `C = Aᵀ · B` (A is k×m, B is k×n) — the Gram-accumulation shape.
    Tn,
}

/// Output shape of a [`MatMul`]: the full product or only the lower
/// triangle of a symmetric one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Triangle {
    /// Every element of `C`.
    Full,
    /// Lower triangle only — valid when the product is symmetric, i.e.
    /// both operands are the **same** matrix (`A·Aᵀ` or `AᵀA`); costs
    /// half the multiply-adds of the full product.
    Lower,
}

/// A typed matrix-multiply descriptor: orientation × accumulate ×
/// triangle, routed through the runtime-dispatched micro-kernel tier.
///
/// Construct with [`MatMul::nn`]/[`MatMul::nt`]/[`MatMul::tn`], refine
/// with [`MatMul::accumulate`] / [`MatMul::lower`], then [`MatMul::run`]
/// (allocating) or [`MatMul::run_into`] (into an existing buffer). The
/// struct is plain data — build it once and reuse it, or inline the
/// chain at the call site.
#[derive(Clone, Copy, Debug)]
pub struct MatMul {
    /// Which operands are read transposed.
    pub transpose: Transpose,
    /// `run_into` adds to the existing output instead of overwriting it.
    pub accumulate: bool,
    /// Full product, or lower triangle of a symmetric one.
    pub triangle: Triangle,
}

impl MatMul {
    /// `C = A · B`.
    pub const fn nn() -> Self {
        MatMul { transpose: Transpose::Nn, accumulate: false, triangle: Triangle::Full }
    }

    /// `C = A · Bᵀ` without materializing `Bᵀ`.
    pub const fn nt() -> Self {
        MatMul { transpose: Transpose::Nt, accumulate: false, triangle: Triangle::Full }
    }

    /// `C = Aᵀ · B` without materializing `Aᵀ`.
    pub const fn tn() -> Self {
        MatMul { transpose: Transpose::Tn, accumulate: false, triangle: Triangle::Full }
    }

    /// Accumulate into the existing output (`C += …`) instead of
    /// overwriting it. Only affects [`MatMul::run_into`] /
    /// [`MatMul::run_rows_into`].
    pub const fn accumulate(mut self) -> Self {
        self.accumulate = true;
        self
    }

    /// Compute only the lower triangle of a **symmetric** product
    /// (`A·Aᵀ` for [`MatMul::nt`], `AᵀA` for [`MatMul::tn`]); both
    /// operand arguments must then be the same matrix. [`MatMul::run`]
    /// mirrors the triangle so the returned matrix is exactly symmetric;
    /// [`MatMul::run_into`] touches only the lower triangle.
    pub const fn lower(mut self) -> Self {
        self.triangle = Triangle::Lower;
        self
    }

    /// Run the product into a freshly allocated output matrix.
    ///
    /// With [`Triangle::Lower`] the lower triangle is computed and then
    /// mirrored, so the result is exactly symmetric (bitwise: the
    /// strict upper equals the strict lower).
    pub fn run(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let (rows, cols) = self.out_shape(a, b);
        let mut c = Matrix::zeros(rows, cols);
        self.dispatch_into(a, b, &mut c);
        if self.triangle == Triangle::Lower {
            c.mirror_lower_to_upper();
        }
        c
    }

    /// Run the product into an existing buffer: overwrite by default,
    /// `C += …` after [`MatMul::accumulate`].
    ///
    /// With [`Triangle::Lower`] only the lower triangle is written (the
    /// strict upper is untouched in accumulate mode and zeroed in
    /// overwrite mode) — the Nyström Gram-accumulation shape: add tile
    /// after tile, then mirror once at the end.
    pub fn run_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix) {
        if !self.accumulate {
            c.as_mut_slice().fill(0.0);
        }
        self.dispatch_into(a, b, c);
    }

    /// Raw row-major slice form of the NT product: `C += A · Bᵀ` with
    /// `A` = `(c.len()/n) × k`, `B` = `n × k`, `C` = `(c.len()/n) × n`
    /// (overwrite first unless [`MatMul::accumulate`]).
    ///
    /// Exists so callers holding borrowed row ranges — the kernel engine
    /// streaming contiguous dataset tiles — can feed the product without
    /// copying into a fresh [`Matrix`]. Only [`MatMul::nt`] with
    /// [`Triangle::Full`] is defined for slices.
    pub fn run_rows_into(&self, a: &[f64], b: &[f64], k: usize, c: &mut [f64], n: usize) {
        assert_eq!(
            (self.transpose, self.triangle),
            (Transpose::Nt, Triangle::Full),
            "run_rows_into supports only the full NT product"
        );
        if !self.accumulate {
            c.fill(0.0);
        }
        gemm::nt_acc(a, b, k, c, n);
    }

    /// Output shape for the given operands.
    fn out_shape(&self, a: &Matrix, b: &Matrix) -> (usize, usize) {
        match self.transpose {
            Transpose::Nn => (a.rows(), b.cols()),
            Transpose::Nt => (a.rows(), b.rows()),
            Transpose::Tn => (a.cols(), b.cols()),
        }
    }

    /// Route to the matching engine (always accumulating; `run`/
    /// `run_into` handle the overwrite semantics).
    fn dispatch_into(&self, a: &Matrix, b: &Matrix, c: &mut Matrix) {
        match self.triangle {
            Triangle::Full => match self.transpose {
                Transpose::Nn => super::gemm_into(a, b, c),
                Transpose::Nt => gemm::nt_into_checked(a, b, c),
                Transpose::Tn => gemm::tn_acc_into(a, b, c),
            },
            Triangle::Lower => {
                assert!(
                    std::ptr::eq(a, b),
                    "Triangle::Lower needs a symmetric product — pass the same matrix twice"
                );
                match self.transpose {
                    Transpose::Nt => gemm::nt_lower_acc_into(a, c),
                    Transpose::Tn => gemm::tn_lower_acc_into(a, c),
                    Transpose::Nn => panic!("Triangle::Lower is undefined for the NN product"),
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // compares the facade bitwise against the legacy wrappers
mod tests {
    use super::super::{gemm_nt, gemm_nt_acc, gemm_tn, syrk, syrk_tn, syrk_tn_into};
    use super::*;

    fn bits(m: &Matrix) -> Vec<u64> {
        m.as_slice().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn facade_matches_legacy_wrappers_bitwise() {
        let a = Matrix::from_fn(23, 17, |i, j| ((i * 17 + j) as f64 * 0.37).sin());
        let b = Matrix::from_fn(19, 17, |i, j| ((i * 19 + j) as f64 * 0.73).cos());
        assert_eq!(bits(&MatMul::nt().run(&a, &b)), bits(&gemm_nt(&a, &b)));
        let t = Matrix::from_fn(17, 11, |i, j| ((i + 3 * j) % 7) as f64 - 3.0);
        assert_eq!(bits(&MatMul::tn().run(&a, &t)), bits(&gemm_tn(&a, &t)));
        assert_eq!(bits(&MatMul::nt().lower().run(&a, &a)), bits(&syrk(&a)));
        assert_eq!(bits(&MatMul::tn().lower().run(&a, &a)), bits(&syrk_tn(&a)));
        let nn = MatMul::nn().run(&a, &t);
        assert_eq!(bits(&nn), bits(&super::super::gemm(&a, &t)));
    }

    #[test]
    fn accumulate_and_overwrite_semantics() {
        let a = Matrix::from_fn(9, 5, |i, j| (i + j) as f64);
        let b = Matrix::from_fn(7, 5, |i, j| (i as f64 - j as f64) * 0.5);
        // accumulate adds to the existing contents
        let mut c1 = Matrix::from_fn(9, 7, |i, j| (i * 7 + j) as f64);
        let mut c2 = c1.clone();
        MatMul::nt().accumulate().run_into(&a, &b, &mut c1);
        super::super::gemm_nt_into(&a, &b, &mut c2);
        assert_eq!(bits(&c1), bits(&c2));
        // overwrite ignores the existing contents
        let mut c3 = Matrix::from_fn(9, 7, |_, _| 1e9);
        MatMul::nt().run_into(&a, &b, &mut c3);
        assert_eq!(bits(&c3), bits(&gemm_nt(&a, &b)));
    }

    #[test]
    fn lower_run_into_leaves_strict_upper_alone_when_accumulating() {
        let a = Matrix::from_fn(40, 21, |i, j| ((i * 21 + j) as f64 * 0.23).sin());
        let mut acc = Matrix::from_fn(21, 21, |i, j| if j > i { 7.5 } else { 0.0 });
        MatMul::tn().accumulate().lower().run_into(&a, &a, &mut acc);
        let mut reference = Matrix::zeros(21, 21);
        syrk_tn_into(&a, &mut reference);
        for i in 0..21 {
            for j in 0..21 {
                if j > i {
                    assert_eq!(acc.get(i, j), 7.5, "strict upper touched at ({i},{j})");
                } else {
                    assert_eq!(acc.get(i, j).to_bits(), reference.get(i, j).to_bits());
                }
            }
        }
    }

    #[test]
    fn slice_form_matches_legacy_acc() {
        let a = Matrix::from_fn(13, 29, |i, j| ((i * 29 + j) % 7) as f64 - 3.0);
        let b = Matrix::from_fn(11, 29, |i, j| ((i * 11 + j) % 5) as f64 - 2.0);
        let mut c1 = vec![0.25; 13 * 11];
        let mut c2 = c1.clone();
        MatMul::nt().accumulate().run_rows_into(a.as_slice(), b.as_slice(), 29, &mut c1, 11);
        gemm_nt_acc(a.as_slice(), b.as_slice(), 29, &mut c2, 11);
        let b1: Vec<u64> = c1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u64> = c2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2);
    }

    #[test]
    #[should_panic(expected = "symmetric product")]
    fn lower_rejects_distinct_operands() {
        let a = Matrix::from_fn(4, 3, |i, j| (i + j) as f64);
        let b = a.clone();
        let _ = MatMul::nt().lower().run(&a, &b);
    }
}
