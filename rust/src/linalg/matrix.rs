//! Row-major dense `f64` matrix.

use std::fmt;

/// Row-major dense matrix of `f64`.
///
/// The single dense container used across the crate: kernel blocks,
/// Cholesky factors, preconditioners. Indexing is `(row, col)`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Deterministic, exactly symmetric, diagonally dominant — hence SPD
    /// — probe matrix: the shared input of the factorization benches and
    /// the cross-thread determinism tests (built serially via
    /// [`Matrix::from_fn`], so it is identical at any pool width).
    pub fn spd_probe(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| {
            if i == j {
                0.25 * n as f64 + (i % 7) as f64 * 0.125
            } else {
                let (lo, hi) = (i.min(j), i.max(j));
                (((lo * 31 + hi * 17) % 23) as f64 - 11.0) * 0.01
            }
        })
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Underlying row-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable underlying row-major storage.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// The main diagonal.
    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.get(i, i)).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Copy the lower triangle over the strict upper triangle, making a
    /// square matrix exactly symmetric (the tail of the lower-triangle-only
    /// symmetric rank-k updates in [`crate::linalg::syrk`] and friends).
    pub fn mirror_lower_to_upper(&mut self) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                self.data[i * n + j] = self.data[j * n + i];
            }
        }
    }

    /// `self + alpha * I` (square matrices only).
    pub fn add_scaled_identity(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// `self + alpha * diag(d)` (square matrices only).
    pub fn add_scaled_diag(&mut self, alpha: f64, d: &[f64]) {
        assert_eq!(self.rows, self.cols);
        assert_eq!(d.len(), self.rows);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha * d[i];
        }
    }

    /// Element-wise scale.
    pub fn scale(&mut self, alpha: f64) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry difference to another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Extract the sub-matrix with the given rows and columns.
    pub fn submatrix(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        Matrix::from_fn(rows.len(), cols.len(), |i, j| self.get(rows[i], cols[j]))
    }

    /// Check for any non-finite entries.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(6);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn eye_is_identity() {
        let i = Matrix::eye(3);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(i.get(r, c), if r == c { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn transpose_round_trip() {
        let m = Matrix::from_fn(41, 67, |i, j| (i * 67 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.rows(), 67);
        assert_eq!(t.cols(), 41);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(t.get(5, 7), m.get(7, 5));
    }

    #[test]
    fn diag_and_add_identity() {
        let mut m = Matrix::diag(&[1.0, 2.0]);
        m.add_scaled_identity(0.5);
        assert_eq!(m.get(0, 0), 1.5);
        assert_eq!(m.get(1, 1), 2.5);
        assert_eq!(m.get(0, 1), 0.0);
    }

    #[test]
    fn submatrix_extracts() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 10 + j) as f64);
        let s = m.submatrix(&[1, 3], &[0, 2]);
        assert_eq!(s.get(0, 0), 10.0);
        assert_eq!(s.get(1, 1), 32.0);
    }

    #[test]
    fn row_views() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
        assert_eq!(m.diagonal(), vec![1.0, 4.0]);
    }
}
