//! Dense linear-algebra substrate.
//!
//! The offline crate registry ships no BLAS/LAPACK bindings, so everything
//! the paper's algorithms need — blocked GEMM, Cholesky and Householder
//! QR factorizations, triangular solves, SPD solves — is implemented here
//! from scratch in `f64` (the paper's experiments ran in double
//! precision).
//!
//! Performance-critical routines ([`gemm`], [`cholesky`],
//! [`solve_lower_matrix`]) are cache-blocked and register-blocked; see
//! `EXPERIMENTS.md §Perf` for the measured iteration log. GEMM, the
//! symmetric rank-k updates ([`syrk`], [`MatMul::lower`]), the matvecs,
//! the matrix triangular solves **and the blocked Cholesky factorization
//! itself** run data-parallel over fixed output blocks on the shared
//! [`crate::util::pool`] — partitioning is independent of the thread
//! count, so parallel results are bit-identical to the serial path.
//!
//! The register micro-kernels under all of these (4×8 GEMM tiles, dots,
//! axpys, the Gaussian exp row pass) are resolved once at startup by
//! [`dispatch`] — scalar, or AVX2+FMA when the host supports it
//! (`BLESS_ISA` overrides) — so results may vary **by ISA** (accuracy-
//! gated against scalar) but never by thread count. Matrix products are
//! described by the typed [`MatMul`] facade; the historical free
//! functions (`gemm_nt`, `syrk_tn`, …) remain as thin deprecated
//! wrappers over the same engines.

pub mod dispatch;

mod chol;
mod gemm;
mod matmul;
mod matrix;
mod qr;
mod triangular;

pub use chol::{cholesky, cholesky_in_place, cholesky_jittered, cholesky_take, CholeskyFactor};
pub use dispatch::{active_isa, kernels, set_isa, set_isa_from_str, Isa, MicroKernels};
#[allow(deprecated)]
pub use gemm::{
    column_sq_norms, gemm, gemm_into, gemm_nt, gemm_nt_acc, gemm_nt_into, gemm_tn, matvec,
    matvec_into, matvec_t, matvec_t_acc, syrk, syrk_tn, syrk_tn_into, syrk_tn_of_lower,
};
pub use matmul::{MatMul, Transpose, Triangle};
pub use matrix::Matrix;
pub use qr::{qr, QrFactor};
pub use triangular::{
    solve_llt_matrix, solve_lower, solve_lower_matrix, solve_upper, solve_upper_from_lower,
    solve_upper_from_lower_matrix,
};

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: breaks the sequential-add dependency
    // chain, ~3x faster than the naive loop on long vectors.
    let n = a.len();
    let mut acc = [0.0f64; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Scale a vector in place.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 14.0, 16.0]);
    }

    #[test]
    fn norm_of_unit() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn scal_scales() {
        let mut x = vec![1.0, -2.0];
        scal(-3.0, &mut x);
        assert_eq!(x, vec![-3.0, 6.0]);
    }
}
