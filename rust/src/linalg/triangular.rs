//! Triangular solves (forward / back substitution), vector and matrix RHS.
//!
//! [`solve_lower_matrix`] — the single hottest routine of the BLESS path
//! — parallelizes over fixed-width **column blocks** of the right-hand
//! side: columns of `L X = B` are independent, every row operation of the
//! blocked solve is elementwise across columns, and the block boundaries
//! depend only on the shape, so the parallel result is bit-identical to
//! the serial one (see [`crate::util::pool`]).

use super::Matrix;
use crate::util::pool;

/// Forward substitution: solve `L x = b` for lower-triangular `L`.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let ld = l.as_slice();
    let mut x = vec![0.0; n];
    for i in 0..n {
        let row = &ld[i * n..i * n + i];
        let s = super::dot(row, &x[..i]);
        x[i] = (b[i] - s) / ld[i * n + i];
    }
    x
}

/// Back substitution: solve `U x = b` for upper-triangular `U`.
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(b.len(), n);
    let ud = u.as_slice();
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let row = &ud[i * n + i + 1..(i + 1) * n];
        let s = super::dot(row, &x[i + 1..]);
        x[i] = (b[i] - s) / ud[i * n + i];
    }
    x
}

/// Column-block width of the parallel [`solve_lower_matrix`] path.
const CB: usize = 256;
/// Minimum `n²·ncols/2` multiply-adds before the solve dispatches.
const PAR_MIN_SOLVE: usize = 1 << 18;

/// Solve `L X = B` for a matrix right-hand side.
///
/// Wide right-hand sides (the `LsGenerator` batch-scoring shape, `ncols`
/// up to `n`) are split into `CB`-column blocks solved in parallel; each
/// block gathers its columns, runs the serial blocked TRSM on them, and
/// scatters the solution back into its disjoint column range. Since the
/// solve acts elementwise per column, every element sees the identical
/// operation sequence either way — bit-identical output.
pub fn solve_lower_matrix(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let ncols = b.cols();
    let work = n.saturating_mul(n).saturating_mul(ncols) / 2;
    if pool::threads() <= 1 || ncols <= CB || work < PAR_MIN_SOLVE {
        return solve_lower_matrix_serial(l, b);
    }
    let mut x = Matrix::zeros(n, ncols);
    let bd = b.as_slice();
    let nblocks = ncols.div_ceil(CB);
    let base = pool::SendPtr(x.as_mut_slice().as_mut_ptr());
    pool::par_for(nblocks, |blk| {
        let c0 = blk * CB;
        let w = CB.min(ncols - c0);
        // gather this block's columns into a contiguous buffer and solve
        // it in place — one copy in, one copy out
        let mut sub = vec![0.0; n * w];
        for (i, srow) in sub.chunks_mut(w).enumerate() {
            srow.copy_from_slice(&bd[i * ncols + c0..i * ncols + c0 + w]);
        }
        solve_lower_in_place(l, &mut sub, w);
        for i in 0..n {
            // SAFETY: block `blk` owns exactly columns `[c0, c0 + w)` of
            // `x`; ranges are disjoint across blocks and in-bounds, and
            // `x` is not otherwise touched during the dispatch.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    sub.as_ptr().add(i * w),
                    base.0.add(i * ncols + c0),
                    w,
                );
            }
        }
    });
    x
}

/// Serial right-looking blocked TRSM (§Perf): solve a `PB`-row panel in
/// place, then push its contribution into all remaining rows with the
/// same 4×8 register micro-kernel shape as [`super::gemm`] — this is the
/// single hottest routine of the whole BLESS path (`LsGenerator` batch
/// scoring) and runs ~3× faster than the row-by-row formulation.
fn solve_lower_matrix_serial(l: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(b.rows(), l.rows());
    let mut x = b.clone();
    solve_lower_in_place(l, x.as_mut_slice(), b.cols());
    x
}

/// The in-place core of the serial TRSM: `xd` holds the `n × ncols`
/// right-hand side row-major on entry and the solution on exit.
fn solve_lower_in_place(l: &Matrix, xd: &mut [f64], ncols: usize) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(xd.len(), n * ncols);
    let ld = l.as_slice();
    const PB: usize = 64;
    let mut s = 0;
    while s < n {
        let e = (s + PB).min(n);
        // 1. in-panel solve (row-streaming; panel is small and hot)
        for i in s..e {
            let (done, rest) = xd.split_at_mut(i * ncols);
            let xrow = &mut rest[..ncols];
            for p in s..i {
                let lip = ld[i * n + p];
                if lip == 0.0 {
                    continue;
                }
                let xp = &done[p * ncols..(p + 1) * ncols];
                for (xi, xpv) in xrow.iter_mut().zip(xp.iter()) {
                    *xi -= lip * xpv;
                }
            }
            let inv = 1.0 / ld[i * n + i];
            for v in xrow.iter_mut() {
                *v *= inv;
            }
        }
        // 2. trailing update X[e.., :] -= L[e.., s..e] · X[s..e, :]
        //    (gemm-shaped; 4-row blocks reuse each solved panel row)
        let (solved, rest) = xd.split_at_mut(e * ncols);
        let panel = &solved[s * ncols..];
        let mut i = e;
        while i < n {
            let rows = (n - i).min(4);
            let base = (i - e) * ncols;
            for p in s..e {
                let xp = &panel[(p - s) * ncols..(p - s + 1) * ncols];
                for r in 0..rows {
                    let lip = ld[(i + r) * n + p];
                    if lip == 0.0 {
                        continue;
                    }
                    let xrow = &mut rest[base + r * ncols..base + (r + 1) * ncols];
                    for (xi, xpv) in xrow.iter_mut().zip(xp.iter()) {
                        *xi -= lip * xpv;
                    }
                }
            }
            i += rows;
        }
        s = e;
    }
}

/// Solve `Lᵀ X = B` against a stored *lower* factor, matrix RHS.
pub fn solve_upper_matrix(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let ncols = b.cols();
    let mut x = b.clone();
    let ld = l.as_slice();
    let xd = x.as_mut_slice();
    for i in (0..n).rev() {
        let inv = 1.0 / ld[i * n + i];
        // finish row i
        {
            let xrow = &mut xd[i * ncols..(i + 1) * ncols];
            for v in xrow.iter_mut() {
                *v *= inv;
            }
        }
        // propagate to rows j < i : X[j,:] -= L[i,j] * X[i,:]
        let (head, tail) = xd.split_at_mut(i * ncols);
        let xrow = &tail[..ncols];
        for j in 0..i {
            let lij = ld[i * n + j];
            if lij == 0.0 {
                continue;
            }
            let xj = &mut head[j * ncols..(j + 1) * ncols];
            for (xv, xr) in xj.iter_mut().zip(xrow.iter()) {
                *xv -= lij * xr;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, matvec};

    fn lower(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if j > i {
                0.0
            } else if i == j {
                2.0 + (i % 3) as f64
            } else {
                ((i * 5 + j * 3) % 7) as f64 * 0.2 - 0.5
            }
        })
    }

    #[test]
    fn solve_lower_residual() {
        let n = 37;
        let l = lower(n);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 1.0).collect();
        let x = solve_lower(&l, &b);
        let lx = matvec(&l, &x);
        for (u, v) in lx.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_upper_residual() {
        let n = 23;
        let u = lower(n).transpose();
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
        let x = solve_upper(&u, &b);
        let ux = matvec(&u, &x);
        for (a, c) in ux.iter().zip(&b) {
            assert!((a - c).abs() < 1e-9);
        }
    }

    #[test]
    fn matrix_solves_match_columnwise() {
        let n = 19;
        let l = lower(n);
        let b = Matrix::from_fn(n, 6, |i, j| ((i + 1) * (j + 2)) as f64 % 5.0 - 2.0);
        let x = solve_lower_matrix(&l, &b);
        for j in 0..6 {
            let xj = solve_lower(&l, &b.col(j));
            for i in 0..n {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-10);
            }
        }
        // upper (Lᵀ) version
        let xu = solve_upper_matrix(&l, &b);
        let lt = l.transpose();
        for j in 0..6 {
            let xj = solve_upper(&lt, &b.col(j));
            for i in 0..n {
                assert!((xu.get(i, j) - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn wide_rhs_takes_block_path_and_matches_columnwise() {
        // ncols > CB and enough work to dispatch: exercises the parallel
        // column-block path (inline on a 1-core runner)
        let n = 48;
        let l = lower(n);
        let ncols = 2 * super::CB + 37;
        let b = Matrix::from_fn(n, ncols, |i, j| ((i * 31 + j * 7) % 11) as f64 * 0.3 - 1.0);
        let x = solve_lower_matrix(&l, &b);
        for j in [0usize, super::CB - 1, super::CB, ncols - 1] {
            let xj = solve_lower(&l, &b.col(j));
            for i in 0..n {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-9, "col {j} row {i}");
            }
        }
    }

    #[test]
    fn round_trip_llt() {
        // L (Lᵀ X) = B  solved in two stages equals (L Lᵀ)⁻¹ B
        let n = 15;
        let l = lower(n);
        let a = gemm(&l, &l.transpose());
        let b = Matrix::from_fn(n, 3, |i, j| (i + j) as f64);
        let y = solve_lower_matrix(&l, &b);
        let x = solve_upper_matrix(&l, &y);
        let ax = gemm(&a, &x);
        assert!(ax.max_abs_diff(&b) < 1e-8);
    }
}
