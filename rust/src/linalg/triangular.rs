//! Triangular solves (forward / back substitution), vector and matrix RHS.
//!
//! The matrix solves — [`solve_lower_matrix`] (`L X = B`, the single
//! hottest routine of the BLESS path), [`solve_upper_from_lower_matrix`]
//! (`Lᵀ X = B` read off the stored *lower* factor, no transpose ever
//! materialized) and the fused [`solve_llt_matrix`] (`L Lᵀ X = B`) — all
//! run through one parallel driver: fixed-width **column blocks** of the
//! right-hand side are gathered contiguously, solved in place with the
//! serial blocked kernels, and scattered back. Columns are independent
//! and every row operation is elementwise across them, and the block
//! boundaries depend only on the shape, so the parallel result is
//! bit-identical to the serial one (see [`crate::util::pool`]).

use super::Matrix;
use crate::util::pool;

/// Forward substitution: solve `L x = b` for lower-triangular `L`.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let kern = super::dispatch::kernels();
    let ld = l.as_slice();
    let mut x = vec![0.0; n];
    for i in 0..n {
        let row = &ld[i * n..i * n + i];
        let s = (kern.dot)(row, &x[..i]);
        x[i] = (b[i] - s) / ld[i * n + i];
    }
    x
}

/// Back substitution: solve `U x = b` for upper-triangular `U`.
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(b.len(), n);
    let kern = super::dispatch::kernels();
    let ud = u.as_slice();
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let row = &ud[i * n + i + 1..(i + 1) * n];
        let s = (kern.dot)(row, &x[i + 1..]);
        x[i] = (b[i] - s) / ud[i * n + i];
    }
    x
}

/// Back substitution `Lᵀ x = b` reading the *lower* factor row-wise —
/// no `n × n` transpose is ever built.
pub fn solve_upper_from_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let kern = super::dispatch::kernels();
    let mut x = b.to_vec();
    let ld = l.as_slice();
    for i in (0..n).rev() {
        let xi = x[i] / ld[i * n + i];
        x[i] = xi;
        // propagate: x[j] -= L[i][j] * xi for j < i  (column i of Lᵀ)
        let row = &ld[i * n..i * n + i];
        (kern.axpy)(-xi, row, &mut x[..i]);
    }
    x
}

/// Column-block width of the parallel matrix-solve paths.
const CB: usize = 256;
/// Minimum multiply-adds before a matrix solve dispatches to the pool.
const PAR_MIN_SOLVE: usize = 1 << 18;

/// Shared driver for the matrix triangular solves: the right-hand side
/// is split into fixed `CB`-column blocks; each block is gathered into a
/// contiguous buffer, solved in place by `core`, and scattered into its
/// disjoint column range of the output. When `parallel` is false (below
/// a call site's work threshold, or the RHS fits in one block) `core`
/// runs once over the whole RHS inline — the solves act elementwise per
/// column, so both paths produce identical bits.
fn par_solve_columns(
    b: &Matrix,
    parallel: bool,
    core: impl Fn(&mut [f64], usize) + Sync,
) -> Matrix {
    let (n, ncols) = (b.rows(), b.cols());
    if !parallel || ncols <= CB || pool::threads() <= 1 {
        let mut x = b.clone();
        core(x.as_mut_slice(), ncols);
        return x;
    }
    let mut x = Matrix::zeros(n, ncols);
    let bd = b.as_slice();
    let nblocks = ncols.div_ceil(CB);
    let base = pool::SendPtr(x.as_mut_slice().as_mut_ptr());
    pool::par_for(nblocks, |blk| {
        let c0 = blk * CB;
        let w = CB.min(ncols - c0);
        // gather this block's columns into a contiguous buffer and solve
        // it in place — one copy in, one copy out
        let mut sub = vec![0.0; n * w];
        for (i, srow) in sub.chunks_mut(w).enumerate() {
            srow.copy_from_slice(&bd[i * ncols + c0..i * ncols + c0 + w]);
        }
        core(&mut sub, w);
        for i in 0..n {
            // SAFETY: block `blk` owns exactly columns `[c0, c0 + w)` of
            // `x`; ranges are disjoint across blocks and in-bounds, and
            // `x` is not otherwise touched during the dispatch.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    sub.as_ptr().add(i * w),
                    base.0.add(i * ncols + c0),
                    w,
                );
            }
        }
    });
    x
}

/// Solve `L X = B` for a matrix right-hand side.
///
/// Wide right-hand sides (the `LsGenerator` batch-scoring shape, `ncols`
/// up to `n`) are split into `CB`-column blocks solved in parallel on
/// the shared pool; each block runs the serial blocked TRSM.
pub fn solve_lower_matrix(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let work = n.saturating_mul(n).saturating_mul(b.cols()) / 2;
    par_solve_columns(b, work >= PAR_MIN_SOLVE, |xd, w| solve_lower_in_place(l, xd, w))
}

/// Solve `Lᵀ X = B` against a stored *lower* factor, matrix RHS — the
/// blocked back-substitution mirror of [`solve_lower_matrix`], same
/// parallel column-block driver, no transpose materialized.
pub fn solve_upper_from_lower_matrix(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let work = n.saturating_mul(n).saturating_mul(b.cols()) / 2;
    par_solve_columns(b, work >= PAR_MIN_SOLVE, |xd, w| {
        solve_upper_from_lower_in_place(l, xd, w)
    })
}

/// Fused SPD solve `(L Lᵀ) X = B`: forward then back substitution per
/// column block on one gathered buffer, so each block pays the
/// gather/scatter copies once for both sweeps.
pub fn solve_llt_matrix(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let work = n.saturating_mul(n).saturating_mul(b.cols());
    par_solve_columns(b, work >= PAR_MIN_SOLVE, |xd, w| {
        solve_lower_in_place(l, xd, w);
        solve_upper_from_lower_in_place(l, xd, w);
    })
}

/// The in-place core of the serial TRSM (§Perf): `xd` holds the
/// `n × ncols` right-hand side row-major on entry and the solution of
/// `L X = B` on exit. Solve a `PB`-row panel in place, then push its
/// contribution into all remaining rows with the same 4-row blocked
/// shape as [`super::gemm`] — ~3× faster than the row-by-row
/// formulation.
fn solve_lower_in_place(l: &Matrix, xd: &mut [f64], ncols: usize) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(xd.len(), n * ncols);
    let kern = super::dispatch::kernels();
    let ld = l.as_slice();
    const PB: usize = 64;
    let mut s = 0;
    while s < n {
        let e = (s + PB).min(n);
        // 1. in-panel solve (row-streaming; panel is small and hot)
        for i in s..e {
            let (done, rest) = xd.split_at_mut(i * ncols);
            let xrow = &mut rest[..ncols];
            for p in s..i {
                let lip = ld[i * n + p];
                if lip == 0.0 {
                    continue;
                }
                let xp = &done[p * ncols..(p + 1) * ncols];
                (kern.axpy)(-lip, xp, xrow);
            }
            let inv = 1.0 / ld[i * n + i];
            for v in xrow.iter_mut() {
                *v *= inv;
            }
        }
        // 2. trailing update X[e.., :] -= L[e.., s..e] · X[s..e, :]
        //    (gemm-shaped; 4-row blocks reuse each solved panel row)
        let (solved, rest) = xd.split_at_mut(e * ncols);
        let panel = &solved[s * ncols..];
        let mut i = e;
        while i < n {
            let rows = (n - i).min(4);
            let base = (i - e) * ncols;
            for p in s..e {
                let xp = &panel[(p - s) * ncols..(p - s + 1) * ncols];
                for r in 0..rows {
                    let lip = ld[(i + r) * n + p];
                    if lip == 0.0 {
                        continue;
                    }
                    let xrow = &mut rest[base + r * ncols..base + (r + 1) * ncols];
                    (kern.axpy)(-lip, xp, xrow);
                }
            }
            i += rows;
        }
        s = e;
    }
}

/// The in-place core of the blocked back substitution: `xd` holds the
/// `n × ncols` right-hand side on entry and the solution of `Lᵀ X = B`
/// (reading the *lower* factor) on exit — the bottom-up mirror of
/// [`solve_lower_in_place`]: solve a `PB`-row panel, then push its
/// contribution up into all rows above it.
fn solve_upper_from_lower_in_place(l: &Matrix, xd: &mut [f64], ncols: usize) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(xd.len(), n * ncols);
    let kern = super::dispatch::kernels();
    let ld = l.as_slice();
    const PB: usize = 64;
    let mut e = n;
    while e > 0 {
        let s = e.saturating_sub(PB);
        // 1. in-panel back substitution, rows e-1 down to s: row i picks
        //    up −L[p,i]·X[p,:] from the already-solved rows p > i of the
        //    panel (L[p,i] is column i of Lᵀ read along row p of L).
        for i in (s..e).rev() {
            let (low, high) = xd.split_at_mut((i + 1) * ncols);
            let xrow = &mut low[i * ncols..];
            for p in (i + 1)..e {
                let lpi = ld[p * n + i];
                if lpi == 0.0 {
                    continue;
                }
                let xp = &high[(p - i - 1) * ncols..(p - i) * ncols];
                (kern.axpy)(-lpi, xp, xrow);
            }
            let inv = 1.0 / ld[i * n + i];
            for v in xrow.iter_mut() {
                *v *= inv;
            }
        }
        // 2. propagate the solved panel upward:
        //    X[j, :] -= Σ_{p ∈ [s,e)} L[p, j] · X[p, :]  for j < s
        //    (4-row target blocks reuse each solved panel row)
        if s > 0 {
            let (head, rest) = xd.split_at_mut(s * ncols);
            let panel = &rest[..(e - s) * ncols];
            let mut j = 0;
            while j < s {
                let rows = (s - j).min(4);
                for p in s..e {
                    let xp = &panel[(p - s) * ncols..(p - s + 1) * ncols];
                    for r in 0..rows {
                        let lpj = ld[p * n + j + r];
                        if lpj == 0.0 {
                            continue;
                        }
                        let xrow = &mut head[(j + r) * ncols..(j + r + 1) * ncols];
                        (kern.axpy)(-lpj, xp, xrow);
                    }
                }
                j += rows;
            }
        }
        e = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, matvec};

    fn lower(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if j > i {
                0.0
            } else if i == j {
                2.0 + (i % 3) as f64
            } else {
                ((i * 5 + j * 3) % 7) as f64 * 0.2 - 0.5
            }
        })
    }

    #[test]
    fn solve_lower_residual() {
        let n = 37;
        let l = lower(n);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 1.0).collect();
        let x = solve_lower(&l, &b);
        let lx = matvec(&l, &x);
        for (u, v) in lx.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_upper_residual() {
        let n = 23;
        let u = lower(n).transpose();
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
        let x = solve_upper(&u, &b);
        let ux = matvec(&u, &x);
        for (a, c) in ux.iter().zip(&b) {
            assert!((a - c).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_upper_from_lower_matches_explicit_transpose() {
        let n = 29;
        let l = lower(n);
        let b: Vec<f64> = (0..n).map(|i| ((i * 3) % 7) as f64 - 2.5).collect();
        let x1 = solve_upper_from_lower(&l, &b);
        let x2 = solve_upper(&l.transpose(), &b);
        for (u, v) in x1.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn matrix_solves_match_columnwise() {
        let n = 19;
        let l = lower(n);
        let b = Matrix::from_fn(n, 6, |i, j| ((i + 1) * (j + 2)) as f64 % 5.0 - 2.0);
        let x = solve_lower_matrix(&l, &b);
        for j in 0..6 {
            let xj = solve_lower(&l, &b.col(j));
            for i in 0..n {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-10);
            }
        }
        // upper (Lᵀ) version
        let xu = solve_upper_from_lower_matrix(&l, &b);
        let lt = l.transpose();
        for j in 0..6 {
            let xj = solve_upper(&lt, &b.col(j));
            for i in 0..n {
                assert!((xu.get(i, j) - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn upper_from_lower_matrix_straddles_panel_boundaries() {
        // sizes around the PB=64 back-substitution panel boundary
        for &n in &[63usize, 64, 65, 130] {
            let l = lower(n);
            let b = Matrix::from_fn(n, 5, |i, j| ((i * 5 + j) % 11) as f64 * 0.4 - 2.0);
            let x = solve_upper_from_lower_matrix(&l, &b);
            let lt = l.transpose();
            for j in 0..5 {
                let xj = solve_upper(&lt, &b.col(j));
                for i in 0..n {
                    assert!((x.get(i, j) - xj[i]).abs() < 1e-9, "n={n} col {j} row {i}");
                }
            }
        }
    }

    #[test]
    fn wide_rhs_takes_block_path_and_matches_columnwise() {
        // ncols > CB and enough work to dispatch: exercises the parallel
        // column-block path (inline on a 1-core runner)
        let n = 48;
        let l = lower(n);
        let ncols = 2 * super::CB + 37;
        let b = Matrix::from_fn(n, ncols, |i, j| ((i * 31 + j * 7) % 11) as f64 * 0.3 - 1.0);
        let x = solve_lower_matrix(&l, &b);
        for j in [0usize, super::CB - 1, super::CB, ncols - 1] {
            let xj = solve_lower(&l, &b.col(j));
            for i in 0..n {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-9, "col {j} row {i}");
            }
        }
        // and the back-substitution twin on the same wide RHS
        let xu = solve_upper_from_lower_matrix(&l, &b);
        let lt = l.transpose();
        for j in [0usize, super::CB, ncols - 1] {
            let xj = solve_upper(&lt, &b.col(j));
            for i in 0..n {
                assert!((xu.get(i, j) - xj[i]).abs() < 1e-9, "upper col {j} row {i}");
            }
        }
    }

    #[test]
    fn round_trip_llt() {
        // L (Lᵀ X) = B  solved in two stages equals (L Lᵀ)⁻¹ B
        let n = 15;
        let l = lower(n);
        let a = gemm(&l, &l.transpose());
        let b = Matrix::from_fn(n, 3, |i, j| (i + j) as f64);
        let y = solve_lower_matrix(&l, &b);
        let x = solve_upper_from_lower_matrix(&l, &y);
        let ax = gemm(&a, &x);
        assert!(ax.max_abs_diff(&b) < 1e-8);
        // the fused solve produces the same bits as the two-stage path
        let fused = solve_llt_matrix(&l, &b);
        for (u, v) in fused.as_slice().iter().zip(x.as_slice()) {
            assert_eq!(u.to_bits(), v.to_bits(), "fused vs two-stage");
        }
    }
}
