//! Runtime ISA dispatch for the dense micro-kernels.
//!
//! Every hot inner loop of the linalg tier — the 4×8 GEMM register
//! tiles, the dot-product (NT) tiles shared by `gemm_nt`/`syrk`/the
//! Cholesky Schur update, the axpy-shaped TRSM and rank-1 updates, and
//! the Gaussian-kernel exp pass — funnels through one [`MicroKernels`]
//! fn-pointer vtable. Two implementations exist:
//!
//! * **scalar** — portable Rust, byte-for-byte the loops the crate
//!   shipped before this tier. Always available; the reference for the
//!   accuracy gates in `tests/isa_dispatch.rs`.
//! * **avx2** — explicit `std::arch` AVX2+FMA intrinsics
//!   (`x86_64` only), selected at first use when
//!   `is_x86_feature_detected!("avx2")` and `("fma")` both hold.
//!
//! Selection happens once, lazily, and can be forced with the
//! `BLESS_ISA` environment variable (`scalar`, `avx2`, or `auto`) or the
//! `repro --isa` CLI flag ([`set_isa`]). Tests flip backends in-process
//! through [`set_isa`] as well.
//!
//! ## Determinism contract
//!
//! Output may vary **by ISA** (the AVX2 kernels use FMA and different
//! reduction orders; they are accuracy-gated against scalar), but never
//! **by thread count**: each vtable function is a pure function of its
//! inputs, and the callers partition work into fixed-size blocks whose
//! boundaries depend only on the problem shape. `tests/
//! parallel_determinism.rs` asserts bit-identical results at 1/2/4/8
//! threads under both backends.
//!
//! The vectorized exp ([`MicroKernels::exp_row`] on the AVX2 path)
//! carries a documented **≤ 4 ULP** bound against `f64::exp` over the
//! kernel-relevant range `[-708, 0]` (see the `avx2` module source for
//! the error budget); inputs below −708 flush to `0.0` where `f64::exp`
//! would return a subnormal.

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set backend of the active [`MicroKernels`] vtable.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Portable Rust loops (always available).
    Scalar,
    /// AVX2 + FMA `std::arch` intrinsics (`x86_64` with runtime support).
    Avx2,
}

impl Isa {
    /// Lower-case name as used by `BLESS_ISA` / `--isa`.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
        }
    }
}

/// The micro-kernel vtable: one fn pointer per hot inner-loop shape.
///
/// All functions are **safe** trampolines — the AVX2 entries are only
/// ever installed after runtime feature detection, so the `unsafe`
/// `target_feature` internals are sound to call.
#[derive(Clone, Copy)]
pub struct MicroKernels {
    /// Which backend this table is.
    pub isa: Isa,
    /// 4×8 NN register tile: `acc[r][c] += Σ_p a[r][p] · bd[p·bstride + j + c]`
    /// for `p ∈ [0, a[0].len())`, `c ∈ [0, 8)`. `bd` must hold at least
    /// `a[0].len()` rows of stride `bstride` with `j + 8 ≤ bstride`.
    /// `acc` is the caller's register tile (accumulated across `KC`
    /// panels by the caller).
    pub nn_4x8: fn(a: [&[f64]; 4], bd: &[f64], bstride: usize, j: usize, acc: &mut [[f64; 8]; 4]),
    /// 4×8 NT (dot-product) register tile:
    /// `acc[r][c] += Σ_p a[r][p] · b[c][p]` — the shared engine of
    /// `gemm_nt`, `syrk` and the Cholesky Schur update (the caller
    /// applies the `±` sign when folding `acc` into `C`).
    pub nt_4x8: fn(a: [&[f64]; 4], b: [&[f64]; 8], acc: &mut [[f64; 8]; 4]),
    /// Dot product of two equal-length slices (ragged tile edges,
    /// remainder rows, matvecs, the triangular vector solves and the
    /// unblocked Cholesky diagonal).
    pub dot: fn(a: &[f64], b: &[f64]) -> f64,
    /// `y += alpha · x` (rank-1 GEMM-TN updates, TRSM row updates,
    /// streaming `Kᵀu` accumulation).
    pub axpy: fn(alpha: f64, x: &[f64], y: &mut [f64]),
    /// Gaussian-kernel exp pass over one row of a cross-term block:
    /// `row[j] ← exp(−gamma · max(ai + b_sq[j] − 2·row[j], 0))`.
    pub exp_row: fn(gamma: f64, ai: f64, b_sq: &[f64], row: &mut [f64]),
}

/// Portable scalar implementations — bitwise the pre-dispatch loops.
mod scalar {
    /// NN tile: identical loop order to the original `gemm_row_block`.
    pub fn nn_4x8(a: [&[f64]; 4], bd: &[f64], bstride: usize, j: usize, acc: &mut [[f64; 8]; 4]) {
        let pl = a[0].len();
        for p in 0..pl {
            let b8 = &bd[p * bstride + j..p * bstride + j + 8];
            let w = [a[0][p], a[1][p], a[2][p], a[3][p]];
            for (rr, acc_r) in acc.iter_mut().enumerate() {
                let wr = w[rr];
                for (c, bv) in acc_r.iter_mut().zip(b8.iter()) {
                    *c += wr * bv;
                }
            }
        }
    }

    /// NT tile: identical loop order to the original `gemm_nt_row_block`
    /// / `syrk_ln_panel` full tile.
    pub fn nt_4x8(a: [&[f64]; 4], b: [&[f64]; 8], acc: &mut [[f64; 8]; 4]) {
        let pl = a[0].len();
        for p in 0..pl {
            for (acc_r, ar) in acc.iter_mut().zip(a.iter()) {
                let av = ar[p];
                for (cv, br) in acc_r.iter_mut().zip(b.iter()) {
                    *cv += av * br[p];
                }
            }
        }
    }

    /// 4-way-unrolled dot (the crate-wide [`crate::linalg::dot`]).
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        crate::linalg::dot(a, b)
    }

    /// Plain fused loop (the crate-wide [`crate::linalg::axpy`]).
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        crate::linalg::axpy(alpha, x, y);
    }

    /// Reference exp pass through `f64::exp` (glibc, ~0.5 ULP).
    pub fn exp_row(gamma: f64, ai: f64, b_sq: &[f64], row: &mut [f64]) {
        for (v, &bj) in row.iter_mut().zip(b_sq.iter()) {
            let d2 = (ai + bj - 2.0 * *v).max(0.0);
            *v = (-gamma * d2).exp();
        }
    }
}

/// AVX2 + FMA implementations (`x86_64` only).
///
/// Safety pattern: each public entry is a safe `fn` that immediately
/// calls an `#[target_feature(enable = "avx2", enable = "fma")]` inner
/// function. The entries are only installed into the active vtable
/// after `is_x86_feature_detected!` confirms both features, so the
/// `unsafe` calls are sound.
///
/// ## `vexp` error budget (≤ 4 ULP over `[-708, 0]`)
///
/// `exp(x) = 2^k · e^z` with `k = ⌊x·log₂e + ½⌋` and
/// `z = (x − k·LN2_HI) − k·LN2_LO`, `|z| ≤ 0.3466`:
///
/// * `k·LN2_HI` is exact (`|k| ≤ 1022` is 11 bits, `LN2_HI` carries a
///   32-bit mantissa; the product fits in 53 bits), so the reduced
///   argument carries only the one rounding of the `LN2_LO` term plus
///   the `~1e-24` tail of the two-term constant: `< 0.1 ULP` on `e^z`.
/// * degree-13 Taylor for `e^z`: truncation `z¹⁴/14! ≤ 4.2e-18`
///   (`< 0.03` ULP of `e^z ≥ 0.707`); the FMA Horner chain accumulates
///   `< 1.5` ULP.
/// * the `2^k` scale is a power of two (exact); the final product
///   rounds once (`≤ 0.5` ULP).
///
/// Total `< 2.5` ULP worst case; the property test in
/// `tests/isa_dispatch.rs` gates a dense sweep at 4 ULP. Inputs below
/// `−708` return `0.0` (the scalar path's subnormal tail is below every
/// kernel tolerance in the crate).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    pub fn nn_4x8(a: [&[f64]; 4], bd: &[f64], bstride: usize, j: usize, acc: &mut [[f64; 8]; 4]) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { nn_4x8_inner(a, bd, bstride, j, acc) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn nn_4x8_inner(
        a: [&[f64]; 4],
        bd: &[f64],
        bstride: usize,
        j: usize,
        acc: &mut [[f64; 8]; 4],
    ) {
        let pl = a[0].len();
        debug_assert!(pl == 0 || (pl - 1) * bstride + j + 8 <= bd.len());
        let mut c00 = _mm256_loadu_pd(acc[0].as_ptr());
        let mut c01 = _mm256_loadu_pd(acc[0].as_ptr().add(4));
        let mut c10 = _mm256_loadu_pd(acc[1].as_ptr());
        let mut c11 = _mm256_loadu_pd(acc[1].as_ptr().add(4));
        let mut c20 = _mm256_loadu_pd(acc[2].as_ptr());
        let mut c21 = _mm256_loadu_pd(acc[2].as_ptr().add(4));
        let mut c30 = _mm256_loadu_pd(acc[3].as_ptr());
        let mut c31 = _mm256_loadu_pd(acc[3].as_ptr().add(4));
        let bp = bd.as_ptr();
        for p in 0..pl {
            let brow = bp.add(p * bstride + j);
            let b0 = _mm256_loadu_pd(brow);
            let b1 = _mm256_loadu_pd(brow.add(4));
            let a0 = _mm256_set1_pd(*a[0].get_unchecked(p));
            c00 = _mm256_fmadd_pd(a0, b0, c00);
            c01 = _mm256_fmadd_pd(a0, b1, c01);
            let a1 = _mm256_set1_pd(*a[1].get_unchecked(p));
            c10 = _mm256_fmadd_pd(a1, b0, c10);
            c11 = _mm256_fmadd_pd(a1, b1, c11);
            let a2 = _mm256_set1_pd(*a[2].get_unchecked(p));
            c20 = _mm256_fmadd_pd(a2, b0, c20);
            c21 = _mm256_fmadd_pd(a2, b1, c21);
            let a3 = _mm256_set1_pd(*a[3].get_unchecked(p));
            c30 = _mm256_fmadd_pd(a3, b0, c30);
            c31 = _mm256_fmadd_pd(a3, b1, c31);
        }
        _mm256_storeu_pd(acc[0].as_mut_ptr(), c00);
        _mm256_storeu_pd(acc[0].as_mut_ptr().add(4), c01);
        _mm256_storeu_pd(acc[1].as_mut_ptr(), c10);
        _mm256_storeu_pd(acc[1].as_mut_ptr().add(4), c11);
        _mm256_storeu_pd(acc[2].as_mut_ptr(), c20);
        _mm256_storeu_pd(acc[2].as_mut_ptr().add(4), c21);
        _mm256_storeu_pd(acc[3].as_mut_ptr(), c30);
        _mm256_storeu_pd(acc[3].as_mut_ptr().add(4), c31);
    }

    pub fn nt_4x8(a: [&[f64]; 4], b: [&[f64]; 8], acc: &mut [[f64; 8]; 4]) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { nt_4x8_inner(a, b, acc) }
    }

    /// Dot-product tile, two B columns at a time: 8 vector accumulators
    /// (4 A rows × 2 B rows), 6 loads per 8 FMAs, lanes reduced with a
    /// deterministic `(l0+l2)+(l1+l3)` tree plus an ordered scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn nt_4x8_inner(a: [&[f64]; 4], b: [&[f64]; 8], acc: &mut [[f64; 8]; 4]) {
        let pl = a[0].len();
        let chunks = pl / 4;
        let mut c = 0;
        while c < 8 {
            let b0 = b[c].as_ptr();
            let b1 = b[c + 1].as_ptr();
            let mut acc00 = _mm256_setzero_pd();
            let mut acc01 = _mm256_setzero_pd();
            let mut acc10 = _mm256_setzero_pd();
            let mut acc11 = _mm256_setzero_pd();
            let mut acc20 = _mm256_setzero_pd();
            let mut acc21 = _mm256_setzero_pd();
            let mut acc30 = _mm256_setzero_pd();
            let mut acc31 = _mm256_setzero_pd();
            for i in 0..chunks {
                let p = i * 4;
                let vb0 = _mm256_loadu_pd(b0.add(p));
                let vb1 = _mm256_loadu_pd(b1.add(p));
                let va0 = _mm256_loadu_pd(a[0].as_ptr().add(p));
                acc00 = _mm256_fmadd_pd(va0, vb0, acc00);
                acc01 = _mm256_fmadd_pd(va0, vb1, acc01);
                let va1 = _mm256_loadu_pd(a[1].as_ptr().add(p));
                acc10 = _mm256_fmadd_pd(va1, vb0, acc10);
                acc11 = _mm256_fmadd_pd(va1, vb1, acc11);
                let va2 = _mm256_loadu_pd(a[2].as_ptr().add(p));
                acc20 = _mm256_fmadd_pd(va2, vb0, acc20);
                acc21 = _mm256_fmadd_pd(va2, vb1, acc21);
                let va3 = _mm256_loadu_pd(a[3].as_ptr().add(p));
                acc30 = _mm256_fmadd_pd(va3, vb0, acc30);
                acc31 = _mm256_fmadd_pd(va3, vb1, acc31);
            }
            let sums0 = [hsum(acc00), hsum(acc10), hsum(acc20), hsum(acc30)];
            let sums1 = [hsum(acc01), hsum(acc11), hsum(acc21), hsum(acc31)];
            for r in 0..4 {
                let mut s0 = sums0[r];
                let mut s1 = sums1[r];
                for p in chunks * 4..pl {
                    let av = *a[r].get_unchecked(p);
                    s0 += av * *b[c].get_unchecked(p);
                    s1 += av * *b[c + 1].get_unchecked(p);
                }
                acc[r][c] += s0;
                acc[r][c + 1] += s1;
            }
            c += 2;
        }
    }

    /// Deterministic lane reduction: `(l0 + l2) + (l1 + l3)`.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let hi = _mm256_extractf128_pd::<1>(v);
        let lo = _mm256_castpd256_pd128(v);
        let s = _mm_add_pd(lo, hi); // [l0+l2, l1+l3]
        let sh = _mm_unpackhi_pd(s, s);
        _mm_cvtsd_f64(_mm_add_sd(s, sh))
    }

    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { dot_inner(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_inner(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for i in 0..chunks {
            let p = i * 8;
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(ap.add(p)), _mm256_loadu_pd(bp.add(p)), acc0);
            acc1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(ap.add(p + 4)),
                _mm256_loadu_pd(bp.add(p + 4)),
                acc1,
            );
        }
        let mut s = hsum(_mm256_add_pd(acc0, acc1));
        for p in chunks * 8..n {
            s += *a.get_unchecked(p) * *b.get_unchecked(p);
        }
        s
    }

    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { axpy_inner(alpha, x, y) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn axpy_inner(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4;
        let va = _mm256_set1_pd(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        for i in 0..chunks {
            let p = i * 4;
            let vy = _mm256_loadu_pd(yp.add(p));
            let vx = _mm256_loadu_pd(xp.add(p));
            _mm256_storeu_pd(yp.add(p), _mm256_fmadd_pd(va, vx, vy));
        }
        for p in chunks * 4..n {
            *y.get_unchecked_mut(p) += alpha * *x.get_unchecked(p);
        }
    }

    pub fn exp_row(gamma: f64, ai: f64, b_sq: &[f64], row: &mut [f64]) {
        // SAFETY: installed only after AVX2+FMA runtime detection.
        unsafe { exp_row_inner(gamma, ai, b_sq, row) }
    }

    /// Cody–Waite two-term range reduction (fdlibm constants).
    const LN2_HI: f64 = 0.693_147_180_369_123_8;
    const LN2_LO: f64 = 1.908_214_929_270_587_7e-10;

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn exp_row_inner(gamma: f64, ai: f64, b_sq: &[f64], row: &mut [f64]) {
        let n = row.len();
        debug_assert_eq!(b_sq.len(), n);
        let chunks = n / 4;
        let vg = _mm256_set1_pd(-gamma);
        let vai = _mm256_set1_pd(ai);
        let vtwo = _mm256_set1_pd(2.0);
        let vzero = _mm256_setzero_pd();
        let bp = b_sq.as_ptr();
        let rp = row.as_mut_ptr();
        for i in 0..chunks {
            let p = i * 4;
            let v = _mm256_loadu_pd(rp.add(p));
            let bj = _mm256_loadu_pd(bp.add(p));
            // d2 = max(ai + bj − 2v, 0); x = −gamma·d2 ≤ 0
            let d2 = _mm256_fnmadd_pd(vtwo, v, _mm256_add_pd(vai, bj));
            let d2 = _mm256_max_pd(d2, vzero);
            let x = _mm256_mul_pd(vg, d2);
            _mm256_storeu_pd(rp.add(p), vexp_nonpos(x));
        }
        for p in chunks * 4..n {
            let v = *row.get_unchecked(p);
            let d2 = (ai + *b_sq.get_unchecked(p) - 2.0 * v).max(0.0);
            *row.get_unchecked_mut(p) = exp_nonpos_scalar(-gamma * d2);
        }
    }

    /// Vectorized `exp(x)` for `x ≤ 0` — see the module docs for the
    /// ≤ 4 ULP budget. Lanes below −708 flush to `0.0`.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn vexp_nonpos(x: __m256d) -> __m256d {
        let log2e = _mm256_set1_pd(std::f64::consts::LOG2_E);
        let half = _mm256_set1_pd(0.5);
        // k = floor(x·log2e + 1/2): round-to-nearest for non-positive x
        let k = _mm256_floor_pd(_mm256_fmadd_pd(x, log2e, half));
        // z = (x − k·LN2_HI) − k·LN2_LO ∈ [−0.3466, 0.3466]
        let z = _mm256_fnmadd_pd(k, _mm256_set1_pd(LN2_HI), x);
        let z = _mm256_fnmadd_pd(k, _mm256_set1_pd(LN2_LO), z);
        // e^z by degree-13 Taylor, Horner with FMA
        let mut p = _mm256_set1_pd(1.0 / 6_227_020_800.0); // 1/13!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 479_001_600.0)); // 1/12!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 39_916_800.0)); // 1/11!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 3_628_800.0)); // 1/10!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 362_880.0)); // 1/9!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 40_320.0)); // 1/8!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 5_040.0)); // 1/7!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 720.0)); // 1/6!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 120.0)); // 1/5!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 24.0)); // 1/4!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0 / 6.0)); // 1/3!
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(0.5));
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0));
        p = _mm256_fmadd_pd(p, z, _mm256_set1_pd(1.0));
        // 2^k via exponent-bit assembly: k ∈ [−1022, 1) after the
        // underflow mask below, so (k + 1023) << 52 never wraps.
        let kf = _mm256_max_pd(k, _mm256_set1_pd(-1022.0));
        let k32 = _mm256_cvtpd_epi32(kf); // exact: kf is integral
        let k64 = _mm256_cvtepi32_epi64(k32);
        let bits = _mm256_slli_epi64::<52>(_mm256_add_epi64(k64, _mm256_set1_epi64x(1023)));
        let scale = _mm256_castsi256_pd(bits);
        let r = _mm256_mul_pd(p, scale);
        // flush x < −708 to zero (f64::exp is subnormal there)
        let underflow = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(-708.0));
        _mm256_andnot_pd(underflow, r)
    }

    /// Scalar twin of [`vexp_nonpos`] for the `n % 4` tail — the same
    /// operation sequence (FMA via `mul_add`), so every element is the
    /// identical function of its input regardless of which side of the
    /// vector boundary it falls on.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn exp_nonpos_scalar(x: f64) -> f64 {
        if x < -708.0 {
            return 0.0;
        }
        let k = f64::mul_add(x, std::f64::consts::LOG2_E, 0.5).floor();
        let z = f64::mul_add(-k, LN2_LO, f64::mul_add(-k, LN2_HI, x));
        let mut p = 1.0 / 6_227_020_800.0;
        p = f64::mul_add(p, z, 1.0 / 479_001_600.0);
        p = f64::mul_add(p, z, 1.0 / 39_916_800.0);
        p = f64::mul_add(p, z, 1.0 / 3_628_800.0);
        p = f64::mul_add(p, z, 1.0 / 362_880.0);
        p = f64::mul_add(p, z, 1.0 / 40_320.0);
        p = f64::mul_add(p, z, 1.0 / 5_040.0);
        p = f64::mul_add(p, z, 1.0 / 720.0);
        p = f64::mul_add(p, z, 1.0 / 120.0);
        p = f64::mul_add(p, z, 1.0 / 24.0);
        p = f64::mul_add(p, z, 1.0 / 6.0);
        p = f64::mul_add(p, z, 0.5);
        p = f64::mul_add(p, z, 1.0);
        p = f64::mul_add(p, z, 1.0);
        let bits = ((k.max(-1022.0) as i64 + 1023) as u64) << 52;
        p * f64::from_bits(bits)
    }
}

/// The scalar vtable (always available; the accuracy reference).
static SCALAR: MicroKernels = MicroKernels {
    isa: Isa::Scalar,
    nn_4x8: scalar::nn_4x8,
    nt_4x8: scalar::nt_4x8,
    dot: scalar::dot,
    axpy: scalar::axpy,
    exp_row: scalar::exp_row,
};

/// The AVX2+FMA vtable (only reachable after runtime detection).
#[cfg(target_arch = "x86_64")]
static AVX2: MicroKernels = MicroKernels {
    isa: Isa::Avx2,
    nn_4x8: avx2::nn_4x8,
    nt_4x8: avx2::nt_4x8,
    dot: avx2::dot,
    axpy: avx2::axpy,
    exp_row: avx2::exp_row,
};

const ISA_UNINIT: u8 = 0;
const ISA_SCALAR: u8 = 1;
const ISA_AVX2: u8 = 2;

/// Lazily-initialized active backend (see [`kernels`]). Runtime-
/// switchable so tests and the bench harness can flip backends
/// in-process via [`set_isa`].
static ACTIVE: AtomicU8 = AtomicU8::new(ISA_UNINIT);

/// True when the host supports the AVX2+FMA backend.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn init_from_env() -> u8 {
    let pick = match std::env::var("BLESS_ISA").ok().as_deref() {
        Some("scalar") => ISA_SCALAR,
        Some("avx2") if avx2_available() => ISA_AVX2,
        // unknown value, "auto", or unsupported request: auto-detect
        _ => {
            if avx2_available() {
                ISA_AVX2
            } else {
                ISA_SCALAR
            }
        }
    };
    // racing initializers pick the same value, so any order is fine
    ACTIVE.store(pick, Ordering::Relaxed);
    pick
}

/// The active micro-kernel vtable.
///
/// First call selects a backend: `BLESS_ISA=scalar|avx2|auto` if set,
/// otherwise AVX2+FMA when the host supports it, scalar elsewhere.
/// Callers hoist this lookup out of their loops — one relaxed atomic
/// load and no allocation.
#[inline]
pub fn kernels() -> &'static MicroKernels {
    let mut tag = ACTIVE.load(Ordering::Relaxed);
    if tag == ISA_UNINIT {
        tag = init_from_env();
    }
    match tag {
        ISA_SCALAR => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        ISA_AVX2 => &AVX2,
        _ => &SCALAR,
    }
}

/// The active backend's identity.
pub fn active_isa() -> Isa {
    kernels().isa
}

/// Force a backend (CLI `--isa`, tests, the SIMD bench). Fails when the
/// host lacks the requested ISA. Affects all subsequent linalg calls in
/// the process; callers that flip backends mid-run are responsible for
/// not doing so concurrently with in-flight factorizations if they need
/// a whole result computed under one ISA.
pub fn set_isa(isa: Isa) -> Result<(), String> {
    match isa {
        Isa::Scalar => {
            ACTIVE.store(ISA_SCALAR, Ordering::Relaxed);
            Ok(())
        }
        Isa::Avx2 => {
            if avx2_available() {
                ACTIVE.store(ISA_AVX2, Ordering::Relaxed);
                Ok(())
            } else {
                Err("this host does not support the avx2 backend (need AVX2 and FMA)".to_string())
            }
        }
    }
}

/// Parse and apply a `--isa` / `BLESS_ISA`-style name
/// (`scalar` / `avx2` / `auto`).
pub fn set_isa_from_str(name: &str) -> Result<(), String> {
    match name {
        "scalar" => set_isa(Isa::Scalar),
        "avx2" => set_isa(Isa::Avx2),
        "auto" => {
            ACTIVE.store(ISA_UNINIT, Ordering::Relaxed);
            kernels();
            Ok(())
        }
        other => Err(format!("unknown ISA '{other}' (expected scalar, avx2, or auto)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, f: impl Fn(usize) -> f64) -> Vec<f64> {
        (0..n).map(f).collect()
    }

    /// Run `f` under the given backend, restoring auto afterwards.
    fn with_isa<T>(isa: Isa, f: impl FnOnce() -> T) -> Option<T> {
        if set_isa(isa).is_err() {
            return None;
        }
        let out = f();
        set_isa_from_str("auto").unwrap();
        Some(out)
    }

    #[test]
    fn scalar_tiles_match_naive() {
        let pl = 37;
        let n = 24;
        let a: [Vec<f64>; 4] =
            std::array::from_fn(|r| seq(pl, |p| ((r * pl + p) as f64 * 0.37).sin()));
        let bd = seq(pl * n, |i| ((i as f64) * 0.23).cos());
        let j = 8;
        let mut acc = [[0.0f64; 8]; 4];
        (SCALAR.nn_4x8)([&a[0], &a[1], &a[2], &a[3]], &bd, n, j, &mut acc);
        for (r, acc_r) in acc.iter().enumerate() {
            for (c, got) in acc_r.iter().enumerate() {
                let want: f64 = (0..pl).map(|p| a[r][p] * bd[p * n + j + c]).sum();
                assert!((got - want).abs() < 1e-12, "nn r={r} c={c}");
            }
        }
    }

    #[test]
    fn backends_agree_on_tiles() {
        let Some(()) = with_isa(Isa::Avx2, || {}) else {
            return; // no AVX2 on this host; the scalar path is the reference
        };
        let pl = 53; // odd: exercises the vector tail
        let a: [Vec<f64>; 4] =
            std::array::from_fn(|r| seq(pl, |p| ((r * 31 + p * 7) as f64 * 0.11).sin()));
        let b: [Vec<f64>; 8] =
            std::array::from_fn(|c| seq(pl, |p| ((c * 13 + p * 3) as f64 * 0.17).cos()));
        let ar: [&[f64]; 4] = std::array::from_fn(|r| a[r].as_slice());
        let br: [&[f64]; 8] = std::array::from_fn(|c| b[c].as_slice());
        let mut s = [[0.0f64; 8]; 4];
        let mut v = [[0.0f64; 8]; 4];
        (SCALAR.nt_4x8)(ar, br, &mut s);
        #[cfg(target_arch = "x86_64")]
        (AVX2.nt_4x8)(ar, br, &mut v);
        for r in 0..4 {
            for c in 0..8 {
                assert!((s[r][c] - v[r][c]).abs() <= 1e-12 * s[r][c].abs().max(1.0));
            }
        }
        let x = seq(101, |i| (i as f64 * 0.7).sin());
        let y = seq(101, |i| (i as f64 * 0.3).cos());
        let ds = (SCALAR.dot)(&x, &y);
        #[cfg(target_arch = "x86_64")]
        {
            let dv = (AVX2.dot)(&x, &y);
            assert!((ds - dv).abs() <= 1e-12 * ds.abs().max(1.0));
            let mut ys = y.clone();
            let mut yv = y.clone();
            (SCALAR.axpy)(0.37, &x, &mut ys);
            (AVX2.axpy)(0.37, &x, &mut yv);
            for (u, w) in ys.iter().zip(&yv) {
                assert!((u - w).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn env_override_and_set_isa() {
        // scalar is always settable
        set_isa(Isa::Scalar).unwrap();
        assert_eq!(active_isa(), Isa::Scalar);
        set_isa_from_str("auto").unwrap();
        if avx2_available() {
            assert_eq!(active_isa(), Isa::Avx2);
        } else {
            assert_eq!(active_isa(), Isa::Scalar);
            assert!(set_isa(Isa::Avx2).is_err());
        }
        assert!(set_isa_from_str("neon").is_err());
    }
}
