//! Blocked general matrix multiplication and matrix-vector products.
//!
//! Row-major GEMM built around the `i-p-j` loop order: the innermost loop
//! streams a row of `B` into a row of `C` with a scalar multiplier, which
//! auto-vectorizes well and keeps all accesses sequential. Outer blocking
//! on the `p` (inner) dimension keeps the active slab of `B` in cache.

use super::Matrix;

/// Inner-dimension block size (tuned in the perf pass, see EXPERIMENTS.md §Perf).
const KC: usize = 256;
/// Row block size.
const MC: usize = 64;

/// `C = A * B` for row-major matrices.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "gemm dimension mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c);
    c
}

/// `C += A * B`, writing into an existing buffer (no allocation).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k);
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let (ad, bd) = (a.as_slice(), b.as_slice());
    let cd = c.as_mut_slice();
    for pb in (0..k).step_by(KC) {
        let pe = (pb + KC).min(k);
        for ib in (0..m).step_by(MC) {
            let ie = (ib + MC).min(m);
            // 4×8 register micro-kernel: a 4-row × 8-col C tile lives in
            // registers across the whole p-panel, so C is read/written
            // once per panel instead of once per p (the k=d≈18 kernel
            // cross-term shape was C-bandwidth-bound; §Perf).
            let mut i = ib;
            while i + 4 <= ie {
                let a0 = &ad[i * k..(i + 1) * k];
                let a1 = &ad[(i + 1) * k..(i + 2) * k];
                let a2 = &ad[(i + 2) * k..(i + 3) * k];
                let a3 = &ad[(i + 3) * k..(i + 4) * k];
                let mut j = 0;
                while j + 8 <= n {
                    let mut acc = [[0.0f64; 8]; 4];
                    for p in pb..pe {
                        let b8 = &bd[p * n + j..p * n + j + 8];
                        let w = [a0[p], a1[p], a2[p], a3[p]];
                        for (r, acc_r) in acc.iter_mut().enumerate() {
                            let wr = w[r];
                            for (c, av) in acc_r.iter_mut().enumerate() {
                                *av += wr * b8[c];
                            }
                        }
                    }
                    for (r, acc_r) in acc.iter().enumerate() {
                        let crow = &mut cd[(i + r) * n + j..(i + r) * n + j + 8];
                        for (cv, av) in crow.iter_mut().zip(acc_r.iter()) {
                            *cv += av;
                        }
                    }
                    j += 8;
                }
                // column remainder
                while j < n {
                    let mut acc = [0.0f64; 4];
                    for p in pb..pe {
                        let bv = bd[p * n + j];
                        acc[0] += a0[p] * bv;
                        acc[1] += a1[p] * bv;
                        acc[2] += a2[p] * bv;
                        acc[3] += a3[p] * bv;
                    }
                    for (r, av) in acc.iter().enumerate() {
                        cd[(i + r) * n + j] += av;
                    }
                    j += 1;
                }
                i += 4;
            }
            // remainder rows: plain row-streaming kernel
            while i < ie {
                let arow = &ad[i * k..(i + 1) * k];
                let crow = &mut cd[i * n..(i + 1) * n];
                for p in pb..pe {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &bd[p * n..(p + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += aip * bv;
                    }
                }
                i += 1;
            }
        }
    }
}

/// `C = Aᵀ * B` without materializing `Aᵀ` (A is k×m, B is k×n, C is m×n).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "gemm_tn dimension mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let (ad, bd) = (a.as_slice(), b.as_slice());
    let cd = c.as_mut_slice();
    // Loop over the shared dimension p (rows of both A and B): rank-1
    // updates C += a_p ⊗ b_p. Sequential access on all three matrices.
    for pb in (0..k).step_by(KC) {
        let pe = (pb + KC).min(k);
        for p in pb..pe {
            let arow = &ad[p * m..(p + 1) * m];
            let brow = &bd[p * n..(p + 1) * n];
            for i in 0..m {
                let aip = arow[i];
                if aip == 0.0 {
                    continue;
                }
                let crow = &mut cd[i * n..(i + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += aip * bv;
                }
            }
        }
    }
    c
}

/// `y = A * x`.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    matvec_into(a, x, &mut y);
    y
}

/// `y = A * x` into an existing buffer.
pub fn matvec_into(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for i in 0..a.rows() {
        y[i] = super::dot(a.row(i), x);
    }
}

/// `y = Aᵀ * x` without materializing `Aᵀ`.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        super::axpy(x[i], a.row(i), &mut y);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|p| a.get(i, p) * b.get(p, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive_small() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-12);
    }

    #[test]
    fn gemm_matches_naive_odd_sizes() {
        // sizes chosen to exercise partial blocks
        let a = Matrix::from_fn(67, 129, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Matrix::from_fn(129, 43, |i, j| ((i * 3 + j * 17) % 9) as f64 - 4.0);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-9);
    }

    #[test]
    fn gemm_tn_matches_transpose_then_gemm() {
        let a = Matrix::from_fn(31, 17, |i, j| (i as f64 - j as f64) * 0.25);
        let b = Matrix::from_fn(31, 23, |i, j| ((i + j) % 7) as f64);
        let c1 = gemm_tn(&a, &b);
        let c2 = gemm(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);
    }

    #[test]
    fn matvec_consistency() {
        let a = Matrix::from_fn(13, 29, |i, j| (i + 2 * j) as f64 * 0.1);
        let x: Vec<f64> = (0..29).map(|i| (i as f64).cos()).collect();
        let y = matvec(&a, &x);
        for i in 0..13 {
            let expect: f64 = (0..29).map(|j| a.get(i, j) * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-10);
        }
        // Aᵀ via matvec_t equals transpose-then-matvec
        let z: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let t1 = matvec_t(&a, &z);
        let t2 = matvec(&a.transpose(), &z);
        for (u, v) in t1.iter().zip(&t2) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn gemm_identity() {
        let a = Matrix::from_fn(10, 10, |i, j| (i * j) as f64);
        let c = gemm(&a, &Matrix::eye(10));
        assert!(c.max_abs_diff(&a) < 1e-12);
    }
}
