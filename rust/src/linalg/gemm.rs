//! Blocked general matrix multiplication and matrix-vector products.
//!
//! Row-major GEMM built around the `i-p-j` loop order: the innermost loop
//! streams a row of `B` into a row of `C` with a scalar multiplier, which
//! auto-vectorizes well and keeps all accesses sequential. Outer blocking
//! on the `p` (inner) dimension keeps the active slab of `B` in cache.
//!
//! All heavy routines here are parallelized over **fixed-size output
//! blocks** through [`crate::util::pool`]: block boundaries depend only
//! on the problem shape (never on the thread count) and every block runs
//! the identical floating-point sequence the serial code would, so the
//! parallel result is bit-identical to the 1-thread path. Small problems
//! stay on an inline serial path to avoid dispatch overhead.
//!
//! The register micro-kernels themselves (4×8 NN and NT tiles, the edge
//! dots and axpys) are resolved once per call through
//! [`super::dispatch::kernels`] — scalar or AVX2+FMA — so results may
//! vary **by ISA** but never by thread count.

use super::dispatch::{self, MicroKernels};
use super::Matrix;
use crate::util::pool;

/// Inner-dimension block size (tuned in the perf pass, see EXPERIMENTS.md §Perf).
const KC: usize = 256;
/// Row block size — also the unit of parallel work distribution.
const MC: usize = 64;
/// Below this many multiply-adds a dispatch is not worth its overhead.
const PAR_MIN_WORK: usize = 1 << 15;

/// `C = A * B` for row-major matrices.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "gemm dimension mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c);
    c
}

/// `C += A * B`, writing into an existing buffer (no allocation).
///
/// Parallelized over `MC`-row blocks of `C`; each worker runs the full
/// `p`-panel loop for its rows, so per-element accumulation order — and
/// with it the bit pattern of the result — matches the serial code.
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k);
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    if m == 0 || n == 0 {
        return;
    }
    let (ad, bd) = (a.as_slice(), b.as_slice());
    let cd = c.as_mut_slice();
    let kern = dispatch::kernels();
    let work = m.saturating_mul(k).saturating_mul(n);
    pool::par_chunks_mut_gated(cd, MC * n, work >= PAR_MIN_WORK, |blk, chunk| {
        gemm_row_block(kern, ad, bd, chunk, blk * MC, k, n);
    });
}

/// One `MC`-row block of `C += A * B`: rows `[i0, i0 + rows)` of `A`/`C`,
/// with `chunk` holding exactly those rows of `C`. The 4×8 register
/// micro-kernel keeps a 4-row × 8-col C tile in registers across the
/// whole `p`-panel, so C is read/written once per panel instead of once
/// per `p` (the k=d≈18 kernel cross-term shape was C-bandwidth-bound;
/// §Perf).
fn gemm_row_block(
    kern: &MicroKernels,
    ad: &[f64],
    bd: &[f64],
    chunk: &mut [f64],
    i0: usize,
    k: usize,
    n: usize,
) {
    let rows = chunk.len() / n;
    for pb in (0..k).step_by(KC) {
        let pe = (pb + KC).min(k);
        let bpanel = &bd[pb * n..pe * n];
        let mut r = 0;
        while r + 4 <= rows {
            let i = i0 + r;
            let a0 = &ad[i * k + pb..i * k + pe];
            let a1 = &ad[(i + 1) * k + pb..(i + 1) * k + pe];
            let a2 = &ad[(i + 2) * k + pb..(i + 2) * k + pe];
            let a3 = &ad[(i + 3) * k + pb..(i + 3) * k + pe];
            let mut j = 0;
            while j + 8 <= n {
                let mut acc = [[0.0f64; 8]; 4];
                (kern.nn_4x8)([a0, a1, a2, a3], bpanel, n, j, &mut acc);
                for (rr, acc_r) in acc.iter().enumerate() {
                    let crow = &mut chunk[(r + rr) * n + j..(r + rr) * n + j + 8];
                    for (cv, av) in crow.iter_mut().zip(acc_r.iter()) {
                        *cv += av;
                    }
                }
                j += 8;
            }
            // column remainder
            while j < n {
                let mut acc = [0.0f64; 4];
                for p in 0..pe - pb {
                    let bv = bpanel[p * n + j];
                    acc[0] += a0[p] * bv;
                    acc[1] += a1[p] * bv;
                    acc[2] += a2[p] * bv;
                    acc[3] += a3[p] * bv;
                }
                for (rr, av) in acc.iter().enumerate() {
                    chunk[(r + rr) * n + j] += av;
                }
                j += 1;
            }
            r += 4;
        }
        // remainder rows: plain row-streaming kernel
        while r < rows {
            let arow = &ad[(i0 + r) * k..(i0 + r + 1) * k];
            let crow = &mut chunk[r * n..(r + 1) * n];
            for p in pb..pe {
                let aip = arow[p];
                if aip == 0.0 {
                    continue;
                }
                let brow = &bd[p * n..(p + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += aip * bv;
                }
            }
            r += 1;
        }
    }
}

/// `C = A * Bᵀ` without materializing `Bᵀ` (A is m×k, B is n×k, C is m×n).
///
/// This is the kernel cross-term shape: a tall row tile of the dataset
/// against a fixed (row-major) center matrix. Because both operands are
/// traversed along their rows, every inner-loop access is sequential and
/// no `n × k` transpose buffer is ever allocated. Parallelized over the
/// same fixed `MC`-row output blocks as [`gemm`], so the result is
/// bit-identical at any thread count.
#[deprecated(note = "use `MatMul::nt().run(a, b)` — same engine, one facade")]
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.rows());
    nt_into_checked(a, b, &mut c);
    c
}

/// `C += A * Bᵀ` into an existing buffer (no allocation).
#[deprecated(note = "use `MatMul::nt().accumulate().run_into(a, b, c)`")]
pub fn gemm_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    nt_into_checked(a, b, c);
}

/// Shape-checked `C += A·Bᵀ` on [`Matrix`] operands (the shared body of
/// the deprecated `gemm_nt`/`gemm_nt_into` wrappers and the
/// [`super::MatMul`] facade).
pub(crate) fn nt_into_checked(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "gemm nt dimension mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.rows());
    nt_acc(a.as_slice(), b.as_slice(), a.cols(), c.as_mut_slice(), b.rows());
}

/// `C += A * Bᵀ` over raw row-major slices: `A` is `(c.len()/n) × k`,
/// `B` is `n × k`, `C` is `(c.len()/n) × n`.
///
/// The slice form exists so callers holding borrowed row ranges (e.g.
/// the kernel engine streaming contiguous dataset tiles) can feed the
/// product without copying into a fresh [`Matrix`]. Same fixed-block
/// parallel partition as the `Matrix` forms.
#[deprecated(note = "use `MatMul::nt().accumulate().run_rows_into(a, b, k, c, n)`")]
pub fn gemm_nt_acc(a: &[f64], b: &[f64], k: usize, c: &mut [f64], n: usize) {
    nt_acc(a, b, k, c, n);
}

/// The raw-slice `C += A·Bᵀ` engine behind [`gemm_nt_acc`] and
/// [`super::MatMul::run_rows_into`].
pub(crate) fn nt_acc(a: &[f64], b: &[f64], k: usize, c: &mut [f64], n: usize) {
    assert!(k > 0, "gemm nt needs a positive inner dimension");
    assert_eq!(b.len(), n * k, "B shape mismatch");
    assert_eq!(c.len() % n.max(1), 0, "C shape mismatch");
    if n == 0 || c.is_empty() {
        return;
    }
    let m = c.len() / n;
    assert_eq!(a.len(), m * k, "A shape mismatch");
    let kern = dispatch::kernels();
    let work = m.saturating_mul(k).saturating_mul(n);
    pool::par_chunks_mut_gated(c, MC * n, work >= PAR_MIN_WORK, |blk, chunk| {
        gemm_nt_row_block(kern, a, b, chunk, blk * MC, k, n);
    });
}

/// One `MC`-row block of `C += A * Bᵀ`: rows `[i0, i0 + rows)` of `A`/`C`.
/// 4×8 micro-kernel over dot-product panels: 4 rows of `A` against 8
/// rows of `B`, all 12 streams read sequentially in `p`, 32 accumulators
/// live in registers across the whole `KC` panel.
fn gemm_nt_row_block(
    kern: &MicroKernels,
    ad: &[f64],
    bd: &[f64],
    chunk: &mut [f64],
    i0: usize,
    k: usize,
    n: usize,
) {
    let rows = chunk.len() / n;
    for pb in (0..k).step_by(KC) {
        let pe = (pb + KC).min(k);
        let mut r = 0;
        while r + 4 <= rows {
            let arow = |rr: usize| &ad[(i0 + r + rr) * k + pb..(i0 + r + rr) * k + pe];
            let a4 = [arow(0), arow(1), arow(2), arow(3)];
            let mut j = 0;
            while j + 8 <= n {
                let b8: [&[f64]; 8] =
                    std::array::from_fn(|cc| &bd[(j + cc) * k + pb..(j + cc) * k + pe]);
                let mut acc = [[0.0f64; 8]; 4];
                (kern.nt_4x8)(a4, b8, &mut acc);
                for (rr, acc_r) in acc.iter().enumerate() {
                    let crow = &mut chunk[(r + rr) * n + j..(r + rr) * n + j + 8];
                    for (cv, av) in crow.iter_mut().zip(acc_r.iter()) {
                        *cv += av;
                    }
                }
                j += 8;
            }
            // column remainder: single B rows against the 4 A rows
            while j < n {
                let brow = &bd[j * k + pb..j * k + pe];
                for (rr, ar) in a4.iter().enumerate() {
                    chunk[(r + rr) * n + j] += (kern.dot)(ar, brow);
                }
                j += 1;
            }
            r += 4;
        }
        // remainder rows: plain dot products
        while r < rows {
            let arow = &ad[(i0 + r) * k + pb..(i0 + r) * k + pe];
            for j in 0..n {
                let brow = &bd[j * k + pb..j * k + pe];
                chunk[r * n + j] += (kern.dot)(arow, brow);
            }
            r += 1;
        }
    }
}

/// `C = A·Aᵀ` (symmetric rank-k update, `A` is `m × k`, `C` is `m × m`).
///
/// Only the lower triangle is computed — each element through the same
/// 4×8 dot-product micro-kernel as the NT product, parallelized over
/// fixed `MC`-row blocks of `C` — and then mirrored into the upper
/// triangle, so the result is exactly symmetric and costs half the
/// multiply-adds of the dense `A·Aᵀ`. Bit-identical at any thread count.
pub fn syrk(a: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), a.rows());
    nt_lower_acc_into(a, &mut c);
    c.mirror_lower_to_upper();
    c
}

/// Lower-triangle-only `C += A·Aᵀ` accumulation (the strict upper
/// triangle is left untouched) — the engine behind [`syrk`] and the
/// `Triangle::Lower` NT path of [`super::MatMul`].
pub(crate) fn nt_lower_acc_into(a: &Matrix, c: &mut Matrix) {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(c.rows(), m, "syrk output shape mismatch");
    assert_eq!(c.cols(), m, "syrk output shape mismatch");
    if m == 0 {
        return;
    }
    let ad = a.as_slice();
    let kern = dispatch::kernels();
    let work = m.saturating_mul(m).saturating_mul(k.max(1)) / 2;
    pool::par_chunks_mut_gated(c.as_mut_slice(), MC * m, work >= PAR_MIN_WORK, |blk, chunk| {
        syrk_ln_panel(kern, ad, chunk, blk * MC, k, m, 0, 1.0);
    });
}

/// One `MC`-row block of the lower-triangle-only rank-`w` update
/// `C[t, 0..=t] += sign · A_t · A_jᵀ` (`A` = `panel`, row-major `p × w`;
/// `chunk` holds rows `[t0, t0+rows)` of a matrix with row stride `ldc`
/// whose triangle starts at column offset `c0`, i.e. the diagonal
/// element of trailing row `t` lives at column `c0 + t`).
///
/// This is the shared engine of [`syrk`] (`c0 = 0`, `sign = +1`) and the
/// Cholesky Schur-complement update (`c0 = ke`, `sign = −1`): full 4×8
/// register tiles up to the group's first diagonal, then scalar dots for
/// the ragged triangle edge. The tile/ragged split depends only on the
/// global trailing-row index `t` (chunks are `MC`-row aligned, `MC` a
/// multiple of 4), so every element takes the same code path — and gets
/// the same bits — at any thread count.
pub(crate) fn syrk_ln_panel(
    kern: &MicroKernels,
    panel: &[f64],
    chunk: &mut [f64],
    t0: usize,
    w: usize,
    ldc: usize,
    c0: usize,
    sign: f64,
) {
    if w == 0 {
        return;
    }
    let rows = chunk.len() / ldc;
    for pb in (0..w).step_by(KC) {
        let pe = (pb + KC).min(w);
        let mut r = 0;
        while r + 4 <= rows {
            let t = t0 + r;
            let arow = |rr: usize| &panel[(t + rr) * w + pb..(t + rr) * w + pe];
            let a4 = [arow(0), arow(1), arow(2), arow(3)];
            let mut j = 0;
            // full 4×8 tiles up to the first row's diagonal column
            while j + 8 <= t + 1 {
                let b8: [&[f64]; 8] =
                    std::array::from_fn(|cc| &panel[(j + cc) * w + pb..(j + cc) * w + pe]);
                let mut acc = [[0.0f64; 8]; 4];
                (kern.nt_4x8)(a4, b8, &mut acc);
                for (rr, acc_r) in acc.iter().enumerate() {
                    let base = (r + rr) * ldc + c0 + j;
                    let crow = &mut chunk[base..base + 8];
                    for (cv, av) in crow.iter_mut().zip(acc_r.iter()) {
                        *cv += sign * av;
                    }
                }
                j += 8;
            }
            // ragged triangle edge: dots out to each row's diagonal
            for (rr, ar) in a4.iter().enumerate() {
                for jj in j..=(t + rr) {
                    let brow = &panel[jj * w + pb..jj * w + pe];
                    chunk[(r + rr) * ldc + c0 + jj] += sign * (kern.dot)(ar, brow);
                }
            }
            r += 4;
        }
        // remainder rows: plain dots along the whole row prefix
        while r < rows {
            let t = t0 + r;
            let ar = &panel[t * w + pb..t * w + pe];
            for jj in 0..=t {
                let brow = &panel[jj * w + pb..jj * w + pe];
                chunk[r * ldc + c0 + jj] += sign * (kern.dot)(ar, brow);
            }
            r += 1;
        }
    }
}

/// Row block size for [`gemm_tn`]'s output (columns of `A`).
const TN_RB: usize = 64;

/// `C = Aᵀ * B` without materializing `Aᵀ` (A is k×m, B is k×n, C is m×n).
///
/// Parallelized over `TN_RB`-row blocks of `C`; within a block, panels of
/// the shared dimension `p` stream rank-1 contributions in ascending `p`
/// order — the same per-element order as the serial rank-1 formulation,
/// so the result is bit-identical.
#[deprecated(note = "use `MatMul::tn().run(a, b)` — same engine, one facade")]
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), b.cols());
    tn_acc_into(a, b, &mut c);
    c
}

/// Shape-checked `C += Aᵀ·B` accumulation (the shared body of the
/// deprecated `gemm_tn` wrapper and the TN path of [`super::MatMul`]).
pub(crate) fn tn_acc_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "gemm tn dimension mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(c.rows(), m, "gemm tn output shape mismatch");
    assert_eq!(c.cols(), n, "gemm tn output shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let (ad, bd) = (a.as_slice(), b.as_slice());
    let cd = c.as_mut_slice();
    let kern = dispatch::kernels();
    let work = m.saturating_mul(k).saturating_mul(n);
    pool::par_chunks_mut_gated(cd, TN_RB * n, work >= PAR_MIN_WORK, |blk, chunk| {
        gemm_tn_row_block(kern, ad, bd, chunk, blk * TN_RB, k, m, n);
    });
}

/// One `TN_RB`-row block of `C = Aᵀ B`: output rows `[i0, i0 + rows)`
/// (= columns of `A`), with `chunk` holding exactly those rows of `C`.
#[allow(clippy::too_many_arguments)]
fn gemm_tn_row_block(
    kern: &MicroKernels,
    ad: &[f64],
    bd: &[f64],
    chunk: &mut [f64],
    i0: usize,
    k: usize,
    m: usize,
    n: usize,
) {
    let rows = chunk.len() / n;
    for pb in (0..k).step_by(KC) {
        let pe = (pb + KC).min(k);
        for p in pb..pe {
            let aseg = &ad[p * m + i0..p * m + i0 + rows];
            let brow = &bd[p * n..(p + 1) * n];
            for (r, &aip) in aseg.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let crow = &mut chunk[r * n..(r + 1) * n];
                (kern.axpy)(aip, brow, crow);
            }
        }
    }
}

/// `C = AᵀA` (`A` is `k × m`, `C` is `m × m`) without materializing `Aᵀ`.
///
/// Computes only the lower triangle — half the multiply-adds of the
/// dense `AᵀA` — and mirrors it, so the result is exactly symmetric.
/// See [`tn_lower_acc_into`] for the partition/determinism contract.
#[deprecated(note = "use `MatMul::tn().lower().run(a, a)` — same engine, one facade")]
pub fn syrk_tn(a: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), a.cols());
    tn_lower_acc_into(a, &mut c);
    c.mirror_lower_to_upper();
    c
}

/// `C += AᵀA`, accumulating into the **lower triangle only** of an
/// existing buffer (no allocation; the strict upper triangle is left
/// untouched).
#[deprecated(note = "use `MatMul::tn().accumulate().lower().run_into(a, a, c)`")]
pub fn syrk_tn_into(a: &Matrix, c: &mut Matrix) {
    tn_lower_acc_into(a, c);
}

/// Lower-triangle-only `C += AᵀA` accumulation.
///
/// The accumulation is rank-1 over rows `p` of `A` in ascending order,
/// parallelized over fixed `TN_RB`-row blocks of `C` (the same partition
/// as the dense TN product) — bit-identical at any thread count. This is
/// the `H += K_tileᵀ K_tile` Gram-accumulation shape of Nyström-KRR:
/// accumulate tile after tile, then call
/// [`Matrix::mirror_lower_to_upper`] once at the end if a fully
/// symmetric matrix is needed (the allocating forms do exactly that).
pub(crate) fn tn_lower_acc_into(a: &Matrix, c: &mut Matrix) {
    let (k, m) = (a.rows(), a.cols());
    assert_eq!(c.rows(), m, "syrk tn output shape mismatch");
    assert_eq!(c.cols(), m, "syrk tn output shape mismatch");
    if m == 0 {
        return;
    }
    let ad = a.as_slice();
    let kern = dispatch::kernels();
    let work = k.saturating_mul(m).saturating_mul(m) / 2;
    pool::par_chunks_mut_gated(c.as_mut_slice(), TN_RB * m, work >= PAR_MIN_WORK, |blk, chunk| {
        syrk_tn_row_block(kern, ad, chunk, blk * TN_RB, 0, k, m);
    });
}

/// `C = LᵀL` for a **lower-triangular** `L`, exploiting both the
/// symmetry of the output and the triangularity of the input.
///
/// `(LᵀL)_{ij} = Σ_{p ≥ max(i,j)} L_{pi} L_{pj}`, so a `TN_RB`-row block
/// of `C` starting at row `i0` only needs rows `p ≥ i0` of `L` — the
/// rank-1 sweep is truncated per block and the zero-skip drops the rest,
/// leaving ~`n³/6` multiply-adds versus `n³/2` for `gemm_tn(l, l)`.
/// This is the `G = (n/M)·LᵀL + λn·I` build of the FALKON
/// preconditioner (Def. 2 / Eq. 15). Bit-identical at any thread count:
/// each element accumulates `p = i..n` in ascending order regardless of
/// the partition.
pub fn syrk_tn_of_lower(l: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(l.cols(), n, "syrk_tn_of_lower requires a square factor");
    let mut c = Matrix::zeros(n, n);
    if n == 0 {
        return c;
    }
    let ld = l.as_slice();
    let kern = dispatch::kernels();
    let work = n.saturating_mul(n).saturating_mul(n) / 6;
    pool::par_chunks_mut_gated(c.as_mut_slice(), TN_RB * n, work >= PAR_MIN_WORK, |blk, chunk| {
        syrk_tn_row_block(kern, ld, chunk, blk * TN_RB, blk * TN_RB, n, n);
    });
    c.mirror_lower_to_upper();
    c
}

/// One `TN_RB`-row block of the lower-triangle-only `C += AᵀA` update:
/// rows `[i0, i0 + rows)` of `C`, rank-1 contributions from rows
/// `p ∈ [p_start, k)` of `A` in ascending order. `p_start > 0` is only
/// sound when `A[p, i] = 0` for all `p < p_start`, `i ≥ i0` (the
/// lower-triangular-input case of [`syrk_tn_of_lower`]).
fn syrk_tn_row_block(
    kern: &MicroKernels,
    ad: &[f64],
    chunk: &mut [f64],
    i0: usize,
    p_start: usize,
    k: usize,
    m: usize,
) {
    let rows = chunk.len() / m;
    for pb in (p_start..k).step_by(KC) {
        let pe = (pb + KC).min(k);
        for p in pb..pe {
            let prow = &ad[p * m..(p + 1) * m];
            for r in 0..rows {
                let i = i0 + r;
                let aip = prow[i];
                if aip == 0.0 {
                    continue;
                }
                let crow = &mut chunk[r * m..r * m + i + 1];
                (kern.axpy)(aip, &prow[..=i], crow);
            }
        }
    }
}

/// Per-column squared norms: `out[j] = Σ_i A_ij²`.
///
/// This is the `‖L⁻¹ k_i‖²` contraction at the tail of every
/// leverage-score batch (Eq. 3) and of [`crate::leverage::exact_leverage_scores`].
/// Parallelized over fixed `MT_CB`-column blocks; each element
/// accumulates rows in ascending order, so the result is bit-identical
/// at any thread count.
pub fn column_sq_norms(a: &Matrix) -> Vec<f64> {
    let (rows, cols) = (a.rows(), a.cols());
    let mut out = vec![0.0; cols];
    if rows == 0 || cols == 0 {
        return out;
    }
    let ad = a.as_slice();
    let parallel = rows.saturating_mul(cols) >= PAR_MIN_MV && cols > MT_CB;
    pool::par_chunks_mut_gated(&mut out, MT_CB, parallel, |blk, och| {
        let j0 = blk * MT_CB;
        let w = och.len();
        for i in 0..rows {
            let aseg = &ad[i * cols + j0..i * cols + j0 + w];
            for (oj, av) in och.iter_mut().zip(aseg.iter()) {
                *oj += av * av;
            }
        }
    });
    out
}

/// Output block sizes for the parallel matvec paths.
const MV_RB: usize = 128;
const MT_CB: usize = 256;
/// Minimum `rows × cols` before a matvec dispatches to the pool.
const PAR_MIN_MV: usize = 1 << 16;

/// `y = A * x`.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    matvec_into(a, x, &mut y);
    y
}

/// `y = A * x` into an existing buffer. Rows of `y` are independent, so
/// the parallel path chunks `y` and computes the identical per-row dot.
pub fn matvec_into(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    let (rows, cols) = (a.rows(), a.cols());
    let ad = a.as_slice();
    let kern = dispatch::kernels();
    let parallel = rows.saturating_mul(cols) >= PAR_MIN_MV;
    pool::par_chunks_mut_gated(y, MV_RB, parallel, |blk, ych| {
        let i0 = blk * MV_RB;
        for (r, yi) in ych.iter_mut().enumerate() {
            let i = i0 + r;
            *yi = (kern.dot)(&ad[i * cols..(i + 1) * cols], x);
        }
    });
}

/// `y = Aᵀ * x` without materializing `Aᵀ`.
///
/// The serial path accumulates row `i`'s contribution into all of `y` in
/// ascending `i` order; the parallel path chunks `y` by *columns* of `A`
/// and accumulates the same ascending-`i` sequence per element, so both
/// paths agree bitwise.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; a.cols()];
    matvec_t_acc(a, x, &mut y);
    y
}

/// `y += Aᵀ * x` into an existing buffer (no allocation, no transpose).
///
/// The streaming `K_nMᵀ·u` paths accumulate one row tile after another
/// into the same length-`M` output; this routine is that building block.
/// Per element the accumulation order is ascending row index `i` on both
/// the serial and the column-chunked parallel path, so the result is
/// bit-identical at any thread count.
pub fn matvec_t_acc(a: &Matrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    let (rows, cols) = (a.rows(), a.cols());
    let kern = dispatch::kernels();
    if rows.saturating_mul(cols) < PAR_MIN_MV || cols <= MT_CB {
        for (i, &xi) in x.iter().enumerate() {
            (kern.axpy)(xi, a.row(i), y);
        }
        return;
    }
    let ad = a.as_slice();
    pool::par_chunks_mut(y, MT_CB, |blk, ych| {
        let j0 = blk * MT_CB;
        let w = ych.len();
        for (i, &xi) in x.iter().enumerate() {
            let aseg = &ad[i * cols + j0..i * cols + j0 + w];
            (kern.axpy)(xi, aseg, ych);
        }
    });
}

#[cfg(test)]
#[allow(deprecated)] // the thin wrappers stay covered until call sites finish migrating
mod tests {
    use super::*;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|p| a.get(i, p) * b.get(p, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive_small() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-12);
    }

    #[test]
    fn gemm_matches_naive_odd_sizes() {
        // sizes chosen to exercise partial blocks
        let a = Matrix::from_fn(67, 129, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Matrix::from_fn(129, 43, |i, j| ((i * 3 + j * 17) % 9) as f64 - 4.0);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-9);
    }

    #[test]
    fn gemm_large_enough_to_dispatch_matches_naive() {
        // above PAR_MIN_WORK and more than one MC row block, so this
        // exercises the pool path (inline when the runner has one core)
        let a = Matrix::from_fn(150, 70, |i, j| ((i * 5 + j * 11) % 13) as f64 * 0.25 - 1.0);
        let b = Matrix::from_fn(70, 90, |i, j| ((i * 7 + j * 3) % 17) as f64 * 0.125 - 1.0);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-9);
    }

    #[test]
    fn gemm_tn_matches_transpose_then_gemm() {
        let a = Matrix::from_fn(31, 17, |i, j| (i as f64 - j as f64) * 0.25);
        let b = Matrix::from_fn(31, 23, |i, j| ((i + j) % 7) as f64);
        let c1 = gemm_tn(&a, &b);
        let c2 = gemm(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);
        // and a shape that crosses the TN_RB block boundary
        let a = Matrix::from_fn(40, 150, |i, j| ((i * 3 + j) % 5) as f64 - 2.0);
        let b = Matrix::from_fn(40, 60, |i, j| ((i + 2 * j) % 9) as f64 * 0.5);
        let c1 = gemm_tn(&a, &b);
        let c2 = gemm(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);
    }

    #[test]
    fn gemm_nt_matches_gemm_with_transpose() {
        // kernel cross-term shape: tall × small-d against a center panel
        let a = Matrix::from_fn(67, 18, |i, j| ((i * 7 + j * 13) % 11) as f64 * 0.3 - 1.0);
        let b = Matrix::from_fn(45, 18, |i, j| ((i * 3 + j * 17) % 9) as f64 * 0.25 - 1.0);
        let c1 = gemm_nt(&a, &b);
        let c2 = gemm(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-10);
        // square shape crossing KC and the parallel-dispatch threshold
        let a = Matrix::from_fn(150, 300, |i, j| ((i * 300 + j) as f64 * 0.37).sin());
        let b = Matrix::from_fn(90, 300, |i, j| ((i * 90 + j) as f64 * 0.73).cos());
        let c1 = gemm_nt(&a, &b);
        let c2 = gemm(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-9);
    }

    #[test]
    fn gemm_nt_into_accumulates() {
        let a = Matrix::from_fn(9, 5, |i, j| (i + j) as f64);
        let b = Matrix::from_fn(7, 5, |i, j| (i as f64 - j as f64) * 0.5);
        let mut c = Matrix::from_fn(9, 7, |i, j| (i * 7 + j) as f64);
        let expect = {
            let mut e = c.clone();
            let p = gemm(&a, &b.transpose());
            for (ev, pv) in e.as_mut_slice().iter_mut().zip(p.as_slice()) {
                *ev += pv;
            }
            e
        };
        gemm_nt_into(&a, &b, &mut c);
        assert!(c.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn gemm_nt_odd_remainders() {
        // rows not divisible by 4, cols not divisible by 8
        let a = Matrix::from_fn(13, 29, |i, j| ((i * 29 + j) % 7) as f64 - 3.0);
        let b = Matrix::from_fn(11, 29, |i, j| ((i * 11 + j) % 5) as f64 - 2.0);
        let c1 = gemm_nt(&a, &b);
        let c2 = gemm(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-11);
    }

    #[test]
    fn matvec_t_acc_accumulates_tiles() {
        // two stacked tiles accumulated into one output equal the full product
        let full = Matrix::from_fn(60, 24, |i, j| ((i * 24 + j) as f64 * 0.19).sin());
        let top = Matrix::from_fn(35, 24, |i, j| full.get(i, j));
        let bot = Matrix::from_fn(25, 24, |i, j| full.get(35 + i, j));
        let x: Vec<f64> = (0..60).map(|i| (i as f64 * 0.41).cos()).collect();
        let mut acc = vec![0.0; 24];
        matvec_t_acc(&top, &x[..35], &mut acc);
        matvec_t_acc(&bot, &x[35..], &mut acc);
        let direct = matvec_t(&full, &x);
        for (a, b) in acc.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_consistency() {
        let a = Matrix::from_fn(13, 29, |i, j| (i + 2 * j) as f64 * 0.1);
        let x: Vec<f64> = (0..29).map(|i| (i as f64).cos()).collect();
        let y = matvec(&a, &x);
        for i in 0..13 {
            let expect: f64 = (0..29).map(|j| a.get(i, j) * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-10);
        }
        // Aᵀ via matvec_t equals transpose-then-matvec
        let z: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let t1 = matvec_t(&a, &z);
        let t2 = matvec(&a.transpose(), &z);
        for (u, v) in t1.iter().zip(&t2) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_t_parallel_shape_matches_transpose() {
        // wide enough (cols > MT_CB, rows*cols > PAR_MIN_MV) to take the
        // column-chunked path
        let a = Matrix::from_fn(200, 400, |i, j| ((i * 13 + j * 7) % 23) as f64 * 0.1 - 1.0);
        let x: Vec<f64> = (0..200).map(|i| ((i * i) as f64).sin()).collect();
        let t1 = matvec_t(&a, &x);
        let t2 = matvec(&a.transpose(), &x);
        for (u, v) in t1.iter().zip(&t2) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn gemm_identity() {
        let a = Matrix::from_fn(10, 10, |i, j| (i * j) as f64);
        let c = gemm(&a, &Matrix::eye(10));
        assert!(c.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn syrk_matches_gemm_nt_self() {
        // odd shapes exercise the ragged triangle edge and remainder rows
        for &(m, k) in &[(1usize, 3usize), (5, 7), (13, 29), (67, 18), (96, 40), (150, 70)] {
            let a = Matrix::from_fn(m, k, |i, j| ((i * 31 + j * 7) % 13) as f64 * 0.3 - 1.5);
            let c = syrk(&a);
            let dense = gemm(&a, &a.transpose());
            assert!(c.max_abs_diff(&dense) < 1e-9, "syrk {m}x{k}");
            // exactly symmetric by construction
            for i in 0..m {
                for j in 0..i {
                    assert_eq!(c.get(i, j).to_bits(), c.get(j, i).to_bits());
                }
            }
        }
    }

    #[test]
    fn syrk_tn_matches_gemm_tn_self() {
        for &(k, m) in &[(3usize, 1usize), (7, 5), (29, 13), (40, 63), (40, 64), (40, 65)] {
            let a = Matrix::from_fn(k, m, |i, j| ((i * 5 + j * 11) % 9) as f64 * 0.25 - 1.0);
            let c = syrk_tn(&a);
            let dense = gemm_tn(&a, &a);
            assert!(c.max_abs_diff(&dense) < 1e-10, "syrk_tn {k}x{m}");
        }
    }

    #[test]
    fn syrk_tn_into_accumulates_tiles() {
        // two stacked tiles accumulated (lower triangle), mirrored once
        // at the end, equal the full-product Gram
        let full = Matrix::from_fn(90, 21, |i, j| ((i * 21 + j) as f64 * 0.23).sin());
        let top = Matrix::from_fn(50, 21, |i, j| full.get(i, j));
        let bot = Matrix::from_fn(40, 21, |i, j| full.get(50 + i, j));
        let mut acc = Matrix::zeros(21, 21);
        syrk_tn_into(&top, &mut acc);
        syrk_tn_into(&bot, &mut acc);
        acc.mirror_lower_to_upper();
        let direct = gemm_tn(&full, &full);
        assert!(acc.max_abs_diff(&direct) < 1e-10);
    }

    #[test]
    fn syrk_tn_of_lower_matches_dense_gemm_tn() {
        // sizes straddling the TN_RB block boundary
        for &n in &[1usize, 5, 63, 64, 65, 97, 150] {
            let l = Matrix::from_fn(n, n, |i, j| {
                if j > i {
                    0.0
                } else if i == j {
                    1.0 + (i % 4) as f64 * 0.5
                } else {
                    (((i * 7 + j * 3) % 11) as f64 - 5.0) * 0.1
                }
            });
            let c = syrk_tn_of_lower(&l);
            let dense = gemm_tn(&l, &l);
            assert!(c.max_abs_diff(&dense) < 1e-9, "syrk_tn_of_lower n={n}");
        }
    }

    #[test]
    fn column_sq_norms_matches_naive() {
        // narrow (serial path) and wide (column-chunked parallel path)
        for &(rows, cols) in &[(13usize, 7usize), (60, 24), (200, 400)] {
            let a = Matrix::from_fn(rows, cols, |i, j| ((i * cols + j) as f64 * 0.13).sin());
            let fast = column_sq_norms(&a);
            for (j, &v) in fast.iter().enumerate() {
                let naive: f64 = (0..rows).map(|i| a.get(i, j) * a.get(i, j)).sum();
                assert!((v - naive).abs() < 1e-10, "col {j}: {v} vs {naive}");
            }
        }
    }
}
