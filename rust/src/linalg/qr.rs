//! Blocked Householder QR factorization `A = Q R` for tall matrices.
//!
//! Compact-WY **right-looking** algorithm on the shared
//! [`crate::util::pool`], mirroring the structure of the blocked
//! Cholesky ([`super::cholesky`]): factor an `NB`-wide panel with
//! unblocked Householder reflections (each reflector applied to the
//! remaining panel columns in parallel over whole columns), accumulate
//! the panel's `T` matrix (`Q_panel = I − V T Vᵀ`), then apply the
//! blocked update `C ← C − V Tᵀ (Vᵀ C)` to the trailing columns through
//! the [`super::MatMul`] facade. All inner dot products run through the
//! runtime-dispatched micro-kernels ([`super::kernels`]), so the factor
//! is ISA-gated exactly like Cholesky; every parallel partition is a
//! fixed function of the shape, so the factor is **bit-identical** at
//! any `--threads` (asserted by `tests/parallel_determinism.rs`).
//!
//! The consumer in this crate is the sketched leverage-score tier
//! ([`crate::leverage`]): the `R` factor of the stacked matrix
//! `[B; √(λn)·I]` satisfies `RᵀR = BᵀB + λnI`, so the "small sketched
//! Gram solve" becomes one triangular solve against `Rᵀ` without ever
//! forming the Gram matrix — the numerically stable route when `B` is
//! ill-conditioned.

use super::{solve_lower_matrix, Matrix};
use crate::util::pool;

/// Panel width of the blocked factorization (narrower than Cholesky's
/// 96: QR panels pay two passes per reflector).
const NB: usize = 32;
/// Minimum multiply-adds in a panel-application stage before it
/// dispatches to the pool.
const PAR_MIN_STAGE: usize = 1 << 14;

/// A Householder QR factorization of an `m × k` matrix with `m ≥ k`.
///
/// Stored in the usual packed form: `R` occupies the upper triangle of
/// the factored matrix, the essential parts of the Householder vectors
/// sit below the diagonal (implicit unit diagonal), and `taus` holds the
/// reflector coefficients. [`QrFactor::r`] and [`QrFactor::thin_q`]
/// return the *sign-normalized* factors — `R` with a non-negative
/// diagonal and `Q` flipped to match — so that `R` agrees with the
/// (unique) upper Cholesky factor of `AᵀA` on full-rank inputs.
#[derive(Clone, Debug)]
pub struct QrFactor {
    /// Packed `R` + Householder vectors (`m × k`).
    packed: Matrix,
    /// Reflector coefficients `τ_j` (length `k`).
    taus: Vec<f64>,
    /// Row signs (±1) that make the normalized `R` diagonal
    /// non-negative; `thin_q` applies them to the matching columns.
    flips: Vec<f64>,
}

impl QrFactor {
    /// Number of rows `m` of the factored matrix.
    pub fn rows(&self) -> usize {
        self.packed.rows()
    }

    /// Number of columns `k` of the factored matrix.
    pub fn cols(&self) -> usize {
        self.packed.cols()
    }

    /// The `k × k` upper-triangular factor with a non-negative diagonal.
    pub fn r(&self) -> Matrix {
        let k = self.cols();
        Matrix::from_fn(k, k, |i, j| {
            if j < i {
                0.0
            } else {
                self.flips[i] * self.packed.get(i, j)
            }
        })
    }

    /// The thin orthonormal factor `Q` (`m × k`, `QᵀQ = I`), consistent
    /// with [`QrFactor::r`]: `A = Q·R` exactly (up to float).
    ///
    /// Built by applying the stored panels to the first `k` columns of
    /// the identity in reverse order, each through the same blocked
    /// `C ← C − V T (Vᵀ C)` update as the factorization — pool-parallel
    /// and bit-identical at any thread count.
    pub fn thin_q(&self) -> Matrix {
        let (m, k) = (self.rows(), self.cols());
        let kern = super::dispatch::kernels();
        let mut q = Matrix::zeros(m, k);
        for j in 0..k {
            q.set(j, j, 1.0);
        }
        let panel_starts: Vec<usize> = (0..k).step_by(NB).collect();
        for &pb in panel_starts.iter().rev() {
            let pe = (pb + NB).min(k);
            let (pm, pw) = (m - pb, pe - pb);
            // rebuild the column-major panel and its T matrix from the
            // packed storage — same values, same dot order as factor time
            let mut panel = vec![0.0; pm * pw];
            for c in 0..pw {
                for r in 0..pm {
                    panel[c * pm + r] = self.packed.get(pb + r, pb + c);
                }
            }
            let tmat = build_t(&panel, pm, pw, &self.taus[pb..pe], kern);
            let vmat = v_matrix(&panel, pm, pw);
            // gather the affected rows of Q, apply Q_panel = I − V T Vᵀ
            let mut c = Matrix::zeros(pm, k);
            for r in 0..pm {
                c.row_mut(r).copy_from_slice(q.row(pb + r));
            }
            let w = super::MatMul::tn().run(&vmat, &c);
            let mut w2 = super::MatMul::nn().run(&tmat, &w);
            w2.scale(-1.0);
            super::MatMul::nn().accumulate().run_into(&vmat, &w2, &mut c);
            for r in 0..pm {
                q.row_mut(pb + r).copy_from_slice(c.row(r));
            }
        }
        // sign normalization: Q·R = (Q·D)(D·R) with D = diag(flips)
        for r in 0..m {
            let row = q.row_mut(r);
            for (j, v) in row.iter_mut().enumerate() {
                *v *= self.flips[j];
            }
        }
        q
    }

    /// Solve `Rᵀ Z = B` (forward substitution against the normalized
    /// upper factor, `B` is `k × nrhs`) — the sketched-Gram solve shape:
    /// with `RᵀR = BᵀB + λnI`, the column squared norms of `Z = R⁻ᵀ Bᵀ`
    /// are the sketched leverage scores.
    pub fn solve_rt_matrix(&self, b: &Matrix) -> Matrix {
        let rt = self.r().transpose();
        solve_lower_matrix(&rt, b)
    }
}

/// Materialize the unit-lower-trapezoidal `V` (`pm × pw`) from a
/// column-major panel.
fn v_matrix(panel: &[f64], pm: usize, pw: usize) -> Matrix {
    Matrix::from_fn(pm, pw, |r, c| {
        if r < c {
            0.0
        } else if r == c {
            1.0
        } else {
            panel[c * pm + r]
        }
    })
}

/// Build the upper-triangular compact-WY `T` (`pw × pw`) of a factored
/// column-major panel: `T[j][j] = τ_j`,
/// `T[0..j, j] = −τ_j · T[0..j, 0..j] · (V[:,0..j]ᵀ v_j)`.
fn build_t(
    panel: &[f64],
    pm: usize,
    pw: usize,
    taus: &[f64],
    kern: &super::dispatch::MicroKernels,
) -> Matrix {
    let mut t = Matrix::zeros(pw, pw);
    for j in 0..pw {
        t.set(j, j, taus[j]);
        if j == 0 || taus[j] == 0.0 {
            continue;
        }
        // y[i] = V[:,i]ᵀ v_j  (v_j has an implicit 1 at row j)
        let mut y = vec![0.0; j];
        for (i, yi) in y.iter_mut().enumerate() {
            let vi = &panel[i * pm + j + 1..(i + 1) * pm];
            let vj = &panel[j * pm + j + 1..(j + 1) * pm];
            *yi = panel[i * pm + j] + (kern.dot)(vi, vj);
        }
        // T[0..j, j] = −τ_j · T_{0..j,0..j} · y  (small upper triangular
        // matvec, serial)
        for i in 0..j {
            let mut s = 0.0;
            for (p, &yp) in y.iter().enumerate().skip(i) {
                s += t.get(i, p) * yp;
            }
            t.set(i, j, -taus[j] * s);
        }
    }
    t
}

/// Blocked Householder QR, taking ownership of the input (`m ≥ k`
/// required; no clone on the success path — mirrors
/// [`super::cholesky_take`]).
///
/// Rank-deficient inputs factor fine (a zero column yields `τ = 0` and a
/// zero `R` diagonal entry); only the triangular *solves* against `R`
/// require full rank.
pub fn qr(mut a: Matrix) -> QrFactor {
    let (m, kc) = (a.rows(), a.cols());
    assert!(m >= kc && kc > 0, "qr requires a tall matrix (m ≥ k ≥ 1), got {m}×{kc}");
    let kern = super::dispatch::kernels();
    let mut taus = vec![0.0; kc];
    let ad = a.as_mut_slice();
    let mut panel: Vec<f64> = Vec::new();
    let mut pb = 0;
    while pb < kc {
        let pe = (pb + NB).min(kc);
        let (pm, pw) = (m - pb, pe - pb);
        // gather the panel column-major: column c of the panel holds
        // A[pb..m, pb+c]
        panel.clear();
        panel.resize(pm * pw, 0.0);
        for r in 0..pm {
            let row = &ad[(pb + r) * kc + pb..(pb + r) * kc + pe];
            for (c, &v) in row.iter().enumerate() {
                panel[c * pm + r] = v;
            }
        }
        // unblocked panel factorization
        for j in 0..pw {
            let (alpha, sigma) = {
                let col = &panel[j * pm + j..(j + 1) * pm];
                (col[0], (kern.dot)(&col[1..], &col[1..]))
            };
            let tau;
            if sigma == 0.0 {
                // already triangular in this column (LAPACK dlarfg
                // convention: no reflection, τ = 0, β = α)
                tau = 0.0;
            } else {
                let beta = -alpha.signum() * (alpha * alpha + sigma).sqrt();
                tau = (beta - alpha) / beta;
                let scale = 1.0 / (alpha - beta);
                let col = &mut panel[j * pm + j..(j + 1) * pm];
                col[0] = beta;
                for v in col[1..].iter_mut() {
                    *v *= scale;
                }
            }
            taus[pb + j] = tau;
            if tau == 0.0 || j + 1 == pw {
                continue;
            }
            // apply H_j = I − τ v vᵀ to the remaining panel columns —
            // whole columns are the parallel unit, so the partition (and
            // the bits) cannot depend on the thread count
            let vt = panel[j * pm + j + 1..(j + 1) * pm].to_vec();
            let rest = &mut panel[(j + 1) * pm..pw * pm];
            let work = (pw - j - 1) * (pm - j);
            pool::par_chunks_mut_gated(rest, pm, work >= PAR_MIN_STAGE, |_, col| {
                let w = col[j] + (kern.dot)(&col[j + 1..], &vt);
                let tw = tau * w;
                col[j] -= tw;
                for (cv, &vv) in col[j + 1..].iter_mut().zip(&vt) {
                    *cv -= tw * vv;
                }
            });
        }
        // trailing update: C ← C − V Tᵀ (Vᵀ C) applies
        // Qᵀ_panel = I − V Tᵀ Vᵀ to the columns right of the panel
        let tw_cols = kc - pe;
        if tw_cols > 0 {
            let tmat = build_t(&panel, pm, pw, &taus[pb..pe], kern);
            let vmat = v_matrix(&panel, pm, pw);
            let mut c = Matrix::zeros(pm, tw_cols);
            for r in 0..pm {
                c.row_mut(r).copy_from_slice(&ad[(pb + r) * kc + pe..(pb + r) * kc + kc]);
            }
            let w = super::MatMul::tn().run(&vmat, &c);
            let tt = tmat.transpose();
            let mut w2 = super::MatMul::nn().run(&tt, &w);
            w2.scale(-1.0);
            super::MatMul::nn().accumulate().run_into(&vmat, &w2, &mut c);
            for r in 0..pm {
                ad[(pb + r) * kc + pe..(pb + r) * kc + kc].copy_from_slice(c.row(r));
            }
        }
        // scatter the factored panel back (β on the diagonal → R, the
        // essential v parts below it)
        for r in 0..pm {
            let row = &mut ad[(pb + r) * kc + pb..(pb + r) * kc + pe];
            for (c, rv) in row.iter_mut().enumerate() {
                *rv = panel[c * pm + r];
            }
        }
        pb = pe;
    }
    let flips: Vec<f64> = (0..kc).map(|j| if a.get(j, j) < 0.0 { -1.0 } else { 1.0 }).collect();
    QrFactor { packed: a, taus, flips }
}

#[cfg(test)]
mod tests {
    use super::super::{cholesky, MatMul};
    use super::*;

    fn test_matrix(m: usize, k: usize, seed: u64) -> Matrix {
        Matrix::from_fn(m, k, |i, j| {
            let t = (i * k + j) as f64 + seed as f64 * 0.7;
            (t * 0.61803).sin() + if i == j { 2.0 } else { 0.0 }
        })
    }

    #[test]
    fn reconstructs_and_q_orthonormal() {
        // sizes straddling the NB=32 panel boundary and square/tall mixes
        for &(m, k) in &[(5usize, 3usize), (31, 31), (33, 32), (95, 64), (97, 96), (200, 97)] {
            let a = test_matrix(m, k, (m + k) as u64);
            let f = qr(a.clone());
            let (q, r) = (f.thin_q(), f.r());
            // R upper triangular with non-negative diagonal
            for i in 0..k {
                assert!(r.get(i, i) >= 0.0, "({m},{k}): negative R diagonal at {i}");
                for j in 0..i {
                    assert_eq!(r.get(i, j), 0.0, "({m},{k}): R not upper at ({i},{j})");
                }
            }
            // QᵀQ = I
            let qtq = MatMul::tn().run(&q, &q);
            let eye = Matrix::eye(k);
            assert!(qtq.max_abs_diff(&eye) < 1e-10, "({m},{k}): QᵀQ ≠ I");
            // A = QR
            let rec = MatMul::nn().run(&q, &r);
            let scale = a.fro_norm().max(1.0);
            assert!(rec.max_abs_diff(&a) / scale < 1e-12, "({m},{k}): A ≠ QR");
        }
    }

    #[test]
    fn r_matches_cholesky_of_gram() {
        // on a well-conditioned input, R equals the (unique) upper
        // Cholesky factor of AᵀA with positive diagonal
        let a = test_matrix(140, 40, 9);
        let r = qr(a.clone()).r();
        let gram = MatMul::tn().lower().run(&a, &a);
        let lc = cholesky(&gram).expect("Gram is SPD");
        let lt = lc.l().transpose();
        assert!(r.max_abs_diff(&lt) / lt.fro_norm() < 1e-10, "R ≠ chol(AᵀA)ᵀ");
    }

    #[test]
    fn stacked_regularized_gram_identity() {
        // the sketched-solve shape: R of [B; √δ·I] satisfies RᵀR = BᵀB + δI
        let b = test_matrix(90, 24, 4);
        let delta = 0.37;
        let mut stacked = Matrix::zeros(90 + 24, 24);
        for r in 0..90 {
            stacked.row_mut(r).copy_from_slice(b.row(r));
        }
        for j in 0..24 {
            stacked.set(90 + j, j, delta.sqrt());
        }
        let r = qr(stacked).r();
        let rtr = MatMul::tn().run(&r, &r);
        let mut gram = MatMul::tn().run(&b, &b);
        gram.add_scaled_identity(delta);
        assert!(rtr.max_abs_diff(&gram) / gram.fro_norm() < 1e-12);
    }

    #[test]
    fn solve_rt_matches_direct() {
        let b = test_matrix(60, 16, 2);
        let delta = 1.25;
        let mut stacked = Matrix::zeros(76, 16);
        for r in 0..60 {
            stacked.row_mut(r).copy_from_slice(b.row(r));
        }
        for j in 0..16 {
            stacked.set(60 + j, j, delta.sqrt());
        }
        let f = qr(stacked);
        let rhs = Matrix::from_fn(16, 5, |i, j| ((i * 5 + j) as f64 * 0.3).cos());
        let z = f.solve_rt_matrix(&rhs);
        // Rᵀ z = rhs
        let rt = f.r().transpose();
        let rec = MatMul::nn().run(&rt, &z);
        assert!(rec.max_abs_diff(&rhs) < 1e-9);
    }

    #[test]
    fn rank_deficient_panel_is_tolerated() {
        // a zero column mid-panel: τ = 0, R diagonal 0, no NaNs
        let mut a = test_matrix(50, 20, 3);
        for i in 0..50 {
            a.set(i, 7, 0.0);
        }
        let f = qr(a.clone());
        let (q, r) = (f.thin_q(), f.r());
        assert!(r.as_slice().iter().all(|v| v.is_finite()));
        let rec = MatMul::nn().run(&q, &r);
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "tall matrix")]
    fn wide_input_rejected() {
        let _ = qr(Matrix::zeros(3, 5));
    }
}
