//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Blocked **right-looking** algorithm on the shared
//! [`crate::util::pool`]: factor an `NB × NB` diagonal block serially
//! (unblocked), triangular-solve the column panel below it in parallel
//! over fixed row blocks, then apply a parallel lower-triangle-only
//! rank-`NB` Schur-complement update (`A₂₂ −= L₂₁ L₂₁ᵀ`) through the
//! same 4×8 dot-product micro-kernel as [`super::gemm_nt`]
//! ([`super::gemm`]'s `syrk` engine). Work partitions are fixed `MC`-row
//! blocks independent of the thread count, so the factor is
//! **bit-identical** at any `--threads` (asserted by
//! `tests/parallel_determinism.rs`).

use super::{
    solve_llt_matrix, solve_lower, solve_lower_matrix, solve_upper_from_lower,
    solve_upper_from_lower_matrix, Matrix,
};
use crate::util::pool;

/// Panel width for the blocked factorization.
const NB: usize = 96;
/// Row-block height of the parallel panel-TRSM / Schur stages (the unit
/// of work distribution; a multiple of the 4-row micro-kernel groups so
/// the tile/ragged split is partition-independent).
const MC: usize = 64;
/// Minimum multiply-adds in a stage before it dispatches to the pool.
const PAR_MIN_STAGE: usize = 1 << 15;

/// A lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
///
/// Wraps the factor together with the solve routines the leverage-score
/// and FALKON code paths need (`A⁻¹ b`, `L⁻¹ B`, quadratic forms). All
/// matrix solves run blocked and data-parallel over fixed column blocks
/// of the right-hand side (see [`super::solve_lower_matrix`]).
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Matrix,
}

impl CholeskyFactor {
    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Consume the factor, yielding the lower-triangular matrix `L`
    /// (strict upper triangle zero) without a copy — for consumers that
    /// operate on `L` directly, e.g. the sketched leverage-score
    /// estimators applying a sketch to the kernel square root.
    pub fn take_l(self) -> Matrix {
        self.l
    }

    /// Solve `A x = b` via two triangular solves.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_upper_from_lower(&self.l, &y)
    }

    /// Solve `L y = b` (forward substitution only).
    pub fn solve_l(&self, b: &[f64]) -> Vec<f64> {
        solve_lower(&self.l, b)
    }

    /// Solve `Lᵀ x = b` (back substitution against the stored lower factor).
    pub fn solve_lt(&self, b: &[f64]) -> Vec<f64> {
        solve_upper_from_lower(&self.l, b)
    }

    /// Solve `L Y = B` column-block-wise for a whole matrix `B`.
    pub fn solve_l_matrix(&self, b: &Matrix) -> Matrix {
        solve_lower_matrix(&self.l, b)
    }

    /// Solve `Lᵀ X = B` column-block-wise against the stored lower
    /// factor (no transpose is ever materialized).
    pub fn solve_lt_matrix(&self, b: &Matrix) -> Matrix {
        solve_upper_from_lower_matrix(&self.l, b)
    }

    /// Fused SPD solve `A X = B` (`= L⁻ᵀ L⁻¹ B`) for a matrix right-hand
    /// side: both triangular sweeps run per column block on one gathered
    /// buffer, so each block is copied in and out exactly once.
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        solve_llt_matrix(&self.l, b)
    }

    /// Quadratic form `bᵀ A⁻¹ b = ‖L⁻¹ b‖²`.
    pub fn quad_form(&self, b: &[f64]) -> f64 {
        let y = solve_lower(&self.l, b);
        super::norm2_sq(&y)
    }

    /// log-determinant of `A`: `2 Σ log L_ii`.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Cholesky factorization `A = L Lᵀ`; returns `None` if `A` is not
/// numerically positive definite.
pub fn cholesky(a: &Matrix) -> Option<CholeskyFactor> {
    let mut l = a.clone();
    cholesky_in_place(&mut l)?;
    Some(CholeskyFactor { l })
}

/// Cholesky factorization taking ownership of the input — no clone on
/// the success path.
///
/// On failure the partially-overwritten matrix is handed back: the
/// factorization only writes the **lower** triangle (the strict upper is
/// zeroed only on success), so for a symmetric input the caller can
/// rebuild the matrix from the intact strict upper triangle plus a saved
/// diagonal and retry — the jittered-retry loops of the FALKON
/// preconditioner and Nyström-KRR do exactly that instead of cloning the
/// `M × M` matrix per attempt.
pub fn cholesky_take(mut a: Matrix) -> Result<CholeskyFactor, Matrix> {
    match cholesky_in_place(&mut a) {
        Some(()) => Ok(CholeskyFactor { l: a }),
        None => Err(a),
    }
}

/// Cholesky with escalating diagonal jitter, entirely in place.
///
/// Factors `a` (symmetric, **exactly** — upper triangle mirrors lower);
/// if the factorization fails, the matrix is rebuilt from its intact
/// strict upper triangle plus the saved diagonal (see [`cholesky_take`])
/// with `jitter` added — starting at `base` (floored at `1e-300`) and
/// multiplying by 100 per attempt — so no `n × n` clone is ever made.
/// Returns the factor and the jitter that succeeded (`0.0` when none was
/// needed), or `None` once the jitter reaches `limit`. This is the
/// shared retry loop of the FALKON preconditioner (`K_MM` from close-by
/// or duplicate centers can be numerically rank-deficient) and the
/// Nyström-KRR normal equations.
///
/// **Precondition:** `a` must be *bitwise* symmetric — the retry path
/// reconstructs the lower triangle from the upper, so any asymmetry
/// would silently change the matrix being factored (checked by a
/// `debug_assert`).
pub fn cholesky_jittered(mut a: Matrix, base: f64, limit: f64) -> Option<(CholeskyFactor, f64)> {
    let n = a.rows();
    debug_assert!(
        {
            let ad = a.as_slice();
            (0..n).all(|i| (0..i).all(|j| ad[i * n + j].to_bits() == ad[j * n + i].to_bits()))
        },
        "cholesky_jittered requires a bitwise-symmetric matrix"
    );
    let diag0 = a.diagonal();
    let mut jitter = 0.0;
    loop {
        match cholesky_take(a) {
            Ok(f) => return Some((f, jitter)),
            Err(mut spoiled) => {
                jitter = if jitter == 0.0 { base.max(1e-300) } else { jitter * 100.0 };
                if jitter >= limit {
                    return None;
                }
                let sd = spoiled.as_mut_slice();
                for i in 0..n {
                    for j in 0..i {
                        sd[i * n + j] = sd[j * n + i];
                    }
                    sd[i * n + i] = diag0[i] + jitter;
                }
                a = spoiled;
            }
        }
    }
}

/// In-place blocked Cholesky: on success the lower triangle of `a` holds
/// `L` and the strict upper triangle is zeroed.
///
/// Right-looking blocked sweep, one `NB`-wide panel at a time:
///
/// 1. **diagonal factor** (serial): unblocked Cholesky of
///    `A[kb..ke, kb..ke]`, rejecting non-SPD pivots;
/// 2. **panel TRSM** (parallel): `L₂₁ = A₂₁ L₁₁⁻ᵀ` — each trailing row
///    forward-substitutes against the diagonal block independently,
///    distributed over fixed `MC`-row blocks;
/// 3. **Schur update** (parallel): `A₂₂ −= L₂₁ L₂₁ᵀ`, lower triangle
///    only, through the 4×8 register micro-kernel
///    ([`super::gemm`]'s `syrk` engine) with the panel staged
///    contiguously once per sweep.
///
/// Every element's floating-point sequence is a pure function of the
/// problem shape — never of the thread count — so parallel factors are
/// bit-identical to `--threads 1`. On failure (non-SPD) only the lower
/// triangle has been modified; see [`cholesky_take`].
pub fn cholesky_in_place(a: &mut Matrix) -> Option<()> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky requires a square matrix");
    let kern = super::dispatch::kernels();
    let ad = a.as_mut_slice();
    // contiguous staging for the current L₂₁ panel, reused across sweeps
    let mut panel: Vec<f64> = Vec::new();
    let mut kb = 0;
    while kb < n {
        let ke = (kb + NB).min(n);
        let w = ke - kb;
        // 1. unblocked factor of the diagonal block A[kb..ke, kb..ke]
        for j in kb..ke {
            let rj = j * n;
            let d = ad[rj + j] - (kern.dot)(&ad[rj + kb..rj + j], &ad[rj + kb..rj + j]);
            if d <= 0.0 || !d.is_finite() {
                return None;
            }
            let djj = d.sqrt();
            ad[rj + j] = djj;
            for i in (j + 1)..ke {
                let ri = i * n;
                let s = (kern.dot)(&ad[ri + kb..ri + j], &ad[rj + kb..rj + j]);
                ad[ri + j] = (ad[ri + j] - s) / djj;
            }
        }
        let trailing = n - ke;
        if trailing == 0 {
            break;
        }
        // 2. panel TRSM: rows ke..n forward-substitute columns kb..ke
        //    against L₁₁ — rows are independent, so the pool distributes
        //    fixed MC-row blocks of the trailing rows.
        {
            let (head, tail) = ad.split_at_mut(ke * n);
            let trsm_work = trailing * w * w / 2;
            pool::par_chunks_mut_gated(tail, MC * n, trsm_work >= PAR_MIN_STAGE, |_, chunk| {
                for row in chunk.chunks_mut(n) {
                    for j in kb..ke {
                        let rj = j * n;
                        let s = (kern.dot)(&row[kb..j], &head[rj + kb..rj + j]);
                        row[j] = (row[j] - s) / head[rj + j];
                    }
                }
            });
        }
        // 3. Schur complement: A[ke.., ke..] −= L₂₁ L₂₁ᵀ (lower triangle
        //    only). The panel is staged contiguously so every micro-kernel
        //    stream is sequential; each output element is its own dot
        //    product of two panel rows, so any fixed partition yields the
        //    serial bits.
        panel.clear();
        panel.reserve(trailing * w);
        for i in ke..n {
            panel.extend_from_slice(&ad[i * n + kb..i * n + ke]);
        }
        let tail = &mut ad[ke * n..];
        let schur_work = trailing * trailing * w / 2;
        pool::par_chunks_mut_gated(tail, MC * n, schur_work >= PAR_MIN_STAGE, |blk, chunk| {
            super::gemm::syrk_ln_panel(kern, &panel, chunk, blk * MC, w, n, ke, -1.0);
        });
        kb = ke;
    }
    // zero the strict upper triangle so the factor is clean
    for i in 0..n {
        for j in (i + 1)..n {
            ad[i * n + j] = 0.0;
        }
    }
    Some(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;

    /// Random-ish SPD matrix: A = M Mᵀ + n·I.
    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        };
        let m = Matrix::from_fn(n, n, |_, _| next());
        let mut a = gemm(&m, &m.transpose());
        a.add_scaled_identity(n as f64 * 0.1 + 1.0);
        a
    }

    #[test]
    fn factor_reconstructs_spd() {
        // sizes straddling the NB panel boundary (95/96/97) and with a
        // multi-panel tail (131, 200)
        for &n in &[1usize, 2, 5, 17, 48, 49, 95, 96, 97, 100, 131, 200] {
            let a = spd(n, n as u64);
            let f = cholesky(&a).expect("SPD must factor");
            let rec = gemm(f.l(), &f.l().transpose());
            let err = rec.max_abs_diff(&a) / a.fro_norm().max(1.0);
            assert!(err < 1e-10, "n={n}: reconstruction error {err}");
        }
    }

    #[test]
    fn factor_matches_unblocked_reference() {
        // textbook unblocked Cholesky as an independent oracle
        for &n in &[33usize, 96, 113] {
            let a = spd(n, 1000 + n as u64);
            let f = cholesky(&a).unwrap();
            let mut r = Matrix::zeros(n, n);
            for j in 0..n {
                let mut d = a.get(j, j);
                for p in 0..j {
                    d -= r.get(j, p) * r.get(j, p);
                }
                let djj = d.sqrt();
                r.set(j, j, djj);
                for i in (j + 1)..n {
                    let mut s = a.get(i, j);
                    for p in 0..j {
                        s -= r.get(i, p) * r.get(j, p);
                    }
                    r.set(i, j, s / djj);
                }
            }
            assert!(f.l().max_abs_diff(&r) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let n = 73;
        let a = spd(n, 3);
        let f = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let x = f.solve(&b);
        // check A x ≈ b
        let ax = crate::linalg::matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8, "residual too large");
        }
    }

    #[test]
    fn solve_matrix_is_fused_two_stage_solve() {
        let n = 57;
        let a = spd(n, 13);
        let f = cholesky(&a).unwrap();
        let b = Matrix::from_fn(n, 9, |i, j| ((i * 9 + j) as f64 * 0.31).sin());
        let x = f.solve_matrix(&b);
        // matches the vector solve column by column
        for j in 0..9 {
            let xj = f.solve(&b.col(j));
            for i in 0..n {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-9, "col {j} row {i}");
            }
        }
        // and A X ≈ B
        let ax = gemm(&a, &x);
        assert!(ax.max_abs_diff(&b) < 1e-7);
    }

    #[test]
    fn quad_form_is_bt_ainv_b() {
        let n = 29;
        let a = spd(n, 7);
        let f = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| ((i * i) as f64).cos()).collect();
        let x = f.solve(&b);
        let direct = crate::linalg::dot(&b, &x);
        assert!((f.quad_form(&b) - direct).abs() < 1e-8);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn cholesky_take_failure_preserves_strict_upper() {
        // a symmetric matrix that fails mid-factorization (SPD leading
        // block, then an indefinite trailing part)
        let n = 120;
        let mut a = spd(n, 17);
        let v = a.get(n - 1, n - 1);
        a.set(n - 1, n - 1, -v); // break positive definiteness at the end
        let orig = a.clone();
        match cholesky_take(a) {
            Ok(_) => panic!("must not factor"),
            Err(ruined) => {
                // strict upper triangle is untouched by the failed attempt
                for i in 0..n {
                    for j in (i + 1)..n {
                        assert_eq!(
                            ruined.get(i, j).to_bits(),
                            orig.get(i, j).to_bits(),
                            "({i},{j}) modified"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn jittered_rescues_singular_and_gives_up_at_limit() {
        // exactly singular PSD: duplicate first and last rows/columns
        let n = 40;
        let mut a = spd(n, 5);
        for j in 0..n {
            a.set(n - 1, j, a.get(0, j));
        }
        for i in 0..n {
            a.set(i, n - 1, a.get(i, 0));
        }
        a.set(n - 1, n - 1, a.get(0, 0));
        let trace: f64 = a.diagonal().iter().sum();
        let (f, _jitter) =
            cholesky_jittered(a, trace * 1e-12 / n as f64, trace).expect("jitter must rescue");
        assert!(f.l().as_slice().iter().all(|v| v.is_finite()));
        // hopeless: −I needs jitter > 1, but the limit caps it at 1
        let mut neg = Matrix::eye(6);
        neg.scale(-1.0);
        assert!(cholesky_jittered(neg, 1e-12, 1.0).is_none());
    }

    #[test]
    fn identity_factors_to_identity() {
        let f = cholesky(&Matrix::eye(5)).unwrap();
        assert!(f.l().max_abs_diff(&Matrix::eye(5)) < 1e-14);
        assert!((f.log_det() - 0.0).abs() < 1e-14);
    }

    #[test]
    fn solve_lt_transpose_consistency() {
        let n = 21;
        let a = spd(n, 11);
        let f = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| i as f64 * 0.3 - 2.0).collect();
        // L (Lᵀ)⁻¹ᵀ? — check L Lᵀ x = b path equals solve()
        let y = f.solve_l(&b);
        let x = f.solve_lt(&y);
        let x2 = f.solve(&b);
        for (u, v) in x.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_lt_matrix_matches_vector_solves() {
        let n = 41;
        let a = spd(n, 19);
        let f = cholesky(&a).unwrap();
        let b = Matrix::from_fn(n, 7, |i, j| ((i + 2 * j) % 13) as f64 * 0.5 - 3.0);
        let x = f.solve_lt_matrix(&b);
        for j in 0..7 {
            let xj = f.solve_lt(&b.col(j));
            for i in 0..n {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-10, "col {j} row {i}");
            }
        }
    }
}
