//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Blocked right-looking algorithm: factor a diagonal panel, triangular-
//! solve the column panel below it, then a (lower-triangle-only) Schur
//! complement update. The update is the GEMM-shaped hot loop and uses the
//! same streaming inner loop as [`super::gemm`].

use super::{solve_lower, solve_lower_matrix, Matrix};

/// Panel width for the blocked factorization.
const NB: usize = 96;

/// A lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
///
/// Wraps the factor together with the solve routines the leverage-score
/// and FALKON code paths need (`A⁻¹ b`, `L⁻¹ B`, quadratic forms).
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Matrix,
}

impl CholeskyFactor {
    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Solve `A x = b` via two triangular solves.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_upper_from_lower(&self.l, &y)
    }

    /// Solve `L y = b` (forward substitution only).
    pub fn solve_l(&self, b: &[f64]) -> Vec<f64> {
        solve_lower(&self.l, b)
    }

    /// Solve `Lᵀ x = b` (back substitution against the stored lower factor).
    pub fn solve_lt(&self, b: &[f64]) -> Vec<f64> {
        solve_upper_from_lower(&self.l, b)
    }

    /// Solve `L Y = B` column-block-wise for a whole matrix `B`.
    pub fn solve_l_matrix(&self, b: &Matrix) -> Matrix {
        solve_lower_matrix(&self.l, b)
    }

    /// Quadratic form `bᵀ A⁻¹ b = ‖L⁻¹ b‖²`.
    pub fn quad_form(&self, b: &[f64]) -> f64 {
        let y = solve_lower(&self.l, b);
        super::norm2_sq(&y)
    }

    /// log-determinant of `A`: `2 Σ log L_ii`.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Back substitution `Lᵀ x = b` reading the *lower* factor row-wise.
fn solve_upper_from_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    let ld = l.as_slice();
    for i in (0..n).rev() {
        let xi = x[i] / ld[i * n + i];
        x[i] = xi;
        // propagate: x[j] -= L[i][j] * xi for j < i  (column i of Lᵀ)
        let row = &ld[i * n..i * n + i];
        for (xj, lij) in x[..i].iter_mut().zip(row.iter()) {
            *xj -= lij * xi;
        }
    }
    x
}

/// Cholesky factorization `A = L Lᵀ`; returns `None` if `A` is not
/// numerically positive definite.
pub fn cholesky(a: &Matrix) -> Option<CholeskyFactor> {
    let mut l = a.clone();
    cholesky_in_place(&mut l)?;
    Some(CholeskyFactor { l })
}

/// In-place blocked Cholesky: on success the lower triangle of `a` holds
/// `L` and the strict upper triangle is zeroed.
pub fn cholesky_in_place(a: &mut Matrix) -> Option<()> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky requires a square matrix");
    let ad = a.as_mut_slice();
    let mut kb = 0;
    while kb < n {
        let ke = (kb + NB).min(n);
        // 1. factor the diagonal panel A[kb..ke, kb..ke] (unblocked)
        for j in kb..ke {
            let mut d = ad[j * n + j];
            for p in kb..j {
                d -= ad[j * n + p] * ad[j * n + p];
            }
            if d <= 0.0 || !d.is_finite() {
                return None;
            }
            let djj = d.sqrt();
            ad[j * n + j] = djj;
            // update column j below the diagonal with the panel
            // contribution [kb..j), then divide by the pivot
            for i in (j + 1)..n {
                let mut s = ad[i * n + j];
                for p in kb..j {
                    s -= ad[i * n + p] * ad[j * n + p];
                }
                ad[i * n + j] = s / djj;
            }
        }
        // 2. Schur complement update of the trailing matrix:
        //    A[ke.., ke..] -= L[ke.., kb..ke] * L[ke.., kb..ke]ᵀ
        //    (lower triangle only). Row i's panel segment is staged in a
        //    local buffer so the inner product runs through the 4-way
        //    unrolled `dot` kernel (§Perf: 1.9 → 4.6 GF/s on chol-512).
        let w = ke - kb;
        let mut rowi = [0.0f64; NB];
        for i in ke..n {
            let ri = i * n;
            rowi[..w].copy_from_slice(&ad[ri + kb..ri + ke]);
            for j in ke..=i {
                let rj = j * n;
                let s = super::dot(&rowi[..w], &ad[rj + kb..rj + ke]);
                ad[ri + j] -= s;
            }
        }
        kb = ke;
    }
    // zero the strict upper triangle so the factor is clean
    for i in 0..n {
        for j in (i + 1)..n {
            ad[i * n + j] = 0.0;
        }
    }
    Some(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;

    /// Random-ish SPD matrix: A = M Mᵀ + n·I.
    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        };
        let m = Matrix::from_fn(n, n, |_, _| next());
        let mut a = gemm(&m, &m.transpose());
        a.add_scaled_identity(n as f64 * 0.1 + 1.0);
        a
    }

    #[test]
    fn factor_reconstructs_spd() {
        for &n in &[1usize, 2, 5, 17, 48, 49, 100, 131] {
            let a = spd(n, n as u64);
            let f = cholesky(&a).expect("SPD must factor");
            let rec = gemm(f.l(), &f.l().transpose());
            let err = rec.max_abs_diff(&a) / a.fro_norm().max(1.0);
            assert!(err < 1e-10, "n={n}: reconstruction error {err}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let n = 73;
        let a = spd(n, 3);
        let f = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let x = f.solve(&b);
        // check A x ≈ b
        let ax = crate::linalg::matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8, "residual too large");
        }
    }

    #[test]
    fn quad_form_is_bt_ainv_b() {
        let n = 29;
        let a = spd(n, 7);
        let f = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| ((i * i) as f64).cos()).collect();
        let x = f.solve(&b);
        let direct = crate::linalg::dot(&b, &x);
        assert!((f.quad_form(&b) - direct).abs() < 1e-8);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn identity_factors_to_identity() {
        let f = cholesky(&Matrix::eye(5)).unwrap();
        assert!(f.l().max_abs_diff(&Matrix::eye(5)) < 1e-14);
        assert!((f.log_det() - 0.0).abs() < 1e-14);
    }

    #[test]
    fn solve_lt_transpose_consistency() {
        let n = 21;
        let a = spd(n, 11);
        let f = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| i as f64 * 0.3 - 2.0).collect();
        // L (Lᵀ)⁻¹ᵀ? — check L Lᵀ x = b path equals solve()
        let y = f.solve_l(&b);
        let x = f.solve_lt(&y);
        let x2 = f.solve(&b);
        for (u, v) in x.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-10);
        }
    }
}
