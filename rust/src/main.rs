//! `repro` — the BLESS reproduction CLI (Layer-3 leader binary).
//!
//! Subcommands regenerate every table/figure of the paper and expose the
//! library's two main entry points (`bless`, `falkon`) directly:
//!
//! ```text
//! repro fig1   [--n 2000] [--lambda 1e-4] [--reps 5] [--engine auto]
//! repro fig2   [--sizes 1000,2000,4000,8000] [--lambda 1e-3]
//! repro fig3   [--n 4000] [--iters 5]
//! repro fig4   [--n 8000]            # SUSY-like end-to-end
//! repro fig5   [--n 8000]            # HIGGS-like end-to-end
//! repro table1 [--sizes ...] [--lambda 1e-3]
//! repro bless  [--n 4000] [--lambda 1e-4] [--method bless|bless-r|...]
//! repro info                         # runtime / artifact diagnostics
//! ```

use bless::coordinator::{
    build_engine, fig1_accuracy, fig2_scaling, fig3_stability, fig45_falkon,
    scaling_exponent, table1_complexity, EngineKind, Fig1Config, Fig2Config, Fig3Config,
    Fig45Config, Method, Table1Config,
};
use bless::data::{higgs_like, susy_like};
use bless::kernels::Gaussian;
use bless::rng::Rng;
use bless::util::cli::Args;
use bless::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let cmd = args.pos(0).unwrap_or("help").to_string();
    match cmd.as_str() {
        "fig1" => cmd_fig1(&args),
        "fig2" => cmd_fig2(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig45(&args, false),
        "fig5" => cmd_fig45(&args, true),
        "table1" => cmd_table1(&args),
        "bless" => cmd_bless(&args),
        "falkon" => cmd_fig45(&args, false),
        "info" => cmd_info(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
repro — BLESS (NeurIPS 2018) reproduction CLI

  fig1    leverage-score R-ACC comparison table (paper Fig. 1)
  fig2    runtime-vs-n sweep (paper Fig. 2)
  fig3    lambda_falkon stability sweep (paper Fig. 3)
  fig4    FALKON-BLESS vs FALKON-UNI on SUSY-like data (paper Fig. 4)
  fig5    same on HIGGS-like data (paper Fig. 5)
  table1  empirical complexity exponents (paper Table 1)
  bless   run one sampler and report the selected set
  info    PJRT runtime / artifact diagnostics

common flags: --n --lambda --sigma --seed --reps --engine native|xla|auto
              --csv <path> (also save the result table as CSV)
";

fn engine_kind(args: &Args) -> EngineKind {
    EngineKind::parse(&args.get_str("engine", "native")).unwrap_or(EngineKind::Native)
}

fn maybe_csv(args: &Args, table: &bless::util::table::Table) -> anyhow::Result<()> {
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("(saved CSV to {path})");
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> anyhow::Result<()> {
    let cfg = Fig1Config {
        n: args.get_usize("n", 2_000),
        sigma: args.get_f64("sigma", 4.0),
        lambda: args.get_f64("lambda", 1e-4),
        reps: args.get_usize("reps", 5),
        seed: args.get_u64("seed", 0),
        uniform_m: args.get_usize("uniform-m", 400),
        ..Default::default()
    };
    let ds = susy_like(cfg.n, &mut Rng::seeded(cfg.seed.wrapping_add(77)));
    let eng = build_engine(engine_kind(args), ds.x, Gaussian::new(cfg.sigma))?;
    println!("engine backend: {}", eng.label());
    let t = fig1_accuracy(eng.as_dyn(), &cfg);
    println!("{}", t.to_console());
    maybe_csv(args, &t)
}

fn parse_sizes(args: &Args, default: &[usize]) -> Vec<usize> {
    args.get("sizes")
        .map(|s| s.split(',').map(|v| v.trim().parse().expect("bad --sizes")).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn cmd_fig2(args: &Args) -> anyhow::Result<()> {
    let cfg = Fig2Config {
        sizes: parse_sizes(args, &[1_000, 2_000, 4_000, 8_000]),
        lambda: args.get_f64("lambda", 1e-3),
        sigma: args.get_f64("sigma", 4.0),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let t = fig2_scaling(&cfg);
    println!("{}", t.to_console());
    for &m in &cfg.methods {
        println!("  {:<10} empirical n-exponent: {}", m.name(), fnum(scaling_exponent(&t, m)));
    }
    maybe_csv(args, &t)
}

fn cmd_fig3(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 4_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = susy_like(n, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let cfg = Fig3Config {
        sigma: args.get_f64("sigma", 4.0),
        lambda_bless: args.get_f64("lambda-bless", 1e-3),
        iterations: args.get_usize("iters", 5),
        seed,
        ..Default::default()
    };
    let eng = build_engine(engine_kind(args), train.x.clone(), Gaussian::new(cfg.sigma))?;
    let res = fig3_stability(eng.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", res.table.to_console());
    println!(
        "95%-optimal region width: BLESS {} decades, UNI {} decades",
        fnum(res.bless_region_decades),
        fnum(res.uni_region_decades)
    );
    maybe_csv(args, &res.table)
}

fn cmd_fig45(args: &Args, higgs: bool) -> anyhow::Result<()> {
    let n = args.get_usize("n", 8_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = if higgs { higgs_like(n, &mut rng) } else { susy_like(n, &mut rng) };
    let (train, test) = ds.split(0.25, &mut rng);
    let mut cfg = if higgs { Fig45Config::higgs() } else { Fig45Config::susy() };
    cfg.iterations = args.get_usize("iters", cfg.iterations);
    cfg.lambda_bless = args.get_f64("lambda-bless", cfg.lambda_bless);
    cfg.lambda_falkon = args.get_f64("lambda-falkon", cfg.lambda_falkon);
    cfg.seed = seed;
    let eng = build_engine(engine_kind(args), train.x.clone(), Gaussian::new(cfg.sigma))?;
    println!("engine backend: {} | train n={} test n={}", eng.label(), train.n(), test.n());
    let (b, u, table) = fig45_falkon(eng.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", table.to_console());
    println!(
        "{}: M={} final AUC {} ({}s sampling)",
        b.label,
        b.centers,
        fnum(b.final_auc()),
        fnum(b.sampling_secs)
    );
    println!("{}: M={} final AUC {}", u.label, u.centers, fnum(u.final_auc()));
    let target = u.final_auc();
    if let Some(it) = b.iters_to_reach(target) {
        println!(
            "FALKON-BLESS reaches FALKON-UNI's final AUC ({}) at iteration {it}/{}",
            fnum(target),
            cfg.iterations
        );
    }
    maybe_csv(args, &table)
}

fn cmd_table1(args: &Args) -> anyhow::Result<()> {
    let cfg = Table1Config {
        sizes: parse_sizes(args, &[1_000, 2_000, 4_000, 8_000]),
        lambda: args.get_f64("lambda", 1e-3),
        sigma: args.get_f64("sigma", 4.0),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let (raw, summary) = table1_complexity(&cfg);
    println!("{}", raw.to_console());
    println!("{}", summary.to_console());
    maybe_csv(args, &summary)
}

fn cmd_bless(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 4_000);
    let lambda = args.get_f64("lambda", 1e-4);
    let method = Method::parse(&args.get_str("method", "bless"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let seed = args.get_u64("seed", 0);
    let ds = susy_like(n, &mut Rng::seeded(seed));
    let eng =
        build_engine(engine_kind(args), ds.x, Gaussian::new(args.get_f64("sigma", 4.0)))?;
    let mut rng = Rng::seeded(seed ^ 1);
    let t0 = std::time::Instant::now();
    let (set, evals) = bless::coordinator::run_method(
        method,
        eng.as_dyn(),
        lambda,
        (1.0 / lambda) as usize,
        &mut rng,
    );
    println!(
        "{} @ λ={lambda:.1e} n={n}: |J|={} score_evals={evals} time={:.2}s (engine {})",
        method.name(),
        set.len(),
        t0.elapsed().as_secs_f64(),
        eng.label()
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    match bless::runtime::find_artifact_dir() {
        None => println!("artifacts: NOT FOUND (run `make artifacts`)"),
        Some(dir) => {
            println!("artifacts: {}", dir.display());
            let rt = bless::runtime::PjrtRuntime::load(&dir)?;
            println!("platform: {}", rt.platform());
            println!(
                "tile: {}x{} (feature dim {})",
                rt.manifest.tile, rt.manifest.tile, rt.manifest.feature_dim
            );
            println!("artifacts compiled: {:?}", rt.artifact_names());
        }
    }
    Ok(())
}
