//! `repro` — the BLESS reproduction CLI (Layer-3 leader binary).
//!
//! Subcommands regenerate every table/figure of the paper and expose the
//! library's two main entry points (`bless`, `falkon`) directly:
//!
//! ```text
//! repro fig1   [--n 2000] [--lambda 1e-4] [--reps 5] [--engine auto]
//! repro fig2   [--sizes 1000,2000,4000,8000] [--lambda 1e-3]
//! repro fig3   [--n 4000] [--iters 5]
//! repro fig4   [--n 8000]            # SUSY-like end-to-end
//! repro fig5   [--n 8000]            # HIGGS-like end-to-end
//! repro table1 [--sizes ...] [--lambda 1e-3]
//! repro bless  [--n 4000] [--lambda 1e-4] [--method bless|bless-r|...]
//! repro train   [--n 8000] [--dataset susy|higgs] [--save model.bin]
//!               [--checkpoint fit.ckpt [--checkpoint-every 2] [--resume]]
//! repro predict --model model.bin [--query "x1,x2,..."] [--queries file.csv]
//! repro serve   --models susy=a.bin,higgs=b.bin [--port 7878] [--workers 2]
//!               [--max-batch 64] [--max-queue 1024] [--retrain-every 60]
//! repro convert --in model.json --out model.bin   # JSON ↔ binary
//! repro info                         # runtime / artifact diagnostics
//! ```

use bless::coordinator::{
    build_engine, fig1_accuracy, fig1_estimator_shootout, fig2_estimator_scaling, fig2_scaling,
    fig3_stability, fig45_falkon, scaling_exponent, scaling_exponent_for, table1_complexity,
    EngineKind, Fig1Config, Fig2Config, Fig3Config, Fig45Config, Method, ShootoutConfig,
    Table1Config,
};
use bless::data::{higgs_like, susy_like};
use bless::falkon::{CheckpointSpec, Falkon, FitOptions};
use bless::kernels::{Gaussian, NativeEngine};
use bless::leverage::WeightedSet;
use bless::lifecycle::{HoldoutGate, LifecycleConfig, RetrainScheduler};
use bless::rng::Rng;
use bless::serve::{Format, ModelArtifact, ModelSpec, Predictor, ServeConfig};
use bless::util::cli::Args;
use bless::util::json::Json;
use bless::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    // One process, one thread policy: every compute kernel (GEMM, kernel
    // blocks, triangular solves) dispatches through the shared pool.
    // Default (0) = all available cores; results are bit-identical at
    // any thread count.
    bless::util::pool::set_threads(args.get_usize("threads", 0));
    // SIMD backend for the linalg micro-kernels: --isa scalar|avx2|auto
    // beats the BLESS_ISA env var, which beats auto-detection. Results
    // may differ by ISA within the documented accuracy gates, never by
    // thread count.
    if let Some(isa) = args.get("isa") {
        bless::linalg::set_isa_from_str(isa).map_err(|e| anyhow::anyhow!("--isa: {e}"))?;
    }
    let cmd = args.pos(0).unwrap_or("help").to_string();
    match cmd.as_str() {
        "fig1" => cmd_fig1(&args),
        "fig2" => cmd_fig2(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig45(&args, false),
        "fig5" => cmd_fig45(&args, true),
        "table1" => cmd_table1(&args),
        "bless" => cmd_bless(&args),
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "convert" => cmd_convert(&args),
        "falkon" => {
            eprintln!(
                "note: `repro falkon` is deprecated (it used to alias fig4); \
                 running `repro train` — use `train` directly"
            );
            cmd_train(&args)
        }
        "info" => cmd_info(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
repro — BLESS (NeurIPS 2018) reproduction CLI

  fig1    leverage-score R-ACC comparison table (paper Fig. 1)
  fig2    runtime-vs-n sweep (paper Fig. 2)
  fig3    lambda_falkon stability sweep (paper Fig. 3)
  fig4    FALKON-BLESS vs FALKON-UNI on SUSY-like data (paper Fig. 4)
  fig5    same on HIGGS-like data (paper Fig. 5)
  table1  empirical complexity exponents (paper Table 1)
  bless   run one sampler and report the selected set
  train   BLESS + FALKON end-to-end; --save <path> writes a model artifact
          (.bin/.bless → binary codec, anything else → JSON)
  predict score queries offline with a saved model (--model <path>)
  serve   TCP prediction server: one model (--model <path>) or a named
          registry (--models name=path,name2=path2) with hot reload
  convert re-encode an artifact between JSON and binary (--in --out)
  info    PJRT runtime / artifact diagnostics

  (`falkon` is a deprecated alias for `train`; it used to re-run fig4)

fig1/fig2 flags: --estimators exact,bless,rrls,count-sketch:256,srft:256,
               rls-nystrom:256 (or `default`) — append the leverage-score
               estimator-family shoot-out: accuracy vs wall-clock vs
               metered kernel evals vs peak workspace per estimator
common flags:  --n --lambda --sigma --seed --reps --engine native|xla|auto
               --threads N (compute threadpool width; default = all cores;
               output is bit-identical at any N)
               --isa scalar|avx2|auto (linalg micro-kernel backend; also
               the BLESS_ISA env var; default auto-detects AVX2+FMA —
               results may differ by ISA within tested accuracy gates,
               never by thread count)
               --csv <path> (also save the result table as CSV)
train flags:   --dataset susy|higgs --lambda-bless --lambda-falkon --iters --save
               --mem-budget MB (K_nM panel-cache budget; cached tiles are
               evaluated once per fit instead of once per CG iteration;
               0 = pure streaming; default = RAM/4 — results are
               bit-identical at any budget)
               --trace [--trace-out trace.json] (span profile over BLESS
               levels, preconditioner phases and CG iterations, plus
               counters; observation only — results stay bit-identical)
               --verbose (per-iteration CG residual table + panel traffic)
               --checkpoint PATH [--checkpoint-every K] [--resume]
               (crash-tolerant fits: the full CG state lands in a
               BLESSCKPT file every K iterations via atomic rename;
               --resume continues bit-identically where a killed run
               stopped — damage or a problem mismatch cold-starts)
               --tol T (CG early-stop on the relative residual; 0 = run
               all --iters, the paper-faithful fixed-iteration regime)
serve flags:   --host --port --workers --max-batch --linger-us --cache
               --cache-quant --max-queue (0 = unbounded; default 1024)
               --threads (shared compute pool for all models' batch GEMMs;
               --workers controls batching concurrency per model)
               --metrics-addr host:port | --metrics-port N (HTTP GET
               /metrics, /healthz, /varz on a separate listener; off by
               default)
               --default-deadline MS (deadline for requests without their
               own deadline_ms; 0 = wait forever; default 0)
               --io-timeout-ms N (per-connection socket read/write timeout,
               the slowloris defense; 0 disables; default 30000)
               --breaker-threshold K (consecutive worker failures that
               quarantine a model; 0 disables; default 8)
               --breaker-cooldown-ms N (open-state dwell before a half-open
               probe; default 1000)
               --stats-file PATH (persist per-model counters + histograms
               on shutdown, restore on start)
               --stats-flush-secs N (also flush that snapshot every N
               seconds while serving; requires --stats-file)
               --retrain-every SECS (continuous-training lifecycle: refit
               on drifting synthetic labels in the background, gate each
               candidate on a fixed holdout RMSE, promote or quarantine,
               and auto-rollback a promotion whose breaker trips inside
               the probation window; needs exactly one disk-backed
               --model — knobs: --retrain-n 2000 --retrain-centers 100
               --retrain-iters 40 --retrain-tol 1e-6 --retrain-lambda
               1e-5 --drift 0.02 --gate-tolerance 0.05
               --probation-secs 5)
               --faults \"conn.delay:p=0.05,ms=200;worker.panic:p=0.01\"
               (seeded fault injection for chaos testing; also the
               BLESS_FAULTS env var — the flag wins; add seed=N to the
               spec for deterministic replay; off by default and zero-cost
               when off)
convert flags: --in <path> --out <path> [--format json|binary] (default: by
               --out extension)
";

fn engine_kind(args: &Args) -> EngineKind {
    EngineKind::parse(&args.get_str("engine", "native")).unwrap_or(EngineKind::Native)
}

fn maybe_csv(args: &Args, table: &bless::util::table::Table) -> anyhow::Result<()> {
    if let Some(path) = args.get("csv") {
        table.save_csv(path)?;
        println!("(saved CSV to {path})");
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> anyhow::Result<()> {
    let cfg = Fig1Config {
        n: args.get_usize("n", 2_000),
        sigma: args.get_f64("sigma", 4.0),
        lambda: args.get_f64("lambda", 1e-4),
        reps: args.get_usize("reps", 5),
        seed: args.get_u64("seed", 0),
        uniform_m: args.get_usize("uniform-m", 400),
        ..Default::default()
    };
    let ds = susy_like(cfg.n, &mut Rng::seeded(cfg.seed.wrapping_add(77)));
    let eng = build_engine(engine_kind(args), ds.x, Gaussian::new(cfg.sigma))?;
    println!("engine backend: {}", eng.label());
    let t = fig1_accuracy(eng.as_dyn(), &cfg)?;
    println!("{}", t.to_console());
    maybe_csv(args, &t)?;
    // --estimators exact,srft:256,... (or "default" for the full family)
    // appends the estimator-family shoot-out on the same data/λ.
    if let Some(list) = args.get("estimators") {
        let sc = ShootoutConfig {
            lambda: cfg.lambda,
            reps: cfg.reps,
            seed: cfg.seed,
            specs: parse_estimator_specs(list, &ShootoutConfig::default().specs),
        };
        let shoot = fig1_estimator_shootout(eng.as_dyn(), &sc)?;
        println!("{}", shoot.to_console());
    }
    Ok(())
}

/// Comma-split an `--estimators` value; `default`/`all` expands to the
/// built-in family so `repro fig1 --estimators default` reproduces the
/// paper-extension shoot-out verbatim.
fn parse_estimator_specs(list: &str, default: &[String]) -> Vec<String> {
    match list.trim() {
        "default" | "all" => default.to_vec(),
        other => other.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
    }
}

fn parse_sizes(args: &Args, default: &[usize]) -> Vec<usize> {
    args.get("sizes")
        .map(|s| s.split(',').map(|v| v.trim().parse().expect("bad --sizes")).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn cmd_fig2(args: &Args) -> anyhow::Result<()> {
    let cfg = Fig2Config {
        sizes: parse_sizes(args, &[1_000, 2_000, 4_000, 8_000]),
        lambda: args.get_f64("lambda", 1e-3),
        sigma: args.get_f64("sigma", 4.0),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let t = fig2_scaling(&cfg);
    println!("{}", t.to_console());
    for &m in &cfg.methods {
        println!("  {:<10} empirical n-exponent: {}", m.name(), fnum(scaling_exponent(&t, m)));
    }
    maybe_csv(args, &t)?;
    // --estimators sweeps the estimator family over the same sizes and
    // reports each member's empirical cost exponent in n.
    if let Some(list) = args.get("estimators") {
        let specs = parse_estimator_specs(list, &ShootoutConfig::default().specs);
        let et = fig2_estimator_scaling(&cfg, &specs)?;
        println!("{}", et.to_console());
        for spec in specs.iter().filter(|_| cfg.sizes.len() >= 2) {
            let name = bless::leverage::parse_estimator(spec)
                .map(|e| e.name())
                .unwrap_or_else(|| spec.clone());
            println!(
                "  {:<22} empirical n-exponent: {}",
                name,
                fnum(scaling_exponent_for(&et, &name))
            );
        }
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 4_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = susy_like(n, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let cfg = Fig3Config {
        sigma: args.get_f64("sigma", 4.0),
        lambda_bless: args.get_f64("lambda-bless", 1e-3),
        iterations: args.get_usize("iters", 5),
        seed,
        ..Default::default()
    };
    let eng = build_engine(engine_kind(args), train.x.clone(), Gaussian::new(cfg.sigma))?;
    let res = fig3_stability(eng.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", res.table.to_console());
    println!(
        "95%-optimal region width: BLESS {} decades, UNI {} decades",
        fnum(res.bless_region_decades),
        fnum(res.uni_region_decades)
    );
    maybe_csv(args, &res.table)
}

fn cmd_fig45(args: &Args, higgs: bool) -> anyhow::Result<()> {
    let n = args.get_usize("n", 8_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = if higgs { higgs_like(n, &mut rng) } else { susy_like(n, &mut rng) };
    let (train, test) = ds.split(0.25, &mut rng);
    let mut cfg = if higgs { Fig45Config::higgs() } else { Fig45Config::susy() };
    cfg.iterations = args.get_usize("iters", cfg.iterations);
    cfg.lambda_bless = args.get_f64("lambda-bless", cfg.lambda_bless);
    cfg.lambda_falkon = args.get_f64("lambda-falkon", cfg.lambda_falkon);
    cfg.seed = seed;
    let eng = build_engine(engine_kind(args), train.x.clone(), Gaussian::new(cfg.sigma))?;
    println!(
        "engine backend: {} | threads {} | train n={} test n={}",
        eng.label(),
        bless::util::pool::threads(),
        train.n(),
        test.n()
    );
    let (b, u, table) = fig45_falkon(eng.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", table.to_console());
    println!(
        "{}: M={} final AUC {} ({}s sampling)",
        b.label,
        b.centers,
        fnum(b.final_auc()),
        fnum(b.sampling_secs)
    );
    println!("{}: M={} final AUC {}", u.label, u.centers, fnum(u.final_auc()));
    let target = u.final_auc();
    if let Some(it) = b.iters_to_reach(target) {
        println!(
            "FALKON-BLESS reaches FALKON-UNI's final AUC ({}) at iteration {it}/{}",
            fnum(target),
            cfg.iterations
        );
    }
    maybe_csv(args, &table)
}

fn cmd_table1(args: &Args) -> anyhow::Result<()> {
    let cfg = Table1Config {
        sizes: parse_sizes(args, &[1_000, 2_000, 4_000, 8_000]),
        lambda: args.get_f64("lambda", 1e-3),
        sigma: args.get_f64("sigma", 4.0),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let (raw, summary) = table1_complexity(&cfg);
    println!("{}", raw.to_console());
    println!("{}", summary.to_console());
    maybe_csv(args, &summary)
}

fn cmd_bless(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 4_000);
    let lambda = args.get_f64("lambda", 1e-4);
    let method = Method::parse(&args.get_str("method", "bless"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    let seed = args.get_u64("seed", 0);
    let ds = susy_like(n, &mut Rng::seeded(seed));
    let eng =
        build_engine(engine_kind(args), ds.x, Gaussian::new(args.get_f64("sigma", 4.0)))?;
    let mut rng = Rng::seeded(seed ^ 1);
    let t0 = std::time::Instant::now();
    let (set, evals) = bless::coordinator::run_method(
        method,
        eng.as_dyn(),
        lambda,
        (1.0 / lambda) as usize,
        &mut rng,
    );
    println!(
        "{} @ λ={lambda:.1e} n={n}: |J|={} score_evals={evals} time={:.2}s (engine {})",
        method.name(),
        set.len(),
        t0.elapsed().as_secs_f64(),
        eng.label()
    );
    Ok(())
}

/// `repro train`: BLESS centers + FALKON fit on a synthetic dataset,
/// report held-out AUC, and optionally save the self-contained model
/// artifact for `repro serve` / `repro predict`.
fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 8_000);
    let seed = args.get_u64("seed", 0);
    let dataset = args.get_str("dataset", "susy");
    let mut rng = Rng::seeded(seed);
    let ds = match dataset.as_str() {
        "susy" => susy_like(n, &mut rng),
        "higgs" => higgs_like(n, &mut rng),
        other => anyhow::bail!("unknown --dataset {other:?} (want susy|higgs)"),
    };
    let sigma = args.get_f64("sigma", if dataset == "higgs" { 5.0 } else { 4.0 });
    let lambda_bless = args.get_f64("lambda-bless", 1e-3);
    let lambda_falkon = args.get_f64("lambda-falkon", 1e-5);
    let iters = args.get_usize("iters", 15);

    // --trace / --trace-out switch on span timing; --verbose adds the CG
    // residual table. Tracing only observes — the fitted model is
    // bit-identical either way (tests/parallel_determinism.rs).
    let trace_out = args.get("trace-out").map(str::to_string);
    let trace = args.has_flag("trace") || trace_out.is_some();
    let verbose = args.has_flag("verbose");
    if trace {
        bless::obs::span::reset();
        bless::obs::span::set_enabled(true);
    }

    let (train, test) = ds.split(0.25, &mut rng);
    let eng = build_engine(engine_kind(args), train.x.clone(), Gaussian::new(sigma))?;
    println!(
        "engine backend: {} | threads {} | {} train n={} test n={} d={}",
        eng.label(),
        bless::util::pool::threads(),
        train.name,
        train.n(),
        test.n(),
        train.d()
    );

    let t0 = std::time::Instant::now();
    let path = bless::bless::bless(
        eng.as_dyn(),
        lambda_bless,
        &bless::bless::BlessConfig::default(),
        &mut rng,
    );
    let set = path.final_set().clone();
    println!(
        "BLESS: |J|={} at λ_bless={lambda_bless:.1e} ({:.2}s)",
        set.len(),
        t0.elapsed().as_secs_f64()
    );

    // K_nM panel budget: --mem-budget in MiB (0 = pure streaming);
    // default = a quarter of RAM. Bit-identical output at any budget.
    let budget_bytes = match args.get("mem-budget") {
        Some(_) => args.get_usize("mem-budget", 0).saturating_mul(1 << 20),
        None => bless::kernels::default_budget_bytes(),
    };
    let solver =
        bless::falkon::Falkon::with_budget(eng.as_dyn(), &set, lambda_falkon, budget_bytes)?;
    let plan = solver.panel().plan();
    println!(
        "panel cache: {}/{} tiles materialized ({:.1} MiB of {:.1} MiB budget)",
        plan.cached_tiles,
        plan.tiles(),
        plan.cached_bytes as f64 / (1 << 20) as f64,
        plan.budget_bytes as f64 / (1 << 20) as f64
    );
    // --checkpoint PATH [--checkpoint-every K] [--resume]: crash-tolerant
    // fits. The complete CG state lands in a BLESSCKPT file every K
    // iterations (atomic rename), and --resume picks up bit-identically
    // where a killed run left off; a damaged or mismatched checkpoint
    // degrades to a cold start. --tol adds an early residual stop
    // (0 = run all --iters, the paper-faithful fixed-iteration regime).
    let checkpoint = args.get("checkpoint").map(|p| CheckpointSpec {
        path: p.into(),
        every: args.get_usize("checkpoint-every", 1),
        resume: args.has_flag("resume"),
    });
    if args.has_flag("resume") && checkpoint.is_none() {
        anyhow::bail!("--resume needs --checkpoint <path>");
    }
    let model = solver.fit_opts(
        &train.y,
        iters,
        None,
        FitOptions { tol: args.get_f64("tol", 0.0), warm_start: None, checkpoint },
    )?;
    let test_auc = bless::data::auc(&model.predict(eng.as_dyn(), &test.x), &test.y);
    println!(
        "FALKON: M={} λ_falkon={lambda_falkon:.1e} {iters} iters | test AUC {}",
        solver.m(),
        fnum(test_auc)
    );

    if verbose || trace {
        println!("CG trace:");
        println!("  {:>4}  {:>12}  {:>9}", "iter", "rel-resid", "ms");
        let mut prev = 0.0;
        for s in &model.iterations {
            let ms = (s.seconds - prev) * 1e3;
            prev = s.seconds;
            println!("  {:>4}  {:>12.3e}  {:>9.2}", s.iter, s.rel_residual, ms);
        }
    }

    // panel traffic: printed with --verbose/--trace and folded into the
    // global counters so `serve --metrics-addr` exposes it after an
    // in-process train
    let pstats = solver.panel().stats();
    let mreg = bless::obs::metrics::global();
    mreg.counter("panel_cached_hits_total").add(pstats.cached_hits);
    mreg.counter("panel_streamed_tiles_total").add(pstats.streamed);
    mreg.counter("panel_streamed_bytes_total").add(pstats.streamed_bytes);
    mreg.counter("panel_entries_evaluated_total").add(pstats.entries_evaluated);
    if verbose || trace {
        println!(
            "panel traffic: {} cached tile hits, {} streamed tiles ({:.1} MiB recomputed)",
            pstats.cached_hits,
            pstats.streamed,
            pstats.streamed_bytes as f64 / (1 << 20) as f64
        );
    }

    if let Some(save) = args.get("save") {
        let artifact = ModelArtifact::from_fitted(&model, eng.as_dyn(), &train.name)?;
        artifact.save(save)?;
        let bytes = std::fs::metadata(save).map(|m| m.len()).unwrap_or(0);
        println!(
            "saved model artifact: {save} (M={} d={} {:.1} KiB)",
            artifact.m(),
            artifact.d(),
            bytes as f64 / 1024.0
        );
    }

    if trace {
        bless::obs::span::set_enabled(false);
        let profile = bless::obs::span::profile();
        print!("{}", profile.to_console());
        if let Some(path) = &trace_out {
            let counters = mreg
                .counter_values()
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect();
            let mut root = std::collections::BTreeMap::new();
            root.insert("spans".to_string(), profile.to_json());
            root.insert("counters".to_string(), Json::Obj(counters));
            std::fs::write(path, Json::Obj(root).to_string())
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!("wrote trace to {path}");
        }
    }
    Ok(())
}

/// Parse one comma-separated query row.
fn parse_query_row(s: &str) -> anyhow::Result<Vec<f64>> {
    s.split(',')
        .map(|v| {
            v.trim()
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad query value {v:?}: {e}"))
        })
        .collect()
}

/// `repro predict`: offline scoring with a saved artifact. Queries come
/// from `--query "x1,x2,..."`, from a CSV file (`--queries path`, one
/// row per line), or default to `--n` standard-normal demo points.
fn cmd_predict(args: &Args) -> anyhow::Result<()> {
    let model_path =
        args.get("model").ok_or_else(|| anyhow::anyhow!("predict needs --model <path>"))?;
    let artifact = ModelArtifact::load(model_path)?;
    let predictor = Predictor::new(&artifact);
    println!(
        "model: {} (M={} d={} σ={} trained on n={})",
        model_path,
        artifact.m(),
        artifact.d(),
        artifact.sigma,
        artifact.trained_n
    );

    let rows: Vec<Vec<f64>> = if let Some(q) = args.get("query") {
        vec![parse_query_row(q)?]
    } else if let Some(path) = args.get("queries") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(parse_query_row)
            .collect::<anyhow::Result<_>>()?
    } else {
        let k = args.get_usize("n", 5);
        let mut rng = Rng::seeded(args.get_u64("seed", 0));
        println!("(no --query/--queries given; scoring {k} standard-normal demo points)");
        (0..k).map(|_| (0..artifact.d()).map(|_| rng.gaussian()).collect()).collect()
    };

    for (i, x) in rows.iter().enumerate() {
        let y = predictor.predict_one(x)?;
        println!("query {i}: score {}", fnum(y));
    }
    Ok(())
}

/// `repro serve`: the TCP prediction server. One artifact (`--model`,
/// registered as "default") or a named registry (`--models a=p1,b=p2`);
/// blocks until a client sends `{"op":"shutdown"}`.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let specs: Vec<ModelSpec> = if let Some(list) = args.get("models") {
        list.split(',')
            .map(|item| ModelSpec::from_cli_arg(item.trim()))
            .collect::<anyhow::Result<_>>()?
    } else {
        let model_path = args.get("model").ok_or_else(|| {
            anyhow::anyhow!("serve needs --model <path> or --models name=path,name2=path2")
        })?;
        vec![ModelSpec {
            name: "default".to_string(),
            artifact: ModelArtifact::load(model_path)?,
            source: Some(model_path.into()),
        }]
    };
    // --metrics-addr takes a full host:port; --metrics-port reuses the
    // serve host. Neither given → no observability listener.
    let metrics_addr = args.get("metrics-addr").map(str::to_string).or_else(|| {
        args.get("metrics-port").map(|_| {
            format!(
                "{}:{}",
                args.get_str("host", "127.0.0.1"),
                args.get_usize("metrics-port", 9100)
            )
        })
    });
    // chaos harness: --faults beats the BLESS_FAULTS env var; absent
    // both, the registry stays disarmed (a single relaxed load per
    // injection point — serving output is bit-identical)
    let fault_spec = args
        .get("faults")
        .map(str::to_string)
        .or_else(|| std::env::var("BLESS_FAULTS").ok().filter(|s| !s.trim().is_empty()));
    match &fault_spec {
        Some(spec) => {
            let plan = bless::faults::FaultPlan::parse(spec)
                .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
            println!("fault injection ARMED: {plan}");
            bless::faults::configure(Some(plan));
        }
        None => bless::faults::configure(None),
    }
    let default_deadline_ms = args.get_u64("default-deadline", 0);
    let io_timeout_ms = args.get_u64("io-timeout-ms", 30_000);
    let mut builder = ServeConfig::builder()
        .addr(format!("{}:{}", args.get_str("host", "127.0.0.1"), args.get_usize("port", 7878)))
        .workers(args.get_usize("workers", 2))
        .max_batch(args.get_usize("max-batch", 64))
        .linger(std::time::Duration::from_micros(args.get_u64("linger-us", 2_000)))
        .cache_capacity(args.get_usize("cache", 1024))
        .cache_quant(args.get_f64("cache-quant", 1e-9))
        .max_queue(args.get_usize("max-queue", 1024))
        .threads(args.get_usize("threads", 0))
        .default_deadline(
            (default_deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(default_deadline_ms)),
        )
        .io_timeout((io_timeout_ms > 0).then(|| std::time::Duration::from_millis(io_timeout_ms)))
        .breaker_threshold(args.get_usize("breaker-threshold", 8) as u32)
        .breaker_cooldown(std::time::Duration::from_millis(
            args.get_u64("breaker-cooldown-ms", 1_000),
        ));
    if let Some(path) = args.get("stats-file") {
        builder = builder.stats_file(path);
    }
    // --stats-flush-secs N: flush the same snapshot every N seconds
    // while serving (needs --stats-file), bounding what a hard kill
    // can lose to one flush interval.
    let flush_secs = args.get_f64("stats-flush-secs", 0.0);
    if flush_secs > 0.0 {
        builder = builder.stats_flush(Some(std::time::Duration::from_secs_f64(flush_secs)));
    }
    if let Some(addr) = metrics_addr {
        builder = builder.metrics_addr(addr);
    }
    let cfg = builder.build()?;
    // --retrain-every SECS: the continuous-training lifecycle. Capture
    // the incumbent artifact + its disk path before the registry takes
    // ownership of the specs; the scheduler itself starts after the
    // server is listening.
    let retrain_secs = args.get_f64("retrain-every", 0.0);
    let lifecycle_seed = if retrain_secs > 0.0 {
        anyhow::ensure!(
            specs.len() == 1,
            "--retrain-every drives exactly one served model (got {})",
            specs.len()
        );
        let spec = &specs[0];
        let path = spec.source.clone().ok_or_else(|| {
            anyhow::anyhow!("--retrain-every needs a disk-backed model (--model <path>)")
        })?;
        Some((spec.name.clone(), spec.artifact.clone(), path))
    } else {
        None
    };
    for spec in &specs {
        println!(
            "model {:?}: M={} d={} ({})",
            spec.name,
            spec.artifact.m(),
            spec.artifact.d(),
            spec.source.as_deref().map(|p| p.display().to_string()).unwrap_or_default()
        );
    }
    println!(
        "serving {} model(s) on {} | workers={}/model max_batch={} linger={}µs cache={} \
         max_queue={} compute_threads={}",
        specs.len(),
        cfg.addr,
        cfg.workers,
        cfg.max_batch,
        cfg.linger.as_micros(),
        cfg.cache_capacity,
        cfg.max_queue,
        bless::util::pool::threads()
    );
    let handle = bless::serve::start_registry(specs, &cfg)?;
    println!(
        "listening on {} — send {{\"op\":\"shutdown\"}} to stop, \
         {{\"op\":\"admin\",\"cmd\":\"reload\",\"model\":…}} to hot-swap",
        handle.addr()
    );
    if let Some(m) = handle.metrics_addr() {
        println!("metrics: http://{m}/metrics (also /healthz, /varz)");
    }
    let scheduler = match lifecycle_seed {
        Some((name, incumbent, path)) => {
            Some(start_retrain(args, &handle, name, incumbent, path, retrain_secs)?)
        }
        None => None,
    };
    handle.join();
    if let Some(s) = scheduler {
        s.stop();
    }
    println!("server stopped");
    Ok(())
}

/// Wire the continuous-training lifecycle onto a running server: a
/// background [`RetrainScheduler`] refits on deterministically drifting
/// SUSY-like labels (warm-started from the previous cycle's `α`), gates
/// every candidate on a fixed holdout split, promotes winners into the
/// live registry entry (persisting to the served artifact path) and
/// rolls back automatically if a fresh promotion trips the breaker.
fn start_retrain(
    args: &Args,
    handle: &bless::serve::ServerHandle,
    name: String,
    incumbent: ModelArtifact,
    artifact_path: std::path::PathBuf,
    every_secs: f64,
) -> anyhow::Result<RetrainScheduler> {
    let entry = handle
        .entry(&name)
        .ok_or_else(|| anyhow::anyhow!("model {name:?} not found in the registry"))?;
    let n = args.get_usize("retrain-n", 2_000);
    let seed = args.get_u64("seed", 0);
    let iters = args.get_usize("retrain-iters", 40);
    let tol = args.get_f64("retrain-tol", 1e-6);
    let centers_m = args.get_usize("retrain-centers", 100);
    let lambda = args.get_f64("retrain-lambda", 1e-5);
    let drift = args.get_f64("drift", 0.02);
    let gate_tol = args.get_f64("gate-tolerance", 0.05);
    let probation = args.get_f64("probation-secs", 5.0);

    let mut rng = Rng::seeded(seed);
    let ds = susy_like(n, &mut rng);
    let (train, holdout) = ds.split(0.25, &mut rng);
    anyhow::ensure!(
        train.d() == entry.dim(),
        "retrain demo generates d={} queries but model {:?} serves d={}",
        train.d(),
        name,
        entry.dim()
    );
    let gate = HoldoutGate::new(holdout.x.clone(), holdout.y.clone(), gate_tol)?;

    // fixed centers across cycles keep α-vectors comparable, so every
    // refit after the first warm-starts from the previous coefficients
    let centers = Rng::seeded(seed ^ 0x9e37_79b9)
        .sample_without_replacement(train.n(), centers_m.min(train.n()));
    let m_actual = centers.len();
    let engine = NativeEngine::new(train.x.clone(), Gaussian::new(incumbent.sigma));
    let base_y = train.y.clone();
    let model_name = name.clone();
    let mut warm: Option<Vec<f64>> = None;
    let trainer = move |cycle: u64| -> anyhow::Result<ModelArtifact> {
        // deterministic label drift: each cycle shifts the target surface
        let y: Vec<f64> = base_y
            .iter()
            .enumerate()
            .map(|(i, v)| v + drift * (0.1 * i as f64 + 0.37 * cycle as f64).sin())
            .collect();
        let set = WeightedSet::uniform(centers.clone(), lambda);
        let solver = Falkon::new(&engine, &set, lambda)?;
        let model = match warm.as_deref() {
            Some(alpha) if alpha.len() == solver.m() => solver.refit(&y, iters, tol, alpha)?,
            _ => solver.fit_opts(&y, iters, None, FitOptions { tol, ..Default::default() })?,
        };
        println!(
            "retrain cycle {cycle} ({model_name}): {} CG iterations ({})",
            model.iterations.len(),
            if warm.is_some() { "warm" } else { "cold" }
        );
        warm = Some(model.alpha.clone());
        ModelArtifact::from_fitted(&model, &engine, "susy-like-drift")
    };

    let mut cfg = LifecycleConfig::new(artifact_path);
    cfg.probation = std::time::Duration::from_secs_f64(probation);
    println!(
        "lifecycle: retraining {name:?} every {every_secs}s (n={n} M={m_actual} drift={drift} \
         gate-tol={gate_tol} probation={probation}s)"
    );
    Ok(RetrainScheduler::start(
        entry,
        incumbent,
        std::time::Duration::from_secs_f64(every_secs),
        trainer,
        gate,
        cfg,
    ))
}

/// `repro convert`: re-encode a model artifact between JSON and binary
/// (format chosen by `--format`, else by the output extension).
fn cmd_convert(args: &Args) -> anyhow::Result<()> {
    let input = args.get("in").ok_or_else(|| anyhow::anyhow!("convert needs --in <path>"))?;
    let output = args.get("out").ok_or_else(|| anyhow::anyhow!("convert needs --out <path>"))?;
    let artifact = ModelArtifact::load(input)?;
    let format = match args.get("format") {
        None => Format::from_path(std::path::Path::new(output)),
        Some("json") => Format::Json,
        Some("binary") | Some("bin") => Format::Binary,
        Some(other) => anyhow::bail!("unknown --format {other:?} (want json|binary)"),
    };
    artifact.save_as(output, format)?;
    let in_bytes = std::fs::metadata(input)?.len();
    let out_bytes = std::fs::metadata(output)?.len();
    println!(
        "{input} ({:.1} KiB) → {output} ({:.1} KiB, {format:?}): {:.2}× the input size",
        in_bytes as f64 / 1024.0,
        out_bytes as f64 / 1024.0,
        out_bytes as f64 / in_bytes as f64
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    match bless::runtime::find_artifact_dir() {
        None => println!("artifacts: NOT FOUND (run `make artifacts`)"),
        Some(dir) => {
            println!("artifacts: {}", dir.display());
            let rt = bless::runtime::PjrtRuntime::load(&dir)?;
            println!("platform: {}", rt.platform());
            println!(
                "tile: {}x{} (feature dim {})",
                rt.manifest.tile, rt.manifest.tile, rt.manifest.feature_dim
            );
            println!("artifacts compiled: {:?}", rt.artifact_names());
        }
    }
    Ok(())
}
