//! The [`KernelEngine`] abstraction and its native (pure-rust) backend.

use super::Gaussian;
use crate::linalg::{self, Matrix};
use crate::util::pool;

/// Row-tile size for streaming matvecs (`K_nM` is never materialized).
pub const DEFAULT_ROW_TILE: usize = 1024;

/// Split `0..n` into `(start, end)` tiles of at most `tile` rows.
pub fn tile_indices(n: usize, tile: usize) -> Vec<(usize, usize)> {
    assert!(tile > 0);
    let mut out = Vec::with_capacity(n.div_ceil(tile));
    let mut s = 0;
    while s < n {
        let e = (s + tile).min(n);
        out.push((s, e));
        s = e;
    }
    out
}

/// Abstraction over who evaluates Gaussian-kernel blocks of the (implicit)
/// `n × n` kernel matrix of a fixed dataset.
///
/// Implementations: [`NativeEngine`] (pure rust) and
/// [`crate::runtime::XlaEngine`] (PJRT-compiled Pallas tiles).
pub trait KernelEngine {
    /// Number of data points.
    fn n(&self) -> usize;

    /// The kernel function.
    fn kernel(&self) -> &Gaussian;

    /// The underlying dataset (row-major `n × d`).
    fn points(&self) -> &Matrix;

    /// Kernel block `K(X[rows], X[cols])` (`|rows| × |cols|`).
    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix;

    /// Cross block `K(Q, X[cols])` for out-of-sample points `Q`.
    fn cross_block(&self, q: &Matrix, cols: &[usize]) -> Matrix;

    /// Kernel diagonal at the given indices (`K_ii`; 1 for Gaussian).
    fn diag(&self, idx: &[usize]) -> Vec<f64> {
        vec![self.kernel().kappa_sq(); idx.len()]
    }

    /// `κ²` bound on the kernel.
    fn kappa_sq(&self) -> f64 {
        self.kernel().kappa_sq()
    }

    /// Streaming `y = K_nM · v` where `M` indexes `centers` (length-n out).
    fn knm_matvec(&self, centers: &[usize], v: &[f64]) -> Vec<f64> {
        assert_eq!(centers.len(), v.len());
        let n = self.n();
        let mut y = vec![0.0; n];
        let rows: Vec<usize> = (0..n).collect();
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            let blk = self.block(&rows[s..e], centers);
            linalg::matvec_into(&blk, v, &mut y[s..e]);
        }
        y
    }

    /// Streaming `z = K_nMᵀ · u` (length-M out).
    fn knm_t_matvec(&self, centers: &[usize], u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.n());
        let n = self.n();
        let mut z = vec![0.0; centers.len()];
        let rows: Vec<usize> = (0..n).collect();
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            let blk = self.block(&rows[s..e], centers);
            let partial = linalg::matvec_t(&blk, &u[s..e]);
            linalg::axpy(1.0, &partial, &mut z);
        }
        z
    }

    /// Fused streaming `z = K_nMᵀ (K_nM v)` — the FALKON CG hot loop.
    /// Each row tile of `K_nM` is evaluated once and used for both
    /// products, halving kernel evaluations vs. two separate passes.
    fn knm_t_knm_matvec(&self, centers: &[usize], v: &[f64]) -> Vec<f64> {
        assert_eq!(centers.len(), v.len());
        let n = self.n();
        let mut z = vec![0.0; centers.len()];
        let rows: Vec<usize> = (0..n).collect();
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            let blk = self.block(&rows[s..e], centers);
            let w = linalg::matvec(&blk, v);
            let partial = linalg::matvec_t(&blk, &w);
            linalg::axpy(1.0, &partial, &mut z);
        }
        z
    }

    /// Streaming `z = K_nMᵀ · y` over labels plus row-sum accounting:
    /// returns `K_nMᵀ y` (used for the FALKON right-hand side).
    fn knm_t_labels(&self, centers: &[usize], y: &[f64]) -> Vec<f64> {
        self.knm_t_matvec(centers, y)
    }
}

/// Pure-rust kernel engine: blocked evaluation with the row-norm trick.
///
/// `K(X_I, X_J) = exp(−γ(‖x_i‖² + ‖x_j‖² − 2 X_I X_Jᵀ))` — the cross term
/// is a GEMM, so the whole block evaluation inherits the blocked GEMM's
/// cache behaviour.
pub struct NativeEngine {
    x: Matrix,
    kernel: Gaussian,
    sq_norms: Vec<f64>,
}

impl NativeEngine {
    /// Build an engine over the dataset `x` with the given kernel.
    pub fn new(x: Matrix, kernel: Gaussian) -> Self {
        let sq_norms = (0..x.rows()).map(|i| linalg::norm2_sq(x.row(i))).collect();
        NativeEngine { x, kernel, sq_norms }
    }

    /// Gather rows into a dense matrix (cheap relative to the GEMM).
    fn gather(&self, idx: &[usize]) -> Matrix {
        let d = self.x.cols();
        let mut m = Matrix::zeros(idx.len(), d);
        for (r, &i) in idx.iter().enumerate() {
            m.row_mut(r).copy_from_slice(self.x.row(i));
        }
        m
    }

    /// Kernel block between two explicit point sets with precomputed
    /// squared norms. The cross-term GEMM is parallel inside
    /// [`linalg::gemm`]; the exp pass below is parallelized over
    /// fixed-size row blocks (elementwise, hence bit-identical to the
    /// serial sweep at any thread count).
    fn block_impl(&self, a: &Matrix, a_sq: &[f64], b: &Matrix, b_sq: &[f64]) -> Matrix {
        /// Row-block height of the parallel exp pass.
        const EXP_RB: usize = 64;
        /// Minimum block cells before the exp pass dispatches.
        const PAR_MIN_EXP: usize = 1 << 14;
        // cross = A · Bᵀ, evaluated as gemm against the transposed gather
        let mut k = linalg::gemm(a, &b.transpose());
        let cols = b_sq.len();
        if cols == 0 || a_sq.is_empty() {
            return k;
        }
        let kd = k.as_mut_slice();
        let parallel = a_sq.len() * cols >= PAR_MIN_EXP;
        pool::par_chunks_mut_gated(kd, EXP_RB * cols, parallel, |blk, chunk| {
            exp_pass(&self.kernel, a_sq, b_sq, blk * EXP_RB, chunk);
        });
        k
    }
}

/// Turn a chunk of cross-term rows (starting at global row `r0`) into
/// kernel values in place: `v ← k(‖a_i‖² + ‖b_j‖² − 2·v)`. Elementwise,
/// so any row partition yields bit-identical results.
fn exp_pass(kernel: &Gaussian, a_sq: &[f64], b_sq: &[f64], r0: usize, chunk: &mut [f64]) {
    let cols = b_sq.len();
    for (local, row) in chunk.chunks_mut(cols).enumerate() {
        let ai = a_sq[r0 + local];
        for (v, &bj) in row.iter_mut().zip(b_sq.iter()) {
            let d2 = ai + bj - 2.0 * *v;
            *v = kernel.from_sq_dist(d2);
        }
    }
}

impl KernelEngine for NativeEngine {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn kernel(&self) -> &Gaussian {
        &self.kernel
    }

    fn points(&self) -> &Matrix {
        &self.x
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        let a = self.gather(rows);
        let b = self.gather(cols);
        let a_sq: Vec<f64> = rows.iter().map(|&i| self.sq_norms[i]).collect();
        let b_sq: Vec<f64> = cols.iter().map(|&j| self.sq_norms[j]).collect();
        self.block_impl(&a, &a_sq, &b, &b_sq)
    }

    fn cross_block(&self, q: &Matrix, cols: &[usize]) -> Matrix {
        assert_eq!(q.cols(), self.x.cols(), "query dimension mismatch");
        let q_sq: Vec<f64> = (0..q.rows()).map(|i| linalg::norm2_sq(q.row(i))).collect();
        let b = self.gather(cols);
        let b_sq: Vec<f64> = cols.iter().map(|&j| self.sq_norms[j]).collect();
        self.block_impl(q, &q_sq, &b, &b_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::rng::Rng;

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(7));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn tiles_cover_range() {
        assert_eq!(tile_indices(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(tile_indices(4, 4), vec![(0, 4)]);
        assert_eq!(tile_indices(0, 4), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn matvec_matches_dense() {
        let eng = engine(60);
        let centers: Vec<usize> = vec![3, 10, 20, 33, 47];
        let v: Vec<f64> = (0..5).map(|i| (i as f64) * 0.5 - 1.0).collect();
        let all: Vec<usize> = (0..60).collect();
        let knm = eng.block(&all, &centers);
        let dense = linalg::matvec(&knm, &v);
        let streamed = eng.knm_matvec(&centers, &v);
        for (a, b) in dense.iter().zip(&streamed) {
            assert!((a - b).abs() < 1e-12);
        }
        // transpose version
        let u: Vec<f64> = (0..60).map(|i| ((i * i) as f64).sin()).collect();
        let dense_t = linalg::matvec_t(&knm, &u);
        let streamed_t = eng.knm_t_matvec(&centers, &u);
        for (a, b) in dense_t.iter().zip(&streamed_t) {
            assert!((a - b).abs() < 1e-12);
        }
        // fused K^T K v
        let fused = eng.knm_t_knm_matvec(&centers, &v);
        let two_pass = eng.knm_t_matvec(&centers, &eng.knm_matvec(&centers, &v));
        for (a, b) in fused.iter().zip(&two_pass) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn cross_block_matches_block_on_same_data() {
        let eng = engine(30);
        let rows = vec![2usize, 8, 14];
        let cols = vec![0usize, 29, 7];
        let q = Matrix::from_fn(3, eng.points().cols(), |i, j| eng.points().get(rows[i], j));
        let via_cross = eng.cross_block(&q, &cols);
        let via_block = eng.block(&rows, &cols);
        assert!(via_cross.max_abs_diff(&via_block) < 1e-12);
    }

    #[test]
    fn knm_t_knm_is_psd_quadratic() {
        // vᵀ (KᵀK) v ≥ 0 for any v
        let eng = engine(50);
        let centers: Vec<usize> = vec![1, 5, 9, 13];
        let mut r = Rng::seeded(9);
        for _ in 0..5 {
            let v: Vec<f64> = (0..4).map(|_| r.gaussian()).collect();
            let z = eng.knm_t_knm_matvec(&centers, &v);
            assert!(linalg::dot(&v, &z) >= -1e-10);
        }
    }
}
