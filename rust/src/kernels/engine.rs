//! The [`KernelEngine`] abstraction and its native (pure-rust) backend.

use super::Gaussian;
use crate::linalg::{self, Matrix};
use crate::util::pool;

/// Row-tile size for streaming matvecs (`K_nM` is never materialized).
pub const DEFAULT_ROW_TILE: usize = 1024;

/// Split `0..n` into `(start, end)` tiles of at most `tile` rows.
pub fn tile_indices(n: usize, tile: usize) -> Vec<(usize, usize)> {
    assert!(tile > 0);
    let mut out = Vec::with_capacity(n.div_ceil(tile));
    let mut s = 0;
    while s < n {
        let e = (s + tile).min(n);
        out.push((s, e));
        s = e;
    }
    out
}

/// A center set gathered out of the dataset **once**: the indices, the
/// dense `M × d` row matrix, and the per-center squared norms.
///
/// Every `K_nM`-shaped product touches the same `M` center rows on every
/// row tile of every iteration; before this struct existed the engine
/// re-gathered (and transposed) them per tile per call. Build a
/// `Centers` once per center set ([`KernelEngine::gather_centers`]) and
/// pass it to the `*_range`/`centers_*` block evaluators — the
/// [`crate::kernels::PanelCache`] holds one for the whole FALKON fit.
#[derive(Clone, Debug)]
pub struct Centers {
    /// Row indices into the engine's dataset.
    pub indices: Vec<usize>,
    /// The gathered center rows (`M × d`, row-major).
    pub points: Matrix,
    /// `‖x̃_j‖²` per center (the row-norm trick's `b_sq`).
    pub sq_norms: Vec<f64>,
}

impl Centers {
    /// Number of centers `M`.
    pub fn m(&self) -> usize {
        self.indices.len()
    }
}

/// Abstraction over who evaluates Gaussian-kernel blocks of the (implicit)
/// `n × n` kernel matrix of a fixed dataset.
///
/// Implementations: [`NativeEngine`] (pure rust) and
/// [`crate::runtime::XlaEngine`] (PJRT-compiled Pallas tiles).
///
/// The `*_range` / `centers_*` family takes a pre-gathered [`Centers`]
/// so that repeated products against a fixed center set (FALKON CG,
/// BLESS score batches) pay the gather once. Default implementations
/// fall back to the index-based [`block`](Self::block)/
/// [`cross_block`](Self::cross_block), so backends only opt in where it
/// pays.
pub trait KernelEngine {
    /// Number of data points.
    fn n(&self) -> usize;

    /// The kernel function.
    fn kernel(&self) -> &Gaussian;

    /// The underlying dataset (row-major `n × d`).
    fn points(&self) -> &Matrix;

    /// Kernel block `K(X[rows], X[cols])` (`|rows| × |cols|`).
    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix;

    /// Cross block `K(Q, X[cols])` for out-of-sample points `Q`.
    fn cross_block(&self, q: &Matrix, cols: &[usize]) -> Matrix;

    /// Kernel diagonal at the given indices (`K_ii`; 1 for Gaussian).
    fn diag(&self, idx: &[usize]) -> Vec<f64> {
        vec![self.kernel().kappa_sq(); idx.len()]
    }

    /// `κ²` bound on the kernel.
    fn kappa_sq(&self) -> f64 {
        self.kernel().kappa_sq()
    }

    /// Gather a center set once (rows + squared norms) for the
    /// `*_range`/`centers_*` evaluators.
    fn gather_centers(&self, idx: &[usize]) -> Centers {
        let x = self.points();
        let d = x.cols();
        let mut points = Matrix::zeros(idx.len(), d);
        for (r, &i) in idx.iter().enumerate() {
            points.row_mut(r).copy_from_slice(x.row(i));
        }
        let sq_norms = (0..points.rows()).map(|r| linalg::norm2_sq(points.row(r))).collect();
        Centers { indices: idx.to_vec(), points, sq_norms }
    }

    /// Identity-range row tile `K(X[s..e], centers)` — the streaming
    /// `K_nM` evaluator. No row-index vector is built; native backends
    /// read the row range straight out of the dataset.
    fn block_range(&self, s: usize, e: usize, centers: &Centers) -> Matrix {
        let rows: Vec<usize> = (s..e).collect();
        self.block(&rows, &centers.indices)
    }

    /// [`block_range`](Self::block_range) into a reusable buffer: `out`
    /// is reshaped by the implementation, so callers can hand the same
    /// workspace to every tile of a sweep. Must produce bit-identical
    /// values to `block_range` — the panel cache relies on it.
    fn block_range_into(&self, s: usize, e: usize, centers: &Centers, out: &mut Matrix) {
        *out = self.block_range(s, e, centers);
    }

    /// `K(centers, X[cols])` (`M × |cols|`) with the row side
    /// pre-gathered — the LsGenerator score-batch shape.
    fn centers_block(&self, centers: &Centers, cols: &[usize]) -> Matrix {
        self.block(&centers.indices, cols)
    }

    /// `K(centers, centers)` (`M × M`) — `K_MM` for the FALKON
    /// preconditioner and the LsGenerator factorization.
    fn centers_square(&self, centers: &Centers) -> Matrix {
        self.block(&centers.indices, &centers.indices)
    }

    /// Cross block `K(Q[s..e], centers)` for a row range of an
    /// out-of-sample query matrix — the prediction tile shape, with
    /// neither the query tile nor the center rows re-copied by native
    /// backends.
    fn cross_block_range(&self, q: &Matrix, s: usize, e: usize, centers: &Centers) -> Matrix {
        let tile = Matrix::from_fn(e - s, q.cols(), |i, j| q.get(s + i, j));
        self.cross_block(&tile, &centers.indices)
    }

    /// Streaming `y = K_nM · v` where `M` indexes `centers` (length-n out).
    fn knm_matvec(&self, centers: &[usize], v: &[f64]) -> Vec<f64> {
        assert_eq!(centers.len(), v.len());
        let n = self.n();
        let c = self.gather_centers(centers);
        let mut y = vec![0.0; n];
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            let blk = self.block_range(s, e, &c);
            linalg::matvec_into(&blk, v, &mut y[s..e]);
        }
        y
    }

    /// Streaming `z = K_nMᵀ · u` (length-M out).
    fn knm_t_matvec(&self, centers: &[usize], u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.n());
        let n = self.n();
        let c = self.gather_centers(centers);
        let mut z = vec![0.0; centers.len()];
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            let blk = self.block_range(s, e, &c);
            linalg::matvec_t_acc(&blk, &u[s..e], &mut z);
        }
        z
    }

    /// Fused streaming `z = K_nMᵀ (K_nM v)` — the FALKON CG hot loop.
    /// Each row tile of `K_nM` is evaluated once and used for both
    /// products, halving kernel evaluations vs. two separate passes.
    fn knm_t_knm_matvec(&self, centers: &[usize], v: &[f64]) -> Vec<f64> {
        assert_eq!(centers.len(), v.len());
        let n = self.n();
        let c = self.gather_centers(centers);
        let mut z = vec![0.0; centers.len()];
        let mut w = vec![0.0; DEFAULT_ROW_TILE.min(n.max(1))];
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            let blk = self.block_range(s, e, &c);
            linalg::matvec_into(&blk, v, &mut w[..e - s]);
            linalg::matvec_t_acc(&blk, &w[..e - s], &mut z);
        }
        z
    }

    /// Streaming `z = K_nMᵀ · y` over labels plus row-sum accounting:
    /// returns `K_nMᵀ y` (used for the FALKON right-hand side).
    fn knm_t_labels(&self, centers: &[usize], y: &[f64]) -> Vec<f64> {
        self.knm_t_matvec(centers, y)
    }
}

/// Pure-rust kernel engine: blocked evaluation with the row-norm trick.
///
/// `K(X_I, X_J) = exp(−γ(‖x_i‖² + ‖x_j‖² − 2 X_I X_Jᵀ))` — the cross term
/// is a GEMM, so the whole block evaluation inherits the blocked GEMM's
/// cache behaviour.
pub struct NativeEngine {
    x: Matrix,
    kernel: Gaussian,
    sq_norms: Vec<f64>,
}

impl NativeEngine {
    /// Build an engine over the dataset `x` with the given kernel.
    pub fn new(x: Matrix, kernel: Gaussian) -> Self {
        let sq_norms = (0..x.rows()).map(|i| linalg::norm2_sq(x.row(i))).collect();
        NativeEngine { x, kernel, sq_norms }
    }

    /// Gather rows into a dense matrix (cheap relative to the GEMM).
    fn gather(&self, idx: &[usize]) -> Matrix {
        let d = self.x.cols();
        let mut m = Matrix::zeros(idx.len(), d);
        for (r, &i) in idx.iter().enumerate() {
            m.row_mut(r).copy_from_slice(self.x.row(i));
        }
        m
    }

    /// Kernel block between two explicit point sets with precomputed
    /// squared norms, written into a reusable buffer (`out` is reshaped
    /// to `|a_sq| × |b_sq|`). `a` and `b` are row-major point slices of
    /// width `d` — borrowed ranges of the dataset or a gathered
    /// [`Centers`] work equally, so no side is ever copied just to feed
    /// the product.
    ///
    /// The cross term runs through the transpose-free NT product
    /// ([`linalg::MatMul`] slice form, `A·Bᵀ` over dot-product panels —
    /// no `d × M` transpose is materialized); the exp pass below is
    /// parallelized over fixed-size row blocks. Both partitions depend
    /// only on the shape, so the result is bit-identical at any thread
    /// count.
    fn block_pair_into(&self, a: &[f64], a_sq: &[f64], b: &[f64], b_sq: &[f64], out: &mut Matrix) {
        /// Row-block height of the parallel exp pass.
        const EXP_RB: usize = 64;
        /// Minimum block cells before the exp pass dispatches.
        const PAR_MIN_EXP: usize = 1 << 14;
        let (rows, cols) = (a_sq.len(), b_sq.len());
        if out.rows() != rows || out.cols() != cols {
            *out = Matrix::zeros(rows, cols);
        } else {
            out.as_mut_slice().fill(0.0);
        }
        if rows == 0 || cols == 0 {
            return;
        }
        linalg::MatMul::nt().accumulate().run_rows_into(
            a,
            b,
            self.x.cols(),
            out.as_mut_slice(),
            cols,
        );
        let kd = out.as_mut_slice();
        let kern = linalg::kernels();
        let gamma = self.kernel.gamma();
        let parallel = rows * cols >= PAR_MIN_EXP;
        pool::par_chunks_mut_gated(kd, EXP_RB * cols, parallel, |blk, chunk| {
            exp_pass(kern, gamma, a_sq, b_sq, blk * EXP_RB, chunk);
        });
    }

    /// Allocating wrapper around [`Self::block_pair_into`].
    fn block_pair(&self, a: &[f64], a_sq: &[f64], b: &[f64], b_sq: &[f64]) -> Matrix {
        let mut out = Matrix::zeros(a_sq.len(), b_sq.len());
        self.block_pair_into(a, a_sq, b, b_sq, &mut out);
        out
    }
}

/// Turn a chunk of cross-term rows (starting at global row `r0`) into
/// kernel values in place: `v ← exp(−γ(‖a_i‖² + ‖b_j‖² − 2·v))`, one row
/// at a time through the dispatched [`linalg::MicroKernels::exp_row`]
/// (scalar `f64::exp`, or the ≤4-ULP AVX2 polynomial path). Elementwise,
/// so any row partition yields bit-identical results.
fn exp_pass(
    kern: &linalg::MicroKernels,
    gamma: f64,
    a_sq: &[f64],
    b_sq: &[f64],
    r0: usize,
    chunk: &mut [f64],
) {
    let cols = b_sq.len();
    for (local, row) in chunk.chunks_mut(cols).enumerate() {
        let ai = a_sq[r0 + local];
        (kern.exp_row)(gamma, ai, b_sq, row);
    }
}

impl KernelEngine for NativeEngine {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn kernel(&self) -> &Gaussian {
        &self.kernel
    }

    fn points(&self) -> &Matrix {
        &self.x
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        let a = self.gather(rows);
        let b = self.gather(cols);
        let a_sq: Vec<f64> = rows.iter().map(|&i| self.sq_norms[i]).collect();
        let b_sq: Vec<f64> = cols.iter().map(|&j| self.sq_norms[j]).collect();
        self.block_pair(a.as_slice(), &a_sq, b.as_slice(), &b_sq)
    }

    fn cross_block(&self, q: &Matrix, cols: &[usize]) -> Matrix {
        assert_eq!(q.cols(), self.x.cols(), "query dimension mismatch");
        let q_sq: Vec<f64> = (0..q.rows()).map(|i| linalg::norm2_sq(q.row(i))).collect();
        let b = self.gather(cols);
        let b_sq: Vec<f64> = cols.iter().map(|&j| self.sq_norms[j]).collect();
        self.block_pair(q.as_slice(), &q_sq, b.as_slice(), &b_sq)
    }

    /// Reuses the engine's precomputed row norms instead of re-deriving
    /// them from the gathered rows.
    fn gather_centers(&self, idx: &[usize]) -> Centers {
        let points = self.gather(idx);
        let sq_norms: Vec<f64> = idx.iter().map(|&i| self.sq_norms[i]).collect();
        Centers { indices: idx.to_vec(), points, sq_norms }
    }

    /// Zero-copy row side: the tile `X[s..e]` and its norms are read
    /// straight out of the dataset — no index vector, no gather.
    fn block_range(&self, s: usize, e: usize, centers: &Centers) -> Matrix {
        let mut out = Matrix::zeros(e - s, centers.m());
        self.block_range_into(s, e, centers, &mut out);
        out
    }

    fn block_range_into(&self, s: usize, e: usize, centers: &Centers, out: &mut Matrix) {
        assert!(s <= e && e <= self.x.rows(), "row range out of bounds");
        let d = self.x.cols();
        let a = &self.x.as_slice()[s * d..e * d];
        self.block_pair_into(
            a,
            &self.sq_norms[s..e],
            centers.points.as_slice(),
            &centers.sq_norms,
            out,
        );
    }

    fn centers_block(&self, centers: &Centers, cols: &[usize]) -> Matrix {
        let b = self.gather(cols);
        let b_sq: Vec<f64> = cols.iter().map(|&j| self.sq_norms[j]).collect();
        self.block_pair(centers.points.as_slice(), &centers.sq_norms, b.as_slice(), &b_sq)
    }

    fn centers_square(&self, centers: &Centers) -> Matrix {
        self.block_pair(
            centers.points.as_slice(),
            &centers.sq_norms,
            centers.points.as_slice(),
            &centers.sq_norms,
        )
    }

    fn cross_block_range(&self, q: &Matrix, s: usize, e: usize, centers: &Centers) -> Matrix {
        assert_eq!(q.cols(), self.x.cols(), "query dimension mismatch");
        assert!(s <= e && e <= q.rows(), "query row range out of bounds");
        let d = q.cols();
        let qa = &q.as_slice()[s * d..e * d];
        let q_sq: Vec<f64> = (s..e).map(|i| linalg::norm2_sq(q.row(i))).collect();
        self.block_pair(qa, &q_sq, centers.points.as_slice(), &centers.sq_norms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::rng::Rng;

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(7));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn tiles_cover_range() {
        assert_eq!(tile_indices(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(tile_indices(4, 4), vec![(0, 4)]);
        assert_eq!(tile_indices(0, 4), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn matvec_matches_dense() {
        let eng = engine(60);
        let centers: Vec<usize> = vec![3, 10, 20, 33, 47];
        let v: Vec<f64> = (0..5).map(|i| (i as f64) * 0.5 - 1.0).collect();
        let all: Vec<usize> = (0..60).collect();
        let knm = eng.block(&all, &centers);
        let dense = linalg::matvec(&knm, &v);
        let streamed = eng.knm_matvec(&centers, &v);
        for (a, b) in dense.iter().zip(&streamed) {
            assert!((a - b).abs() < 1e-12);
        }
        // transpose version
        let u: Vec<f64> = (0..60).map(|i| ((i * i) as f64).sin()).collect();
        let dense_t = linalg::matvec_t(&knm, &u);
        let streamed_t = eng.knm_t_matvec(&centers, &u);
        for (a, b) in dense_t.iter().zip(&streamed_t) {
            assert!((a - b).abs() < 1e-12);
        }
        // fused K^T K v
        let fused = eng.knm_t_knm_matvec(&centers, &v);
        let two_pass = eng.knm_t_matvec(&centers, &eng.knm_matvec(&centers, &v));
        for (a, b) in fused.iter().zip(&two_pass) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn cross_block_matches_block_on_same_data() {
        let eng = engine(30);
        let rows = vec![2usize, 8, 14];
        let cols = vec![0usize, 29, 7];
        let q = Matrix::from_fn(3, eng.points().cols(), |i, j| eng.points().get(rows[i], j));
        let via_cross = eng.cross_block(&q, &cols);
        let via_block = eng.block(&rows, &cols);
        assert!(via_cross.max_abs_diff(&via_block) < 1e-12);
    }

    #[test]
    fn cached_center_paths_match_index_paths() {
        let eng = engine(120);
        let cols: Vec<usize> = vec![3, 10, 20, 33, 47, 90, 119];
        let c = eng.gather_centers(&cols);
        assert_eq!(c.m(), cols.len());
        // block_range == block on the same identity range (bitwise)
        let rows: Vec<usize> = (40..100).collect();
        let via_idx = eng.block(&rows, &cols);
        let via_range = eng.block_range(40, 100, &c);
        assert_eq!(via_idx.as_slice().len(), via_range.as_slice().len());
        for (a, b) in via_idx.as_slice().iter().zip(via_range.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "block_range diverged from block");
        }
        // block_range_into reuses a workspace of the wrong shape
        let mut ws = Matrix::zeros(3, 2);
        eng.block_range_into(40, 100, &c, &mut ws);
        for (a, b) in via_range.as_slice().iter().zip(ws.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "block_range_into diverged");
        }
        // centers_block == block(centers, cols)
        let other: Vec<usize> = vec![0, 7, 55];
        let cb = eng.centers_block(&c, &other);
        let cb_ref = eng.block(&cols, &other);
        for (a, b) in cb.as_slice().iter().zip(cb_ref.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "centers_block diverged");
        }
        // centers_square == block(centers, centers)
        let sq = eng.centers_square(&c);
        let sq_ref = eng.block(&cols, &cols);
        for (a, b) in sq.as_slice().iter().zip(sq_ref.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "centers_square diverged");
        }
        // cross_block_range == cross_block on the same query rows
        let q = Matrix::from_fn(9, eng.points().cols(), |i, j| eng.points().get(2 * i, j));
        let cr = eng.cross_block_range(&q, 2, 8, &c);
        let q_sub = Matrix::from_fn(6, q.cols(), |i, j| q.get(2 + i, j));
        let cr_ref = eng.cross_block(&q_sub, &cols);
        for (a, b) in cr.as_slice().iter().zip(cr_ref.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "cross_block_range diverged");
        }
    }

    #[test]
    fn knm_t_knm_is_psd_quadratic() {
        // vᵀ (KᵀK) v ≥ 0 for any v
        let eng = engine(50);
        let centers: Vec<usize> = vec![1, 5, 9, 13];
        let mut r = Rng::seeded(9);
        for _ in 0..5 {
            let v: Vec<f64> = (0..4).map(|_| r.gaussian()).collect();
            let z = eng.knm_t_knm_matvec(&centers, &v);
            assert!(linalg::dot(&v, &z) >= -1e-10);
        }
    }
}
