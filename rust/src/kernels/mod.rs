//! Kernel functions and kernel-matrix engines.
//!
//! The paper's compute hot-spot is the evaluation of Gaussian-kernel
//! blocks `K(X_I, X_J)` (leverage-score formulas, FALKON matvecs). The
//! [`KernelEngine`] trait abstracts *who* evaluates those blocks:
//!
//! * [`NativeEngine`] — pure-rust blocked evaluation via the row-norm
//!   trick `‖x−y‖² = ‖x‖² + ‖y‖² − 2·x·y` (GEMM-shaped); always
//!   available, used as the correctness baseline and in ablations.
//! * [`crate::runtime::XlaEngine`] — the production path: PJRT-compiled
//!   Pallas/JAX tiles produced by `make artifacts`.
//!
//! All downstream algorithms (BLESS, baselines, FALKON) are generic over
//! the engine, so switching the compute backend is a one-line change.
//!
//! On top of the engines sits the [`panel`] execution layer: a
//! memory-budgeted cache of `K_nM` row tiles ([`PanelCache`]) that lets
//! FALKON pay for kernel evaluation once per fit instead of once per CG
//! iteration, bit-identical to pure streaming at any budget.

mod engine;
mod gaussian;
pub mod panel;

pub use engine::{tile_indices, Centers, KernelEngine, NativeEngine, DEFAULT_ROW_TILE};
pub use gaussian::{fast_exp_neg, Gaussian};
pub use panel::{default_budget_bytes, PanelCache, PanelPlan, PanelStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::rng::Rng;

    #[test]
    fn engine_block_matches_pointwise() {
        let ds = susy_like(40, &mut Rng::seeded(0));
        let kern = Gaussian::new(2.0);
        let eng = NativeEngine::new(ds.x.clone(), kern.clone());
        let rows = vec![0, 5, 9];
        let cols = vec![1, 2, 3, 30];
        let b = eng.block(&rows, &cols);
        for (bi, &i) in rows.iter().enumerate() {
            for (bj, &j) in cols.iter().enumerate() {
                let direct = kern.eval(ds.x.row(i), ds.x.row(j));
                assert!(
                    (b.get(bi, bj) - direct).abs() < 1e-12,
                    "block ({bi},{bj}) mismatch"
                );
            }
        }
    }

    #[test]
    fn kernel_matrix_is_symmetric_with_unit_diag() {
        let ds = susy_like(25, &mut Rng::seeded(1));
        let eng = NativeEngine::new(ds.x.clone(), Gaussian::new(1.5));
        let all: Vec<usize> = (0..25).collect();
        let k = eng.block(&all, &all);
        for i in 0..25 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-12);
            for j in 0..i {
                assert!((k.get(i, j) - k.get(j, i)).abs() < 1e-12);
            }
        }
    }
}
