//! Memory-budgeted `K_nM` panel cache: pay for kernel evaluation once,
//! not once per CG iteration.
//!
//! FALKON's `O(n·M·t)` training cost assumes applying `K_nM` is cheap,
//! but a purely streaming solver re-evaluates every kernel tile — gather,
//! GEMM, exp — on every CG iteration, making training `t×` the cost of
//! one kernel sweep. The center set is **fixed** for the whole fit, so
//! row tiles of `K_nM` can be materialized once and streamed from memory
//! many times — if they fit. This module makes that trade explicit:
//!
//! * [`PanelPlan`] — given `n`, `M`, `d` and a byte budget (CLI
//!   `--mem-budget <MB>`; `0` = pure streaming), decides per row tile
//!   whether to **materialize once and reuse** or **recompute per use**.
//!   Tiles are the same fixed [`DEFAULT_ROW_TILE`] partition the
//!   streaming path uses, and the decision is a greedy prefix (tiles are
//!   interchangeable — each is touched exactly once per sweep), so the
//!   plan depends only on `(n, M, d, budget)`.
//! * [`PanelCache`] — holds the pre-gathered [`Centers`], the
//!   materialized tiles, and one reusable per-tile workspace for the
//!   recomputed remainder; serves the `K_nM` matvec family
//!   ([`knm_matvec`](PanelCache::knm_matvec),
//!   [`knm_t_matvec`](PanelCache::knm_t_matvec),
//!   [`knm_t_knm_matvec`](PanelCache::knm_t_knm_matvec)).
//!
//! **Determinism invariant:** a cached tile holds exactly the bytes the
//! streaming evaluator produces ([`KernelEngine::block_range_into`] is
//! required to match [`KernelEngine::block_range`] bitwise), the tile
//! partition never depends on the budget, and every downstream product
//! consumes tiles in the same order — so any budget (0, partial,
//! unbounded) and any thread count yield **bit-identical** results.
//! `rust/tests/panel_cache.rs` and `rust/tests/parallel_determinism.rs`
//! enforce this end-to-end through FALKON training and prediction.

use std::cell::{Cell, RefCell};

use super::{tile_indices, Centers, KernelEngine, DEFAULT_ROW_TILE};
use crate::linalg::{self, Matrix};

/// Fallback budget when total memory cannot be determined (1 GiB).
const FALLBACK_BUDGET: usize = 1 << 30;

/// Default panel budget: a quarter of physical RAM (read from
/// `/proc/meminfo`), falling back to 1 GiB when that is unavailable.
/// A quarter leaves room for the dataset, the preconditioner and the
/// serving tier while still caching the full `K_nM` panel for every
/// paper-scale shape (n=8000, M=2000 ⇒ 128 MiB).
pub fn default_budget_bytes() -> usize {
    total_memory_bytes().map(|t| t / 4).unwrap_or(FALLBACK_BUDGET)
}

/// `MemTotal` from `/proc/meminfo` (linux); `None` elsewhere.
fn total_memory_bytes() -> Option<usize> {
    let text = std::fs::read_to_string("/proc/meminfo").ok()?;
    let line = text.lines().find(|l| l.starts_with("MemTotal:"))?;
    let kb: usize = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb.saturating_mul(1024))
}

/// The materialize-vs-recompute decision for every row tile of `K_nM`.
#[derive(Clone, Debug)]
pub struct PanelPlan {
    /// Dataset rows `n`.
    pub n: usize,
    /// Center count `M`.
    pub m: usize,
    /// Feature dimension `d` (drives the fixed gather overhead).
    pub d: usize,
    /// Row-tile height (the streaming partition; fixed).
    pub tile_rows: usize,
    /// Number of leading tiles materialized; the rest are recomputed.
    pub cached_tiles: usize,
    /// Bytes the materialized tiles occupy.
    pub cached_bytes: usize,
    /// The budget the plan was built against.
    pub budget_bytes: usize,
}

impl PanelPlan {
    /// Plan for an `n × M` panel over features of dimension `d` within
    /// `budget_bytes`. Budget `0` disables caching (pure streaming);
    /// `usize::MAX` caches everything. The gathered center matrix and
    /// its norms (`M·(d+2)·8` bytes, always held) are charged against
    /// the budget first; remaining bytes are filled with a greedy prefix
    /// of [`DEFAULT_ROW_TILE`]-row tiles.
    pub fn new(n: usize, m: usize, d: usize, budget_bytes: usize) -> PanelPlan {
        let tile_rows = DEFAULT_ROW_TILE;
        let overhead = m.saturating_mul(d + 2).saturating_mul(8);
        let mut remaining = budget_bytes.saturating_sub(overhead);
        let mut cached_tiles = 0;
        let mut cached_bytes = 0usize;
        for (s, e) in tile_indices(n, tile_rows) {
            let bytes = (e - s).saturating_mul(m).saturating_mul(8);
            if bytes > remaining {
                break;
            }
            remaining -= bytes;
            cached_tiles += 1;
            cached_bytes += bytes;
        }
        PanelPlan { n, m, d, tile_rows, cached_tiles, cached_bytes, budget_bytes }
    }

    /// Total number of row tiles.
    pub fn tiles(&self) -> usize {
        self.n.div_ceil(self.tile_rows)
    }

    /// Whether tile `t` is materialized under this plan.
    pub fn is_cached(&self, t: usize) -> bool {
        t < self.cached_tiles
    }

    /// Whether every tile is materialized (no recomputation at all).
    pub fn fully_cached(&self) -> bool {
        self.cached_tiles == self.tiles()
    }
}

/// Counters describing how much kernel work a [`PanelCache`] performed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanelStats {
    /// Kernel entries evaluated (materialization + streamed recomputes).
    pub entries_evaluated: u64,
    /// Tile serves answered from the materialized store.
    pub cached_hits: u64,
    /// Tile serves that recomputed into the workspace.
    pub streamed: u64,
    /// Bytes of tile data produced by streamed recomputes (`/metrics`
    /// and `train --trace` report this as recompute traffic).
    pub streamed_bytes: u64,
}

/// Per-sweep scratch reused by every recomputed tile, plus the
/// tile-local product vector of the fused matvec. Full-height and tail
/// tiles get separate workspaces (their shapes differ whenever `n` is
/// not a multiple of the tile height, and one buffer would be reshaped
/// twice per sweep), so after the first sweep the streaming path
/// allocates nothing per tile. Living inside the cache, all three
/// survive across CG iterations.
struct Scratch {
    full_ws: Matrix,
    tail_ws: Matrix,
    w: Vec<f64>,
}

/// A `K_nM` panel bound to one engine + center set, serving bit-identical
/// tiles from memory (within budget) or by recomputation (beyond it).
///
/// Construction eagerly materializes the planned tiles — one kernel
/// sweep — so the preconditioner right-hand side, every CG iteration and
/// training-set prediction all stream from memory afterwards. See the
/// [module docs](self) for the budget heuristic and the determinism
/// invariant.
pub struct PanelCache<'a> {
    engine: &'a dyn KernelEngine,
    centers: std::sync::Arc<Centers>,
    plan: PanelPlan,
    tiles: Vec<Option<Matrix>>,
    scratch: RefCell<Scratch>,
    entries_evaluated: Cell<u64>,
    cached_hits: Cell<u64>,
    streamed: Cell<u64>,
    streamed_bytes: Cell<u64>,
}

impl<'a> PanelCache<'a> {
    /// Build a cache for `centers` within `budget_bytes` (see
    /// [`PanelPlan::new`]); materializes the planned tiles eagerly.
    pub fn new(engine: &'a dyn KernelEngine, centers: &[usize], budget_bytes: usize) -> Self {
        let centers = std::sync::Arc::new(engine.gather_centers(centers));
        let m = centers.m();
        let n = engine.n();
        let plan = PanelPlan::new(n, m, engine.points().cols(), budget_bytes);
        let mut cache = PanelCache {
            engine,
            centers,
            tiles: vec![None; plan.tiles()],
            plan,
            scratch: RefCell::new(Scratch {
                full_ws: Matrix::zeros(0, 0),
                tail_ws: Matrix::zeros(0, 0),
                w: Vec::new(),
            }),
            entries_evaluated: Cell::new(0),
            cached_hits: Cell::new(0),
            streamed: Cell::new(0),
            streamed_bytes: Cell::new(0),
        };
        // Materialize the planned prefix eagerly — one kernel sweep over
        // the cached tiles, through the *same* evaluator the streaming
        // path uses, so stored and recomputed tiles agree bitwise.
        for (t, (s, e)) in tile_indices(n, cache.plan.tile_rows).into_iter().enumerate() {
            if !cache.plan.is_cached(t) {
                break;
            }
            let blk = cache.engine.block_range(s, e, &cache.centers);
            let evals = ((e - s) * m) as u64;
            cache.entries_evaluated.set(cache.entries_evaluated.get() + evals);
            cache.tiles[t] = Some(blk);
        }
        cache
    }

    /// Build with the process default budget ([`default_budget_bytes`]).
    pub fn with_default_budget(engine: &'a dyn KernelEngine, centers: &[usize]) -> Self {
        Self::new(engine, centers, default_budget_bytes())
    }

    /// The pre-gathered center set (shared with fitted models).
    pub fn centers(&self) -> &Centers {
        &self.centers
    }

    /// A cheaply clonable handle to the center set.
    pub fn centers_arc(&self) -> std::sync::Arc<Centers> {
        std::sync::Arc::clone(&self.centers)
    }

    /// The materialize-vs-recompute plan in force.
    pub fn plan(&self) -> &PanelPlan {
        &self.plan
    }

    /// Number of centers `M`.
    pub fn m(&self) -> usize {
        self.centers.m()
    }

    /// Dataset rows `n`.
    pub fn n(&self) -> usize {
        self.plan.n
    }

    /// Work counters accumulated since construction.
    pub fn stats(&self) -> PanelStats {
        PanelStats {
            entries_evaluated: self.entries_evaluated.get(),
            cached_hits: self.cached_hits.get(),
            streamed: self.streamed.get(),
            streamed_bytes: self.streamed_bytes.get(),
        }
    }

    /// `y = K_nM · v` (length-`n` out) — prediction on the training set.
    pub fn knm_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.m());
        let mut y = vec![0.0; self.n()];
        let mut guard = self.scratch.borrow_mut();
        let Scratch { full_ws, tail_ws, .. } = &mut *guard;
        for (t, (s, e)) in tile_indices(self.n(), self.plan.tile_rows).into_iter().enumerate() {
            let ws = if e - s == self.plan.tile_rows { &mut *full_ws } else { &mut *tail_ws };
            let blk = self.tile(t, s, e, ws);
            linalg::matvec_into(blk, v, &mut y[s..e]);
        }
        y
    }

    /// `z = K_nMᵀ · u` (length-`M` out) — the FALKON right-hand side.
    pub fn knm_t_matvec(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.n());
        let mut z = vec![0.0; self.m()];
        let mut guard = self.scratch.borrow_mut();
        let Scratch { full_ws, tail_ws, .. } = &mut *guard;
        for (t, (s, e)) in tile_indices(self.n(), self.plan.tile_rows).into_iter().enumerate() {
            let ws = if e - s == self.plan.tile_rows { &mut *full_ws } else { &mut *tail_ws };
            let blk = self.tile(t, s, e, ws);
            linalg::matvec_t_acc(blk, &u[s..e], &mut z);
        }
        z
    }

    /// Fused `z = K_nMᵀ (K_nM v)` — the CG hot loop. Each tile is served
    /// once per call and used for both products.
    pub fn knm_t_knm_matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut z = vec![0.0; self.m()];
        self.knm_t_knm_matvec_into(v, &mut z);
        z
    }

    /// [`Self::knm_t_knm_matvec`] into a caller buffer (zeroed first) —
    /// lets the CG loop reuse one output vector across iterations.
    pub fn knm_t_knm_matvec_into(&self, v: &[f64], z: &mut [f64]) {
        assert_eq!(v.len(), self.m());
        assert_eq!(z.len(), self.m());
        z.fill(0.0);
        let mut guard = self.scratch.borrow_mut();
        let Scratch { full_ws, tail_ws, w } = &mut *guard;
        if w.len() < self.plan.tile_rows {
            w.resize(self.plan.tile_rows, 0.0);
        }
        for (t, (s, e)) in tile_indices(self.n(), self.plan.tile_rows).into_iter().enumerate() {
            let ws = if e - s == self.plan.tile_rows { &mut *full_ws } else { &mut *tail_ws };
            let blk = self.tile(t, s, e, ws);
            linalg::matvec_into(blk, v, &mut w[..e - s]);
            linalg::matvec_t_acc(blk, &w[..e - s], z);
        }
    }

    /// Serve tile `t` (rows `s..e`): from the materialized store when the
    /// plan cached it, otherwise recomputed into `ws`. Either way the
    /// returned tile is bitwise the streaming evaluator's output.
    fn tile<'w>(&'w self, t: usize, s: usize, e: usize, ws: &'w mut Matrix) -> &'w Matrix {
        match &self.tiles[t] {
            Some(m) => {
                self.cached_hits.set(self.cached_hits.get() + 1);
                m
            }
            None => {
                self.engine.block_range_into(s, e, &self.centers, ws);
                let entries = ((e - s) * self.m()) as u64;
                self.entries_evaluated.set(self.entries_evaluated.get() + entries);
                self.streamed.set(self.streamed.get() + 1);
                self.streamed_bytes.set(self.streamed_bytes.get() + entries * 8);
                ws
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::rng::Rng;

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(17));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    fn bits_of(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn plan_budget_extremes() {
        let p0 = PanelPlan::new(5_000, 300, 18, 0);
        assert_eq!(p0.cached_tiles, 0);
        assert_eq!(p0.cached_bytes, 0);
        assert!(!p0.fully_cached());
        let pall = PanelPlan::new(5_000, 300, 18, usize::MAX);
        assert!(pall.fully_cached());
        assert_eq!(pall.tiles(), 5);
        assert_eq!(pall.cached_bytes, 5_000 * 300 * 8);
    }

    #[test]
    fn plan_partial_budget_is_greedy_prefix() {
        // budget for the center overhead + exactly two full tiles
        let (n, m, d) = (5_000, 300, 18);
        let overhead = m * (d + 2) * 8;
        let tile_bytes = DEFAULT_ROW_TILE * m * 8;
        let p = PanelPlan::new(n, m, d, overhead + 2 * tile_bytes + tile_bytes / 2);
        assert_eq!(p.cached_tiles, 2);
        assert!(p.is_cached(0) && p.is_cached(1) && !p.is_cached(2));
        assert_eq!(p.cached_bytes, 2 * tile_bytes);
    }

    #[test]
    fn cached_and_streaming_matvecs_agree_bitwise() {
        let eng = engine(2_500); // 3 tiles: 1024 + 1024 + 452
        let centers: Vec<usize> = (0..60).map(|i| i * 41).collect();
        let v: Vec<f64> = (0..60).map(|i| ((i as f64) * 0.23).sin()).collect();
        let u: Vec<f64> = (0..2_500).map(|i| ((i as f64) * 0.017).cos()).collect();
        let streaming = PanelCache::new(&eng, &centers, 0);
        let partial = {
            let overhead = centers.len() * (18 + 2) * 8;
            PanelCache::new(&eng, &centers, overhead + DEFAULT_ROW_TILE * centers.len() * 8)
        };
        let cached = PanelCache::new(&eng, &centers, usize::MAX);
        assert_eq!(streaming.plan().cached_tiles, 0);
        assert_eq!(partial.plan().cached_tiles, 1);
        assert!(cached.plan().fully_cached());
        for cache in [&streaming, &partial, &cached] {
            assert_eq!(bits_of(&cache.knm_matvec(&v)), bits_of(&eng.knm_matvec(&centers, &v)));
            assert_eq!(
                bits_of(&cache.knm_t_matvec(&u)),
                bits_of(&eng.knm_t_matvec(&centers, &u))
            );
            assert_eq!(
                bits_of(&cache.knm_t_knm_matvec(&v)),
                bits_of(&eng.knm_t_knm_matvec(&centers, &v))
            );
        }
    }

    #[test]
    fn fully_cached_panel_evaluates_each_entry_once() {
        let eng = engine(2_000);
        let centers: Vec<usize> = (0..40).map(|i| i * 17).collect();
        let cache = PanelCache::new(&eng, &centers, usize::MAX);
        let after_build = cache.stats();
        assert_eq!(after_build.entries_evaluated, (2_000 * 40) as u64);
        let v: Vec<f64> = (0..40).map(|i| i as f64 * 0.1).collect();
        for _ in 0..5 {
            let _ = cache.knm_t_knm_matvec(&v);
        }
        let after_sweeps = cache.stats();
        assert_eq!(
            after_sweeps.entries_evaluated, after_build.entries_evaluated,
            "cached sweeps must not re-evaluate the kernel"
        );
        assert_eq!(after_sweeps.streamed, 0);
        assert_eq!(after_sweeps.streamed_bytes, 0);
        assert_eq!(after_sweeps.cached_hits, 5 * 2); // 2 tiles × 5 sweeps
    }

    #[test]
    fn streaming_panel_reevaluates_each_sweep() {
        let eng = engine(1_500);
        let centers: Vec<usize> = (0..30).map(|i| i * 11).collect();
        let cache = PanelCache::new(&eng, &centers, 0);
        assert_eq!(cache.stats().entries_evaluated, 0, "budget 0 must not materialize");
        let v: Vec<f64> = vec![0.5; 30];
        for _ in 0..3 {
            let _ = cache.knm_t_knm_matvec(&v);
        }
        assert_eq!(cache.stats().entries_evaluated, (3 * 1_500 * 30) as u64);
        assert_eq!(cache.stats().cached_hits, 0);
        assert_eq!(cache.stats().streamed, 3 * 2); // 2 tiles × 3 sweeps
        assert_eq!(cache.stats().streamed_bytes, (3 * 1_500 * 30 * 8) as u64);
    }

    #[test]
    fn into_variant_reuses_output() {
        let eng = engine(900);
        let centers: Vec<usize> = (0..25).map(|i| i * 7).collect();
        let cache = PanelCache::with_default_budget(&eng, &centers);
        let v: Vec<f64> = (0..25).map(|i| (i as f64 * 0.3).sin()).collect();
        let direct = cache.knm_t_knm_matvec(&v);
        let mut out = vec![123.0; 25];
        cache.knm_t_knm_matvec_into(&v, &mut out);
        assert_eq!(bits_of(&direct), bits_of(&out));
    }
}
