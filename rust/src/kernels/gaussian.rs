//! The Gaussian (RBF) kernel used throughout the paper's experiments.

/// Fast `exp(x)` for `x ≤ 0` — the kernel-block hot loop is exp-bound
/// (perf pass, EXPERIMENTS.md §Perf), and `f64::exp` costs ~10 ns/call.
///
/// Range-reduction `exp(x) = 2^k · e^z` with `k = round(x·log2 e)` and
/// `z = x − k·ln 2 ∈ [−0.347, 0.347]`, degree-8 Taylor for `e^z`
/// (relative error < 3e-10, far below the f32 accuracy of the Pallas
/// tiles), exponent assembled with bit arithmetic. Branch-light so the
/// surrounding loops auto-vectorize.
#[inline]
pub fn fast_exp_neg(x: f64) -> f64 {
    debug_assert!(x <= 1e-9, "fast_exp_neg expects non-positive input");
    if x < -708.0 {
        return 0.0;
    }
    const LOG2E: f64 = std::f64::consts::LOG2_E;
    const LN2_HI: f64 = 0.693_147_180_369_123_8;
    const LN2_LO: f64 = 1.908_214_929_270_587_7e-10;
    let y = x * LOG2E;
    let k = (y + 0.5).floor(); // round-to-nearest for y ≤ 0
    let z = (x - k * LN2_HI) - k * LN2_LO;
    // e^z, |z| ≤ 0.3466: Horner degree 8
    let p = 1.0
        + z * (1.0
            + z * (0.5
                + z * (1.0 / 6.0
                    + z * (1.0 / 24.0
                        + z * (1.0 / 120.0
                            + z * (1.0 / 720.0
                                + z * (1.0 / 5040.0 + z * (1.0 / 40320.0))))))));
    // scale by 2^k via exponent bits (k ∈ [-1075, 1); subnormals handled
    // by the early-out above at -708)
    let bits = ((k as i64 + 1023) as u64) << 52;
    p * f64::from_bits(bits)
}

/// Gaussian kernel `K(x, x') = exp(−‖x−x'‖² / (2σ²))`.
///
/// Bounded by `κ² = 1` (Eq. 17 of the paper with κ = 1), which the
/// algorithms exploit (`λ₀ = κ²`, `R_h = q₁·min(κ²/λ_h, n)`).
#[derive(Clone, Debug)]
pub struct Gaussian {
    sigma: f64,
    gamma: f64,
}

impl Gaussian {
    /// Kernel with bandwidth `sigma` (the paper uses σ = 4 for SUSY,
    /// σ = 22 for HIGGS).
    pub fn new(sigma: f64) -> Self {
        assert!(sigma > 0.0, "bandwidth must be positive");
        Gaussian { sigma, gamma: 1.0 / (2.0 * sigma * sigma) }
    }

    /// Bandwidth σ.
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// `γ = 1/(2σ²)` — the form the AOT kernels take as input.
    pub fn gamma(&self) -> f64 {
        self.gamma
    }

    /// `κ² = sup_x K(x,x)`.
    pub fn kappa_sq(&self) -> f64 {
        1.0
    }

    /// Evaluate on a pair of points.
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut d2 = 0.0;
        for (a, b) in x.iter().zip(y) {
            let diff = a - b;
            d2 += diff * diff;
        }
        (-self.gamma * d2).exp()
    }

    /// Evaluate from a precomputed squared distance.
    #[inline]
    pub fn from_sq_dist(&self, d2: f64) -> f64 {
        // clamp tiny negative values produced by the ‖x‖²+‖y‖²−2x·y trick.
        // NOTE (§Perf): a range-reduced polynomial exp ([`fast_exp_neg`])
        // was measured at 6.5 ns/call vs 5.0 ns for `f64::exp` on this
        // target (glibc's exp already vectorizes well) — change reverted,
        // see EXPERIMENTS.md §Perf iteration log.
        (-self.gamma * d2.max(0.0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_similarity_is_one() {
        let k = Gaussian::new(3.0);
        let x = vec![1.0, -2.0, 0.5];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn known_value() {
        let k = Gaussian::new(1.0);
        // ‖(0)−(2)‖² = 4 → exp(−4/2) = exp(−2); fast_exp_neg is accurate
        // to ~3e-10 relative
        assert!((k.eval(&[0.0], &[2.0]) - (-2.0f64).exp()).abs() < 1e-9);
    }

    #[test]
    fn fast_exp_matches_std_exp() {
        // dense sweep over the whole kernel-relevant range
        let mut worst = 0.0f64;
        let mut x = -700.0;
        while x <= 0.0 {
            let got = fast_exp_neg(x);
            let want = x.exp();
            let rel = if want > 0.0 { (got - want).abs() / want } else { got };
            worst = worst.max(rel);
            x += 0.0173; // irrational-ish step to avoid hitting only integers
        }
        assert!(worst < 1e-9, "worst relative error {worst}");
        assert_eq!(fast_exp_neg(-800.0), 0.0);
        assert_eq!(fast_exp_neg(0.0), 1.0);
    }

    #[test]
    fn symmetry_and_bounds() {
        let k = Gaussian::new(0.7);
        let x = vec![0.3, 1.2];
        let y = vec![-0.5, 2.0];
        assert_eq!(k.eval(&x, &y), k.eval(&y, &x));
        let v = k.eval(&x, &y);
        assert!(v > 0.0 && v < 1.0);
        assert_eq!(k.kappa_sq(), 1.0);
    }

    #[test]
    fn sq_dist_form_clamps_negative() {
        let k = Gaussian::new(1.0);
        assert_eq!(k.from_sq_dist(-1e-14), 1.0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_bandwidth_rejected() {
        Gaussian::new(0.0);
    }
}
