//! Sketched ridge leverage-score estimators (El Alaoui & Mahoney 2015):
//! count-sketch (Clarkson–Woodruff) and the subsampled randomized
//! Hadamard transform (SRFT), applied to the kernel square root.
//!
//! With `K = L Lᵀ` (jittered Cholesky of `K` itself), the push-through
//! identity gives the **exact** scores as
//! `ℓ(i,λ) = row_i(L) · (LᵀL + λnI)⁻¹ · row_i(L)ᵀ`. Sketching replaces
//! `L` by `B = L Sᵀ` (`n × s`, `s ≪ n`) so the Gram solve shrinks from
//! `n × n` to `s × s`:
//!
//! `ℓ̃(i,λ) = b_i (BᵀB + λnI)⁻¹ b_iᵀ`
//!
//! which is precisely the exact score of the approximate kernel
//! `K̃ = B Bᵀ = L SᵀS Lᵀ` — so `S = I` (or any orthonormal `S`, e.g.
//! SRFT at `s = p`) recovers the exact scores up to float, and the
//! quality degrades gracefully with the JL property of `SᵀS ≈ I`.
//!
//! The solve never forms `BᵀB`: the `R` factor of the stacked
//! `(n+s) × s` matrix `[B; √(λn)·I]` (new blocked Householder QR,
//! [`crate::linalg::qr`]) satisfies `RᵀR = BᵀB + λnI`, so
//! `ℓ̃(i,λ) = ‖R⁻ᵀ b_iᵀ‖²` — one triangular solve, numerically stable
//! even when `B` is ill-conditioned. Both sketch applications are
//! pool-parallel over fixed output-row blocks (each output row depends
//! only on its own row of `L`), keeping the scores bit-identical at any
//! thread count.

use crate::kernels::KernelEngine;
use crate::leverage::{Estimate, LeverageError, LeverageEstimator};
use crate::linalg::{cholesky_jittered, column_sq_norms, qr, Matrix};
use crate::rng::Rng;
use crate::util::pool;

/// Row-block height of the parallel sketch application.
const SKETCH_RB: usize = 64;
/// Minimum madds before the sketch application dispatches to the pool.
const PAR_MIN_SKETCH: usize = 1 << 14;

/// Jittered Cholesky square root `L` of the kernel matrix itself.
///
/// `K` is PSD but numerically rank-deficient for smooth kernels (its
/// spectrum decays below machine precision), so a plain factorization
/// routinely fails; escalating diagonal jitter `δI` factors `K + δI`
/// instead, perturbing the estimated scores by `O(δ/λn)` — negligible
/// against the sketching error.
fn kernel_sqrt(engine: &dyn KernelEngine, lambda: f64) -> Result<Matrix, LeverageError> {
    let n = engine.n();
    if n == 0 || !(lambda > 0.0) {
        return Err(LeverageError::InvalidConfig(format!("n={n}, lambda={lambda}")));
    }
    let all: Vec<usize> = (0..n).collect();
    let mut k = engine.block(&all, &all);
    // bitwise symmetry for the factorization's symmetry contract
    k.mirror_lower_to_upper();
    let trace: f64 = k.diagonal().iter().sum();
    let (f, _jitter) = cholesky_jittered(k, trace.abs() * 1e-12 / n as f64, trace.abs().max(1.0))
        .ok_or(LeverageError::FactorizationFailed { dim: n, lambda })?;
    Ok(f.take_l())
}

/// Shared tail of both sketched estimators: given `B = L Sᵀ`, solve the
/// regularized sketched Gram system via the stacked QR and return
/// `ℓ̃_i = ‖R⁻ᵀ b_i‖²`, clamped to `[1e-300, 1]`.
fn scores_from_sketch(b: &Matrix, lam_n: f64) -> Vec<f64> {
    let (n, s) = (b.rows(), b.cols());
    let mut stacked = Matrix::zeros(n + s, s);
    for r in 0..n {
        stacked.row_mut(r).copy_from_slice(b.row(r));
    }
    for j in 0..s {
        stacked.set(n + j, j, lam_n.sqrt());
    }
    let f = qr(stacked);
    let z = f.solve_rt_matrix(&b.transpose());
    column_sq_norms(&z).into_iter().map(|v| v.clamp(1e-300, 1.0)).collect()
}

/// Peak dense workspace of a sketched run at size `(n, s)`: the kernel
/// matrix / its square root, the sketch `B`, the stacked QR input, and
/// the `s × n` solve operands.
fn sketch_peak_bytes(n: usize, s: usize) -> u64 {
    8 * (n * n + n * s + (n + s) * s + 2 * s * n) as u64
}

/// Count-sketch (Clarkson–Woodruff transform): `S` has one `±1` per
/// column of `L`, placed in a hashed row. Applying it is a single
/// `O(n²)` pass over `L` — no multiplication by a dense test matrix —
/// making it the cheapest sketch per entry.
pub struct CountSketchEstimator {
    /// Sketch size (columns of `B`); theory wants `s ≳ d_eff²/ε²`.
    pub s: usize,
}

impl CountSketchEstimator {
    /// Apply the count-sketch to the rows of lower-triangular `L`:
    /// `B[i, h(j)] += σ(j)·L[i,j]`. The hash/sign draws consume exactly
    /// `2n` values from `rng`; the application is parallel over fixed
    /// blocks of output rows (each reads only its own row of `L`).
    fn apply(&self, l: &Matrix, rng: &mut Rng) -> Matrix {
        let n = l.rows();
        let s = self.s;
        let h: Vec<usize> = (0..n).map(|_| rng.below(s)).collect();
        let sg: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let mut b = Matrix::zeros(n, s);
        let ld = l.as_slice();
        let parallel = n * n / 2 >= PAR_MIN_SKETCH;
        pool::par_chunks_mut_gated(b.as_mut_slice(), SKETCH_RB * s, parallel, |blk, chunk| {
            for (local, row) in chunk.chunks_mut(s).enumerate() {
                let i = blk * SKETCH_RB + local;
                // L is lower triangular: columns 0..=i only
                for (j, &v) in ld[i * n..i * n + i + 1].iter().enumerate() {
                    row[h[j]] += sg[j] * v;
                }
            }
        });
        b
    }
}

impl LeverageEstimator for CountSketchEstimator {
    fn name(&self) -> String {
        format!("count-sketch(s={})", self.s)
    }

    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Estimate, LeverageError> {
        if self.s == 0 {
            return Err(LeverageError::InvalidConfig("count-sketch size s must be ≥ 1".into()));
        }
        let n = engine.n();
        let l = kernel_sqrt(engine, lambda)?;
        let b = self.apply(&l, rng);
        drop(l);
        let scores = scores_from_sketch(&b, lambda * n as f64);
        Ok(Estimate::new(scores, sketch_peak_bytes(n, self.s)))
    }
}

/// In-place unnormalized fast Walsh–Hadamard transform (length must be a
/// power of two). Serial per row — the parallel unit is the row.
fn fwht(v: &mut [f64]) {
    let p = v.len();
    debug_assert!(p.is_power_of_two());
    let mut h = 1;
    while h < p {
        let mut i = 0;
        while i < p {
            for j in i..i + h {
                let (x, y) = (v[j], v[j + h]);
                v[j] = x + y;
                v[j + h] = x - y;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// Subsampled randomized Hadamard transform:
/// `S = √(p/s) · P · (H/√p) · D` with `p = 2^⌈log₂ n⌉`, `D` a random
/// sign diagonal, `H` the Walsh–Hadamard matrix and `P` a subsample of
/// `s` of the `p` coordinates without replacement.
///
/// At `s = p`, `SᵀS = I` exactly (orthonormal rows, full subsample), so
/// the estimator reproduces the exact scores up to float — the tight
/// anchor case in `tests/estimator_accuracy.rs`.
pub struct SrftEstimator {
    /// Sketch size (clamped to `p`, the padded power of two).
    pub s: usize,
}

impl SrftEstimator {
    /// Apply the SRFT to the rows of `L`: per output row, sign-flip,
    /// zero-pad to `p`, transform, subsample `s` fixed coordinates.
    /// Draws `n` signs + one subsample from `rng`, then runs parallel
    /// over fixed blocks of rows.
    fn apply(&self, l: &Matrix, rng: &mut Rng) -> Matrix {
        let n = l.rows();
        let p = n.next_power_of_two();
        let s = self.s.min(p);
        let sg: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let coords = rng.sample_without_replacement(p, s);
        // √(p/s) subsample scale × 1/√p orthonormal-H scale = 1/√s
        let scale = (s as f64).sqrt().recip();
        let mut b = Matrix::zeros(n, s);
        let ld = l.as_slice();
        let parallel = n * (p + s) >= PAR_MIN_SKETCH;
        pool::par_chunks_mut_gated(b.as_mut_slice(), SKETCH_RB * s, parallel, |blk, chunk| {
            let mut buf = vec![0.0; p];
            for (local, row) in chunk.chunks_mut(s).enumerate() {
                let i = blk * SKETCH_RB + local;
                buf.fill(0.0);
                for (j, &v) in ld[i * n..i * n + i + 1].iter().enumerate() {
                    buf[j] = sg[j] * v;
                }
                fwht(&mut buf);
                for (t, &c) in coords.iter().enumerate() {
                    row[t] = buf[c] * scale;
                }
            }
        });
        b
    }
}

impl LeverageEstimator for SrftEstimator {
    fn name(&self) -> String {
        format!("srft(s={})", self.s)
    }

    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Estimate, LeverageError> {
        if self.s == 0 {
            return Err(LeverageError::InvalidConfig("SRFT size s must be ≥ 1".into()));
        }
        let n = engine.n();
        let l = kernel_sqrt(engine, lambda)?;
        let b = self.apply(&l, rng);
        drop(l);
        let scores = scores_from_sketch(&b, lambda * n as f64);
        Ok(Estimate::new(scores, sketch_peak_bytes(n, b.cols())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{exact_leverage_scores, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(17));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn fwht_is_orthogonal_involution() {
        // H (H x) = p·x for the unnormalized transform
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.9).sin()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((8.0 * a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_sketch_recovers_exact_scores() {
        // SRFT at s = p is an orthonormal S: SᵀS = I ⇒ exact scores.
        let n = 64; // power of two: p = n, no padding
        let eng = engine(n);
        let lambda = 2e-2;
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let est = SrftEstimator { s: 64 };
        let approx = est.scores(&eng, lambda, &mut Rng::seeded(3)).unwrap();
        let stats = RAccStats::from_scores(&approx, &exact);
        assert!(stats.within_bound(1e-4), "orthonormal sketch not exact: {stats:?}");
    }

    #[test]
    fn sketched_scores_are_plausible_at_moderate_size() {
        let eng = engine(200);
        let lambda = 2e-2;
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        for est in [
            Box::new(CountSketchEstimator { s: 512 }) as Box<dyn LeverageEstimator>,
            Box::new(SrftEstimator { s: 128 }),
        ] {
            let approx = est.scores(&eng, lambda, &mut Rng::seeded(11)).unwrap();
            assert_eq!(approx.len(), 200);
            assert!(approx.iter().all(|&v| v.is_finite() && v > 0.0 && v <= 1.0));
            let stats = RAccStats::from_scores(&approx, &exact);
            assert!(
                stats.mean > 0.4 && stats.mean < 2.5,
                "{}: mean R-ACC {} implausible",
                est.name(),
                stats.mean
            );
        }
    }

    #[test]
    fn zero_sketch_size_is_config_error() {
        let eng = engine(16);
        for est in [
            Box::new(CountSketchEstimator { s: 0 }) as Box<dyn LeverageEstimator>,
            Box::new(SrftEstimator { s: 0 }),
        ] {
            let err = est.estimate(&eng, 1e-2, &mut Rng::seeded(0)).unwrap_err();
            assert!(matches!(err, LeverageError::InvalidConfig(_)), "{err}");
        }
    }

    #[test]
    fn oversized_srft_clamps_to_padded_dimension() {
        let eng = engine(20); // p = 32
        let est = SrftEstimator { s: 1000 };
        let out = est.estimate(&eng, 1e-2, &mut Rng::seeded(5)).unwrap();
        assert_eq!(out.scores.len(), 20);
        assert!(out.scores.iter().all(|&v| v.is_finite()));
    }
}
