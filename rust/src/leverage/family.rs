//! The leverage-score **estimator family**: one trait over every way
//! this crate approximates ridge leverage scores, plus uniform cost
//! accounting.
//!
//! The paper's central comparison — BLESS vs the rest of the field — is
//! only meaningful when every competitor answers the same question
//! through the same interface: *"scores for all `n` points at `λ`,
//! given a kernel engine and a seed"*. [`LeverageEstimator`] is that
//! interface; [`run_estimator`] wraps the engine in a
//! [`CountingEngine`] so kernel-entry evaluations are measured rather
//! than estimated, and each estimator reports its actual peak dense
//! workspace. The fig1/fig2 shoot-out and `BENCH_estimators.json` are
//! built on these three pieces.
//!
//! Members: [`ExactEstimator`] (O(n³) reference), [`BlessEstimator`]
//! (Alg. 1), [`RrlsEstimator`] (Bernoulli recursive RLS baseline),
//! [`CountSketchEstimator`] / [`SrftEstimator`] (El Alaoui &
//! Mahoney-style sketches of the kernel square root), and
//! [`RlsNystromEstimator`] (Musco & Musco fixed-size recursive
//! Nyström).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::baselines::{rrls, RrlsConfig};
use crate::bless::{bless, BlessConfig};
use crate::kernels::{Centers, Gaussian, KernelEngine, DEFAULT_ROW_TILE};
use crate::leverage::{
    exact_leverage_scores, CountSketchEstimator, LeverageError, LsGenerator,
    RecursiveNystromConfig, RlsNystromEstimator, SrftEstimator,
};
use crate::linalg::Matrix;
use crate::rng::Rng;

/// The result of one estimator run: the scores plus cost accounting.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Approximate (or exact) scores `ℓ̃(i,λ)` for every point `0..n`.
    pub scores: Vec<f64>,
    /// Peak dense workspace the estimator allocated, in bytes —
    /// computed by the estimator from its *actual* dictionary / sketch
    /// sizes, not a static bound.
    pub peak_bytes: u64,
    /// Kernel entries evaluated. Estimators leave this 0; it is filled
    /// in by [`run_estimator`]'s [`CountingEngine`].
    pub kernel_evals: u64,
}

impl Estimate {
    /// An estimate with the given scores and workspace, evals unfilled.
    pub fn new(scores: Vec<f64>, peak_bytes: u64) -> Self {
        Estimate { scores, peak_bytes, kernel_evals: 0 }
    }
}

/// A ridge leverage-score estimator: anything that can produce scores
/// for all `n` points of a [`KernelEngine`]'s dataset at level `λ`.
///
/// Contract shared by every implementation:
/// - scores are clamped positive and finite on success;
/// - the same `(engine, lambda, seed)` triple yields **bitwise
///   identical** scores at any `--threads` (the determinism tier in
///   `tests/parallel_determinism.rs` enforces this);
/// - all randomness is drawn from the passed [`Rng`] — no hidden state,
///   so seed-sensitivity is testable (`util/prop.rs`).
pub trait LeverageEstimator {
    /// Display name including parameters, e.g. `srft(s=256)`.
    fn name(&self) -> String;

    /// Estimate scores for every point at regularization `λ`.
    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Estimate, LeverageError>;

    /// Convenience: scores only.
    fn scores(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Vec<f64>, LeverageError> {
        Ok(self.estimate(engine, lambda, rng)?.scores)
    }
}

/// Run an estimator with kernel-evaluation metering: wraps `engine` in a
/// [`CountingEngine`] and fills [`Estimate::kernel_evals`] with the
/// measured count.
pub fn run_estimator(
    est: &dyn LeverageEstimator,
    engine: &dyn KernelEngine,
    lambda: f64,
    rng: &mut Rng,
) -> Result<Estimate, LeverageError> {
    let counting = CountingEngine::new(engine);
    let mut out = est.estimate(&counting, lambda, rng)?;
    out.kernel_evals = counting.kernel_evals();
    Ok(out)
}

/// A [`KernelEngine`] decorator that counts evaluated kernel entries.
///
/// Every block-producing method is overridden to add `rows × cols` to an
/// atomic counter before delegating; the `knm_*` streaming defaults
/// bottom out in the overridden `block_range`, so they are metered too.
/// `diag`/`gather_centers` delegate without counting — the Gaussian
/// diagonal is free and gathers evaluate nothing.
pub struct CountingEngine<'a> {
    inner: &'a dyn KernelEngine,
    evals: AtomicU64,
}

impl<'a> CountingEngine<'a> {
    /// Wrap an engine with a zeroed counter.
    pub fn new(inner: &'a dyn KernelEngine) -> Self {
        CountingEngine { inner, evals: AtomicU64::new(0) }
    }

    /// Kernel entries evaluated through this wrapper so far.
    pub fn kernel_evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    fn add(&self, rows: usize, cols: usize) {
        self.evals.fetch_add((rows * cols) as u64, Ordering::Relaxed);
    }
}

impl KernelEngine for CountingEngine<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn kernel(&self) -> &Gaussian {
        self.inner.kernel()
    }

    fn points(&self) -> &Matrix {
        self.inner.points()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.add(rows.len(), cols.len());
        self.inner.block(rows, cols)
    }

    fn cross_block(&self, q: &Matrix, cols: &[usize]) -> Matrix {
        self.add(q.rows(), cols.len());
        self.inner.cross_block(q, cols)
    }

    fn diag(&self, idx: &[usize]) -> Vec<f64> {
        self.inner.diag(idx)
    }

    fn kappa_sq(&self) -> f64 {
        self.inner.kappa_sq()
    }

    fn gather_centers(&self, idx: &[usize]) -> Centers {
        self.inner.gather_centers(idx)
    }

    fn block_range(&self, s: usize, e: usize, centers: &Centers) -> Matrix {
        self.add(e - s, centers.m());
        self.inner.block_range(s, e, centers)
    }

    fn block_range_into(&self, s: usize, e: usize, centers: &Centers, out: &mut Matrix) {
        self.add(e - s, centers.m());
        self.inner.block_range_into(s, e, centers, out);
    }

    fn centers_block(&self, centers: &Centers, cols: &[usize]) -> Matrix {
        self.add(centers.m(), cols.len());
        self.inner.centers_block(centers, cols)
    }

    fn centers_square(&self, centers: &Centers) -> Matrix {
        self.add(centers.m(), centers.m());
        self.inner.centers_square(centers)
    }

    fn cross_block_range(&self, q: &Matrix, s: usize, e: usize, centers: &Centers) -> Matrix {
        self.add(e - s, centers.m());
        self.inner.cross_block_range(q, s, e, centers)
    }
}

/// Peak workspace of a subset estimator with an `m`-column dictionary:
/// the `m × m` factor, one `m × tile` cross block, and the score vector.
fn subset_peak_bytes(n: usize, m: usize) -> u64 {
    8 * (m * m + m * DEFAULT_ROW_TILE.min(n) + n) as u64
}

/// The O(n³) exact reference (Eq. 1) as a family member.
pub struct ExactEstimator;

impl LeverageEstimator for ExactEstimator {
    fn name(&self) -> String {
        "exact".to_string()
    }

    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        _rng: &mut Rng,
    ) -> Result<Estimate, LeverageError> {
        let n = engine.n();
        let scores = exact_leverage_scores(engine, lambda)?;
        // K, its regularized copy/factor, and the n×n triangular solve
        Ok(Estimate::new(scores, 8 * (3 * n * n) as u64))
    }
}

/// BLESS (Alg. 1) adapted onto the family: run the path, then score all
/// points through the final dictionary's [`LsGenerator`].
pub struct BlessEstimator {
    pub cfg: BlessConfig,
}

impl LeverageEstimator for BlessEstimator {
    fn name(&self) -> String {
        "bless".to_string()
    }

    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Estimate, LeverageError> {
        let path = bless(engine, lambda, &self.cfg, rng);
        let set = path.final_set();
        let gen = LsGenerator::new(engine, set, lambda)?;
        let scores = gen.scores_all();
        Ok(Estimate::new(scores, subset_peak_bytes(engine.n(), set.len())))
    }
}

/// The Bernoulli-keeps recursive RLS baseline ([`rrls`]) as a family
/// member (distinct from the fixed-size Musco & Musco variant,
/// [`RlsNystromEstimator`]).
pub struct RrlsEstimator {
    pub cfg: RrlsConfig,
}

impl LeverageEstimator for RrlsEstimator {
    fn name(&self) -> String {
        "rrls".to_string()
    }

    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Estimate, LeverageError> {
        let out = rrls(engine, lambda, &self.cfg, rng);
        let gen = LsGenerator::new(engine, &out.set, lambda)?;
        let scores = gen.scores_all();
        Ok(Estimate::new(scores, subset_peak_bytes(engine.n(), out.set.len())))
    }
}

/// Parse an estimator spec string into a boxed family member.
///
/// Specs (case-insensitive, optional `:<param>` suffix):
/// - `exact`
/// - `bless`
/// - `rrls`
/// - `count-sketch[:s]` (aliases `cwt`, `countsketch`; default s = 256)
/// - `srft[:s]` (default s = 256)
/// - `rls-nystrom[:m]` (aliases `recursive-nystrom`, `rlsn`;
///   default m = 256)
///
/// Returns `None` for unknown names or malformed parameters.
pub fn parse_estimator(spec: &str) -> Option<Box<dyn LeverageEstimator>> {
    let spec = spec.trim().to_ascii_lowercase();
    let (name, arg) = match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec.as_str(), None),
    };
    let parse_size = |default: usize| -> Option<usize> {
        match arg {
            None => Some(default),
            Some(a) => a.parse::<usize>().ok().filter(|&v| v > 0),
        }
    };
    match name {
        "exact" => {
            if arg.is_some() {
                return None;
            }
            Some(Box::new(ExactEstimator))
        }
        "bless" => {
            if arg.is_some() {
                return None;
            }
            Some(Box::new(BlessEstimator { cfg: BlessConfig::default() }))
        }
        "rrls" => {
            if arg.is_some() {
                return None;
            }
            Some(Box::new(RrlsEstimator { cfg: RrlsConfig::default() }))
        }
        "count-sketch" | "countsketch" | "cwt" => {
            Some(Box::new(CountSketchEstimator { s: parse_size(256)? }))
        }
        "srft" => Some(Box::new(SrftEstimator { s: parse_size(256)? })),
        "rls-nystrom" | "recursive-nystrom" | "rlsn" => Some(Box::new(RlsNystromEstimator {
            cfg: RecursiveNystromConfig { m: parse_size(256)?, ..Default::default() },
        })),
        _ => None,
    }
}

/// The default shoot-out lineup: every family member, with the sketched
/// estimators at sketch size `sketch_s` and the Nyström variants at
/// dictionary size `nystrom_m`.
pub fn default_family(sketch_s: usize, nystrom_m: usize) -> Vec<Box<dyn LeverageEstimator>> {
    vec![
        Box::new(ExactEstimator),
        Box::new(BlessEstimator { cfg: BlessConfig::default() }),
        Box::new(RrlsEstimator { cfg: RrlsConfig::default() }),
        Box::new(CountSketchEstimator { s: sketch_s }),
        Box::new(SrftEstimator { s: sketch_s }),
        Box::new(RlsNystromEstimator {
            cfg: RecursiveNystromConfig { m: nystrom_m, ..Default::default() },
        }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::NativeEngine;
    use crate::leverage::RAccStats;

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(31));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn counting_engine_meters_every_block_path() {
        let eng = engine(40);
        let c = CountingEngine::new(&eng);
        assert_eq!(c.kernel_evals(), 0);
        let rows: Vec<usize> = (0..10).collect();
        let cols: Vec<usize> = (0..7).collect();
        let b = c.block(&rows, &cols);
        assert_eq!(b.rows(), 10);
        assert_eq!(c.kernel_evals(), 70);
        let centers = c.gather_centers(&cols);
        assert_eq!(c.kernel_evals(), 70, "gather must not count");
        let _ = c.centers_square(&centers);
        assert_eq!(c.kernel_evals(), 70 + 49);
        let _ = c.block_range(0, 5, &centers);
        assert_eq!(c.kernel_evals(), 70 + 49 + 35);
        let _ = c.centers_block(&centers, &rows);
        assert_eq!(c.kernel_evals(), 70 + 49 + 35 + 70);
        // streaming defaults flow through the counted block_range
        let v = vec![1.0; cols.len()];
        let _ = c.knm_matvec(&cols, &v);
        assert_eq!(c.kernel_evals(), 70 + 49 + 35 + 70 + 40 * 7);
        // values untouched by the metering
        let direct = eng.block(&rows, &cols);
        assert!(b.max_abs_diff(&direct) == 0.0);
    }

    #[test]
    fn exact_estimator_matches_reference_and_counts_n_squared() {
        let eng = engine(35);
        let lambda = 1e-2;
        let est = ExactEstimator;
        let out = run_estimator(&est, &eng, lambda, &mut Rng::seeded(0)).unwrap();
        let reference = exact_leverage_scores(&eng, lambda).unwrap();
        assert_eq!(out.scores, reference);
        assert_eq!(out.kernel_evals, 35 * 35);
        assert!(out.peak_bytes >= 8 * 35 * 35);
    }

    #[test]
    fn adapted_samplers_stay_accurate_through_the_trait() {
        let eng = engine(300);
        let lambda = 1e-2;
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        for (est, name) in [
            (
                Box::new(BlessEstimator { cfg: BlessConfig::default() })
                    as Box<dyn LeverageEstimator>,
                "bless",
            ),
            (Box::new(RrlsEstimator { cfg: RrlsConfig::default() }), "rrls"),
        ] {
            assert_eq!(est.name(), name);
            let out = run_estimator(est.as_ref(), &eng, lambda, &mut Rng::seeded(4)).unwrap();
            let stats = RAccStats::from_scores(&out.scores, &exact);
            assert!(
                stats.mean > 0.5 && stats.mean < 2.0,
                "{name}: mean R-ACC {} out of range",
                stats.mean
            );
            assert!(out.kernel_evals > 0, "{name}: no kernel evals metered");
            assert!(out.peak_bytes > 0);
        }
    }

    #[test]
    fn spec_parsing_roundtrip() {
        for (spec, name) in [
            ("exact", "exact"),
            ("bless", "bless"),
            ("rrls", "rrls"),
            ("count-sketch:128", "count-sketch(s=128)"),
            ("CWT:64", "count-sketch(s=64)"),
            ("srft", "srft(s=256)"),
            ("srft:512", "srft(s=512)"),
            ("rls-nystrom:100", "rls-nystrom(m=100)"),
            ("rlsn", "rls-nystrom(m=256)"),
        ] {
            let est = parse_estimator(spec).unwrap_or_else(|| panic!("spec {spec} rejected"));
            assert_eq!(est.name(), name, "spec {spec}");
        }
        for bad in ["", "unknown", "srft:0", "srft:abc", "exact:3", "count-sketch:-1"] {
            assert!(parse_estimator(bad).is_none(), "spec {bad} accepted");
        }
        assert_eq!(default_family(128, 96).len(), 6);
    }
}
