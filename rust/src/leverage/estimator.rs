//! The subset-based leverage-score estimator `ℓ̃_{J,A}` of Eq. (3).
//!
//! `ℓ̃_J(i,λ) = (λn)⁻¹ (K_ii − K_{J,i}ᵀ (K_{J,J} + λnA)⁻¹ K_{J,i})`
//!
//! A built [`LsGenerator`] holds the Cholesky factor of `K_{J,J} + λnA`
//! and answers batched score queries in `O(|J|²)` per point — this is the
//! inner object every sampling algorithm (BLESS, baselines) builds once
//! per iteration and queries many times.

use crate::kernels::{tile_indices, Centers, KernelEngine, DEFAULT_ROW_TILE};
use crate::leverage::{LeverageError, WeightedSet};
use crate::linalg::{cholesky_jittered, CholeskyFactor, Matrix};

/// Leverage-score generator for a fixed `(J, A, λ)`.
///
/// The dictionary rows `X[J]` are gathered **once** at construction
/// ([`Centers`]) and shared by the factorization and every score batch —
/// BLESS/BLESS-R/RRLS query one generator many times per level, which
/// previously re-gathered (and transposed) the `|J| × d` block per call.
pub struct LsGenerator<'a> {
    engine: &'a dyn KernelEngine,
    set: WeightedSet,
    /// The dictionary rows + norms, gathered once for all score batches.
    centers: Centers,
    lambda: f64,
    /// Cholesky of `K_{J,J} + λnA`; `None` when `J = ∅` (then
    /// `ℓ̃_∅(i,λ) = K_ii/(λn)`, Def. 1 of the appendix).
    factor: Option<CholeskyFactor>,
}

impl<'a> LsGenerator<'a> {
    /// Build the generator: evaluates `K_{J,J}`, adds `λnA`, factorizes.
    ///
    /// Cost: `O(|J|² d)` kernel evaluations + `O(|J|³)` factorization.
    ///
    /// The factorization retries with escalating diagonal jitter (same
    /// policy as [`exact_leverage_scores`](crate::leverage::exact_leverage_scores))
    /// and returns [`LeverageError::FactorizationFailed`] only when that
    /// is exhausted — previously this was a hard error on any
    /// borderline-PSD `K_{J,J}` (heavy duplicate draws at tiny λ).
    pub fn new(
        engine: &'a dyn KernelEngine,
        set: &WeightedSet,
        lambda: f64,
    ) -> Result<Self, LeverageError> {
        if !(lambda > 0.0) {
            return Err(LeverageError::InvalidConfig(format!(
                "lambda must be positive, got {lambda}"
            )));
        }
        set.validate().map_err(|e| LeverageError::InvalidSet(e.to_string()))?;
        let centers = engine.gather_centers(&set.indices);
        let factor = if set.is_empty() {
            None
        } else {
            let mut kjj = engine.centers_square(&centers);
            let lam_n = lambda * engine.n() as f64;
            kjj.add_scaled_diag(lam_n, &set.weights);
            // With-replacement samplers can hand us duplicate indices,
            // which keeps K_JJ PSD but can make the factorization
            // borderline; the λnA shift keeps it SPD for A > 0. The
            // in-place factorization takes ownership — no |J|² clone.
            // The kernel product is symmetric only up to round-off;
            // mirror for the factorization's bitwise-symmetry contract.
            kjj.mirror_lower_to_upper();
            let trace: f64 = kjj.diagonal().iter().sum();
            let m = set.len();
            let (f, _jitter) =
                cholesky_jittered(kjj, trace.abs() * 1e-12 / m as f64, trace.abs().max(1.0))
                    .ok_or(LeverageError::FactorizationFailed { dim: m, lambda })?;
            Some(f)
        };
        Ok(LsGenerator { engine, set: set.clone(), centers, lambda, factor })
    }

    /// The `(J, A)` pair this generator was built from.
    pub fn set(&self) -> &WeightedSet {
        &self.set
    }

    /// Regularization level λ.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Approximate scores `ℓ̃_J(i,λ)` for a batch of in-sample indices.
    pub fn scores(&self, idx: &[usize]) -> Vec<f64> {
        let diag = self.engine.diag(idx);
        match &self.factor {
            None => {
                let lam_n = self.lambda * self.engine.n() as f64;
                diag.iter().map(|&kii| kii / lam_n).collect()
            }
            Some(f) => {
                // K_{J,idx}: |J| × |idx|, dictionary side pre-gathered
                let kju = self.engine.centers_block(&self.centers, idx);
                self.scores_from_cross(&kju, &diag, f)
            }
        }
    }

    /// Approximate scores for **every** dataset point (`0..n`), streamed
    /// in row tiles — the full-sweep shape at the top of RRLS and the
    /// end-to-end accuracy checks, without materializing one `0..n`
    /// index vector or one `|J| × n` cross block.
    pub fn scores_all(&self) -> Vec<f64> {
        let n = self.engine.n();
        let lam_n = self.lambda * n as f64;
        let mut out = Vec::with_capacity(n);
        let mut idx = Vec::with_capacity(DEFAULT_ROW_TILE.min(n));
        for (s, e) in tile_indices(n, DEFAULT_ROW_TILE) {
            idx.clear();
            idx.extend(s..e);
            let diag = self.engine.diag(&idx);
            match &self.factor {
                None => out.extend(diag.iter().map(|&kii| kii / lam_n)),
                Some(f) => {
                    // centers_block yields the |J| × (e-s) orientation the
                    // triangular solve consumes directly — no transpose
                    let kju = self.engine.centers_block(&self.centers, &idx);
                    out.extend_from_slice(&self.scores_from_cross(&kju, &diag, f));
                }
            }
        }
        out
    }

    /// Out-of-sample scores `ℓ̂_J(x,λ)` for explicit query points
    /// (Def. 1 in the appendix; used by FALKON-BLESS diagnostics).
    pub fn scores_points(&self, q: &Matrix) -> Vec<f64> {
        let diag = vec![self.engine.kappa_sq(); q.rows()];
        match &self.factor {
            None => {
                let lam_n = self.lambda * self.engine.n() as f64;
                diag.iter().map(|&kii| kii / lam_n).collect()
            }
            Some(f) => {
                let kjq =
                    self.engine.cross_block_range(q, 0, q.rows(), &self.centers).transpose();
                self.scores_from_cross(&kjq, &diag, f)
            }
        }
    }

    /// Shared tail: given `K_{J,·}` (|J| × m) and the kernel diagonal,
    /// compute `(K_ii − ‖L⁻¹ k_i‖²)/(λn)` column-wise. Both stages run
    /// on the pool over fixed column blocks of the batch: the triangular
    /// solve through [`CholeskyFactor::solve_l_matrix`] and the
    /// `‖L⁻¹ k_i‖²` contraction through
    /// [`crate::linalg::column_sq_norms`] — bit-identical at any thread
    /// count.
    fn scores_from_cross(&self, kju: &Matrix, diag: &[f64], f: &CholeskyFactor) -> Vec<f64> {
        let z = f.solve_l_matrix(kju);
        let col_sq = crate::linalg::column_sq_norms(&z);
        let lam_n = self.lambda * self.engine.n() as f64;
        // exact arithmetic guarantees positivity; clamp the float residue
        diag.iter()
            .zip(&col_sq)
            .map(|(&kii, &sq)| ((kii - sq) / lam_n).max(1e-300))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::exact_leverage_scores;
    use crate::rng::Rng;

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(21));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn full_set_identity_recovers_exact() {
        // Paper §2.2: J = [n], A = I ⇒ ℓ̃_J(i,λ) = ℓ(i,λ) exactly.
        let eng = engine(35);
        let lambda = 1e-2;
        let set = WeightedSet::uniform((0..35).collect(), lambda);
        let gen = LsGenerator::new(&eng, &set, lambda).unwrap();
        let approx = gen.scores(&(0..35).collect::<Vec<_>>());
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        for (a, e) in approx.iter().zip(&exact) {
            assert!((a - e).abs() < 1e-9, "{a} vs {e}");
        }
    }

    #[test]
    fn empty_set_gives_diag_over_lambda_n() {
        let eng = engine(20);
        let lambda = 0.05;
        let set = WeightedSet { indices: vec![], weights: vec![], lambda };
        let gen = LsGenerator::new(&eng, &set, lambda).unwrap();
        let s = gen.scores(&[0, 5, 19]);
        let expect = 1.0 / (lambda * 20.0);
        for v in s {
            assert!((v - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn subset_scores_upper_bound_exact() {
        // A smaller model (J ⊂ [n], A=I) can only *overestimate* scores:
        // K_JJ-based projection captures less energy, so the residual
        // K_ii − kᵀ(·)⁻¹k is larger than with J=[n].
        let eng = engine(40);
        let lambda = 1e-2;
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let sub = WeightedSet::uniform((0..40).step_by(2).collect(), lambda);
        let gen = LsGenerator::new(&eng, &sub, lambda).unwrap();
        let approx = gen.scores(&(0..40).collect::<Vec<_>>());
        for (i, (a, e)) in approx.iter().zip(&exact).enumerate() {
            assert!(*a >= *e - 1e-9, "point {i}: approx {a} < exact {e}");
        }
    }

    #[test]
    fn scores_all_matches_indexed_batch() {
        let eng = engine(50);
        let lambda = 1e-2;
        let set = WeightedSet::uniform(vec![1, 8, 15, 22, 29, 41], lambda);
        let gen = LsGenerator::new(&eng, &set, lambda).unwrap();
        let all_idx: Vec<usize> = (0..50).collect();
        let batched = gen.scores(&all_idx);
        let streamed = gen.scores_all();
        assert_eq!(streamed.len(), 50);
        for (a, b) in batched.iter().zip(&streamed) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        // empty dictionary: flat K_ii/(λn)
        let empty = WeightedSet { indices: vec![], weights: vec![], lambda };
        let gen = LsGenerator::new(&eng, &empty, lambda).unwrap();
        let s = gen.scores_all();
        assert!(s.iter().all(|&v| (v - 1.0 / (lambda * 50.0)).abs() < 1e-12));
    }

    #[test]
    fn out_of_sample_matches_in_sample_on_training_points() {
        let eng = engine(30);
        let lambda = 1e-2;
        let set = WeightedSet::uniform(vec![0, 3, 6, 9, 12], lambda);
        let gen = LsGenerator::new(&eng, &set, lambda).unwrap();
        let idx = vec![1usize, 7, 22];
        let in_sample = gen.scores(&idx);
        let q = Matrix::from_fn(3, eng.points().cols(), |i, j| eng.points().get(idx[i], j));
        let oos = gen.scores_points(&q);
        for (a, b) in in_sample.iter().zip(&oos) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn weights_change_scores() {
        let eng = engine(25);
        let lambda = 1e-2;
        let idx: Vec<usize> = (0..10).collect();
        let s_id = {
            let set = WeightedSet::uniform(idx.clone(), lambda);
            LsGenerator::new(&eng, &set, lambda).unwrap().scores(&[15])[0]
        };
        let s_big = {
            let set =
                WeightedSet { indices: idx.clone(), weights: vec![100.0; 10], lambda };
            LsGenerator::new(&eng, &set, lambda).unwrap().scores(&[15])[0]
        };
        // Larger A ⇒ more regularization ⇒ bigger residual ⇒ larger score
        assert!(s_big > s_id);
    }

    #[test]
    fn duplicate_indices_tolerated() {
        // with-replacement samplers produce duplicates; the generator must
        // still factor thanks to the λnA shift.
        let eng = engine(25);
        let lambda = 1e-2;
        let set = WeightedSet {
            indices: vec![2, 2, 7, 7, 7],
            weights: vec![1.0, 1.0, 0.5, 0.5, 0.5],
            lambda,
        };
        let gen = LsGenerator::new(&eng, &set, lambda).unwrap();
        let s = gen.scores(&[0, 1]);
        assert!(s.iter().all(|&v| v > 0.0 && v.is_finite()));
    }
}
