//! Fixed-size recursive-RLS Nyström (Musco & Musco 2017, Alg. 3 as
//! commonly deployed): recursive Bernoulli(1/2) halving like the
//! [`crate::baselines::rrls`] baseline, but every level draws an
//! **exactly `m`-column** multinomial sample proportional to the
//! estimated scores instead of Bernoulli keeps — the variant with a
//! user-chosen memory budget, which is what makes it comparable to the
//! sketched estimators (both are parameterized by one size knob).
//!
//! Sampling and weighting go through
//! [`crate::baselines`]' `sample_proportional`, i.e. the Eq.-3
//! convention `A = (|pool|·m/n)·diag(p)` shared with BLESS, so the
//! resulting [`WeightedSet`] plugs into [`LsGenerator`] and FALKON
//! unchanged.

use crate::baselines::{sample_proportional, SamplerOutput};
use crate::kernels::KernelEngine;
use crate::leverage::{Estimate, LeverageError, LeverageEstimator, LsGenerator, WeightedSet};
use crate::rng::Rng;

/// Parameters of fixed-size recursive-RLS Nyström.
#[derive(Clone, Debug)]
pub struct RecursiveNystromConfig {
    /// Dictionary size sampled at every level (the memory knob).
    pub m: usize,
    /// Pools of at most this size short-circuit to a uniform dictionary.
    pub base_size: usize,
    /// Oversampling constant in `p_i = min(q₂·ℓ̃(i,λ), 1)`.
    pub q2: f64,
}

impl Default for RecursiveNystromConfig {
    fn default() -> Self {
        RecursiveNystromConfig { m: 256, base_size: 128, q2: 2.0 }
    }
}

/// Run fixed-size recursive-RLS Nyström over the whole dataset;
/// the returned set has exactly `cfg.m` columns (with repeats) unless
/// the dataset already fits the base case.
pub fn recursive_nystrom(
    engine: &dyn KernelEngine,
    lambda: f64,
    cfg: &RecursiveNystromConfig,
    rng: &mut Rng,
) -> Result<SamplerOutput, LeverageError> {
    if cfg.m == 0 {
        return Err(LeverageError::InvalidConfig("rls-nystrom needs m ≥ 1".into()));
    }
    let n = engine.n();
    let pool: Vec<usize> = (0..n).collect();
    let mut evals = 0usize;
    let set = recurse(engine, &pool, lambda, cfg, rng, &mut evals)?;
    Ok(SamplerOutput { set, score_evals: evals })
}

fn recurse(
    engine: &dyn KernelEngine,
    pool: &[usize],
    lambda: f64,
    cfg: &RecursiveNystromConfig,
    rng: &mut Rng,
    evals: &mut usize,
) -> Result<WeightedSet, LeverageError> {
    if pool.len() <= cfg.base_size.max(cfg.m) {
        return Ok(WeightedSet::uniform(pool.to_vec(), lambda));
    }
    // uniform halving, same scheme as the Bernoulli-keeps baseline
    let half: Vec<usize> = pool.iter().copied().filter(|_| rng.bernoulli(0.5)).collect();
    let half = if half.is_empty() { vec![pool[0]] } else { half };
    let inner = recurse(engine, &half, lambda, cfg, rng, evals)?;

    // score the whole pool against the inner dictionary (top level
    // streams the full sweep; the pool is always an order-preserving
    // filter of 0..n, so the identity fast path is valid there)
    let gen = LsGenerator::new(engine, &inner, lambda)?;
    let scores = if pool.len() == engine.n() {
        debug_assert!(
            pool.iter().enumerate().all(|(k, &i)| k == i),
            "full-length pool must be the identity ordering"
        );
        gen.scores_all()
    } else {
        gen.scores(pool)
    };
    *evals += pool.len();

    // fixed-size multinomial sample ∝ min(q₂·ℓ̃, 1), Eq.-3 weights
    let p: Vec<f64> = scores.iter().map(|&s| (cfg.q2 * s).min(1.0)).collect();
    Ok(sample_proportional(pool, &p, cfg.m, engine.n(), lambda, rng))
}

/// [`recursive_nystrom`] adapted onto the estimator family: sample the
/// dictionary, then score all points through its [`LsGenerator`].
pub struct RlsNystromEstimator {
    pub cfg: RecursiveNystromConfig,
}

impl LeverageEstimator for RlsNystromEstimator {
    fn name(&self) -> String {
        format!("rls-nystrom(m={})", self.cfg.m)
    }

    fn estimate(
        &self,
        engine: &dyn KernelEngine,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<Estimate, LeverageError> {
        let out = recursive_nystrom(engine, lambda, &self.cfg, rng)?;
        let gen = LsGenerator::new(engine, &out.set, lambda)?;
        let scores = gen.scores_all();
        let n = engine.n();
        let m = out.set.len();
        let peak = 8 * (m * m + m * crate::kernels::DEFAULT_ROW_TILE.min(n) + n) as u64;
        Ok(Estimate::new(scores, peak))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{exact_leverage_scores, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(47));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn fixed_size_dictionary_and_accurate_generator() {
        let eng = engine(400);
        let lambda = 5e-3;
        let cfg = RecursiveNystromConfig { m: 150, ..Default::default() };
        let out = recursive_nystrom(&eng, lambda, &cfg, &mut Rng::seeded(1)).unwrap();
        out.set.validate().unwrap();
        assert_eq!(out.set.len(), 150, "fixed-size sampler must return exactly m columns");
        assert!(out.score_evals >= 400, "top level scores all n points");
        let gen = LsGenerator::new(&eng, &out.set, lambda).unwrap();
        let stats = RAccStats::from_scores(
            &gen.scores_all(),
            &exact_leverage_scores(&eng, lambda).unwrap(),
        );
        assert!(stats.mean > 0.5 && stats.mean < 2.0, "mean {}", stats.mean);
    }

    #[test]
    fn small_pool_short_circuits_uniform() {
        let eng = engine(60);
        let cfg = RecursiveNystromConfig { m: 100, ..Default::default() };
        let out = recursive_nystrom(&eng, 1e-2, &cfg, &mut Rng::seeded(2)).unwrap();
        assert_eq!(out.score_evals, 0);
        assert_eq!(out.set.len(), 60);
        assert!(out.set.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn zero_m_rejected() {
        let eng = engine(30);
        let cfg = RecursiveNystromConfig { m: 0, ..Default::default() };
        let err = recursive_nystrom(&eng, 1e-2, &cfg, &mut Rng::seeded(0)).unwrap_err();
        assert!(matches!(err, LeverageError::InvalidConfig(_)));
    }

    #[test]
    fn estimator_adapter_scores_all_points() {
        let eng = engine(350);
        let lambda = 1e-2;
        let est = RlsNystromEstimator {
            cfg: RecursiveNystromConfig { m: 120, ..Default::default() },
        };
        let scores = est.scores(&eng, lambda, &mut Rng::seeded(6)).unwrap();
        assert_eq!(scores.len(), 350);
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let stats = RAccStats::from_scores(&scores, &exact);
        assert!(stats.mean > 0.5 && stats.mean < 2.0, "mean {}", stats.mean);
    }
}
