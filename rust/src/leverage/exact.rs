//! Exact ridge leverage scores (Eq. 1) — the O(n³) reference.
//!
//! `ℓ(i,λ) = (K̂ (K̂ + λnI)⁻¹)_ii` computed via the identity
//! `K(K+λnI)⁻¹ = (λn)⁻¹ (K − K(K+λnI)⁻¹K)`, so with `L Lᵀ = K + λnI`:
//! `ℓ(i,λ) = (λn)⁻¹ (K_ii − ‖L⁻¹ k_i‖²)` — a single triangular matrix
//! solve instead of a full inverse.

use crate::kernels::KernelEngine;
use crate::leverage::LeverageError;
use crate::linalg::{cholesky_jittered, column_sq_norms};

/// Exact leverage scores for all `n` points at regularization `λ`.
///
/// Cost: `O(n³)` time, `O(n²)` memory — only feasible for moderate `n`;
/// used as the Figure-1 accuracy reference and in tests. The
/// factorization, the `n`-column triangular solve and the `‖Z e_i‖²`
/// contraction all run on the shared pool (fixed-block partitions, so
/// the scores are bit-identical at any thread count).
///
/// `K + λnI` is SPD for any PSD kernel matrix, but float round-off on
/// near-rank-deficient inputs (duplicated points, tiny λ) can push the
/// smallest pivot negative; the factorization retries with escalating
/// diagonal jitter and returns
/// [`LeverageError::FactorizationFailed`] — instead of the historical
/// panic — when even that fails (e.g. non-finite data making kernel
/// entries NaN).
pub fn exact_leverage_scores(
    engine: &dyn KernelEngine,
    lambda: f64,
) -> Result<Vec<f64>, LeverageError> {
    let n = engine.n();
    assert!(n > 0 && lambda > 0.0);
    let all: Vec<usize> = (0..n).collect();
    let k = engine.block(&all, &all);
    let lam_n = lambda * n as f64;
    let mut reg = k.clone();
    reg.add_scaled_identity(lam_n);
    // the NT kernel product is symmetric up to round-off, not bitwise —
    // mirror before the factorization's symmetry debug-assert sees it
    reg.mirror_lower_to_upper();
    let trace: f64 = reg.diagonal().iter().sum();
    let (f, _jitter) = cholesky_jittered(reg, trace.abs() * 1e-12 / n as f64, trace.abs().max(1.0))
        .ok_or(LeverageError::FactorizationFailed { dim: n, lambda })?;
    // Z = L⁻¹ K ; ℓ_i = (K_ii − ‖Z e_i‖²)/(λn) = (K_ii − Σ_r Z_ri²)/(λn)
    let z = f.solve_l_matrix(&k);
    let col_sq = column_sq_norms(&z);
    Ok((0..n).map(|i| ((k.get(i, i) - col_sq[i]) / lam_n).max(0.0)).collect())
}

/// Effective dimension `d_eff(λ) = Σ_i ℓ(i,λ)` from a score vector.
pub fn effective_dimension(scores: &[f64]) -> f64 {
    scores.iter().sum()
}

/// `d_∞(λ) = n · max_i ℓ(i,λ)` — the uniform-sampling complexity measure.
pub fn max_leverage_dimension(scores: &[f64]) -> f64 {
    scores.iter().fold(0.0f64, |a, &b| a.max(b)) * scores.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::linalg::{gemm, Matrix};
    use crate::rng::Rng;

    fn engine(n: usize, sigma: f64) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(11));
        NativeEngine::new(ds.x, Gaussian::new(sigma))
    }

    /// Direct dense oracle: diag(K (K+λnI)⁻¹) via full solve.
    fn oracle(engine: &NativeEngine, lambda: f64) -> Vec<f64> {
        use crate::kernels::KernelEngine as _;
        let n = engine.n();
        let all: Vec<usize> = (0..n).collect();
        let k = engine.block(&all, &all);
        let mut reg = k.clone();
        reg.add_scaled_identity(lambda * n as f64);
        let f = crate::linalg::cholesky(&reg).unwrap();
        // X = (K+λnI)⁻¹ K, ℓ_i = (K X)… — use symmetric form: ℓ_i = (K A⁻¹)_ii
        // = Σ_j K_ij (A⁻¹K)_ji ; compute A⁻¹K via the fused SPD solve
        // and contract.
        let ainv_k = f.solve_matrix(&k);
        let prod = gemm(&k, &ainv_k);
        // note: leverage = diag(K (K+λnI)^{-1}); K(K+λnI)^{-1} and
        // (K+λnI)^{-1}K share the diagonal by symmetry — but `prod`
        // here is K (K+λnI)⁻¹ K. Use the (λn)⁻¹(K − ·) identity instead:
        let lam_n = lambda * n as f64;
        (0..n).map(|i| (k.get(i, i) - prod.get(i, i)) / lam_n).collect()
    }

    #[test]
    fn matches_dense_oracle() {
        let eng = engine(50, 2.0);
        for &lambda in &[1e-1, 1e-2, 1e-3] {
            let fast = exact_leverage_scores(&eng, lambda).unwrap();
            let slow = oracle(&eng, lambda);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-9, "λ={lambda}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scores_in_unit_interval_and_sum_bounds() {
        let eng = engine(80, 3.0);
        let lambda = 1e-2;
        let scores = exact_leverage_scores(&eng, lambda).unwrap();
        for &s in &scores {
            assert!((0.0..=1.0 + 1e-12).contains(&s));
        }
        let deff = effective_dimension(&scores);
        let dinf = max_leverage_dimension(&scores);
        // d_eff ≤ d_∞ ≤ 1/λ (paper §2.1, using κ²=1)
        assert!(deff <= dinf + 1e-9);
        assert!(dinf <= 1.0 / lambda + 1e-9);
        assert!(deff > 0.0);
    }

    #[test]
    fn identity_kernel_limit() {
        // For well-separated points (tiny σ) the kernel matrix → I and
        // ℓ(i,λ) → 1/(1 + λn).
        let x = Matrix::from_fn(10, 2, |i, j| (i * 10 + j) as f64 * 50.0);
        let eng = NativeEngine::new(x, Gaussian::new(0.01));
        let lambda = 0.05;
        let scores = exact_leverage_scores(&eng, lambda).unwrap();
        let expect = 1.0 / (1.0 + lambda * 10.0);
        for &s in &scores {
            assert!((s - expect).abs() < 1e-9, "{s} vs {expect}");
        }
    }

    #[test]
    fn monotone_in_lambda() {
        // Lemma 3: ℓ(i,λ') ≤ ℓ(i,λ) ≤ (λ'/λ) ℓ(i,λ') for λ ≤ λ'
        let eng = engine(40, 2.0);
        let (lam, lam_p) = (1e-3, 1e-2);
        let lo = exact_leverage_scores(&eng, lam_p).unwrap();
        let hi = exact_leverage_scores(&eng, lam).unwrap();
        for (l, h) in lo.iter().zip(&hi) {
            assert!(*l <= *h + 1e-12);
            assert!(*h <= (lam_p / lam) * *l + 1e-9);
        }
    }

    #[test]
    fn deff_decreases_with_lambda() {
        let eng = engine(60, 2.0);
        let d1 = effective_dimension(&exact_leverage_scores(&eng, 1e-1).unwrap());
        let d2 = effective_dimension(&exact_leverage_scores(&eng, 1e-3).unwrap());
        assert!(d1 < d2, "d_eff must grow as λ shrinks: {d1} vs {d2}");
    }
}
