//! Ridge leverage scores: exact computation (Eq. 1), the subset-based
//! estimator `ℓ̃_J` (Eq. 3) with its weight matrix `A`, and the R-ACC
//! accuracy statistics used by the paper's Figure 1.
//!
//! [`LsGenerator`] batch scoring — the `K_{J,U}` block evaluation and the
//! `L⁻¹ K_{J,U}` triangular solve — is the inner loop of every sampler;
//! both pieces run data-parallel on the shared [`crate::util::pool`].

mod estimator;
mod exact;
mod family;
mod recursive;
mod sketch;

pub use estimator::LsGenerator;
pub use exact::{effective_dimension, exact_leverage_scores, max_leverage_dimension};
pub use family::{
    default_family, parse_estimator, run_estimator, BlessEstimator, CountingEngine, Estimate,
    ExactEstimator, LeverageEstimator, RrlsEstimator,
};
pub use recursive::{recursive_nystrom, RecursiveNystromConfig, RlsNystromEstimator};
pub use sketch::{CountSketchEstimator, SrftEstimator};

use crate::util::quantile;

/// Typed failure modes of the leverage-score tier.
///
/// Historically `exact_leverage_scores` panicked ("K + λnI must be SPD")
/// when the factorization failed — reachable from library code on
/// degenerate inputs (e.g. non-finite data rows turning kernel entries
/// into NaN, where no amount of diagonal jitter rescues the Cholesky).
/// Every estimator now surfaces that as a value instead.
#[derive(Clone, Debug, PartialEq)]
pub enum LeverageError {
    /// The (jittered) Cholesky factorization of the regularized kernel
    /// matrix exhausted its retry budget.
    FactorizationFailed {
        /// Dimension of the matrix that failed to factor.
        dim: usize,
        /// Regularization level at which it failed.
        lambda: f64,
    },
    /// A [`WeightedSet`] failed validation (length mismatch,
    /// non-positive weight, out-of-range index).
    InvalidSet(String),
    /// An estimator was built or invoked with invalid parameters.
    InvalidConfig(String),
}

impl std::fmt::Display for LeverageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LeverageError::FactorizationFailed { dim, lambda } => write!(
                f,
                "Cholesky of the {dim}×{dim} regularized kernel matrix failed \
                 (λ={lambda}): jitter retries exhausted — is the input data finite?"
            ),
            LeverageError::InvalidSet(msg) => write!(f, "invalid weighted set: {msg}"),
            LeverageError::InvalidConfig(msg) => write!(f, "invalid estimator config: {msg}"),
        }
    }
}

impl std::error::Error for LeverageError {}

/// A weighted column subset `(J, A)` — the output of every sampler in this
/// crate (BLESS, BLESS-R and all baselines) and the input to FALKON.
///
/// `weights[k]` is the diagonal entry `A_kk` of the weight matrix in
/// Eq. (3): uniform samplers use `A = I`; BLESS uses
/// `A_h = (R_h·M_h/n)·diag(p)`; BLESS-R uses `A_h = diag(p)`.
#[derive(Clone, Debug)]
pub struct WeightedSet {
    /// Selected column indices (into the dataset), possibly with repeats
    /// for with-replacement samplers.
    pub indices: Vec<usize>,
    /// Positive diagonal of the weight matrix `A` (same length).
    pub weights: Vec<f64>,
    /// Regularization level this set was built for.
    pub lambda: f64,
}

impl WeightedSet {
    /// Uniformly-weighted set (`A = I`).
    pub fn uniform(indices: Vec<usize>, lambda: f64) -> Self {
        let weights = vec![1.0; indices.len()];
        WeightedSet { indices, weights, lambda }
    }

    /// Number of selected columns.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// Whether the set is empty.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Sanity: weights strictly positive and lengths agree.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.indices.len() == self.weights.len(), "length mismatch");
        anyhow::ensure!(
            self.weights.iter().all(|&w| w > 0.0 && w.is_finite()),
            "non-positive weight"
        );
        Ok(())
    }
}

/// Relative-accuracy statistics of approximate vs exact leverage scores —
/// the quantities reported in the paper's Figure 1 (mean R-ACC and the
/// 5ᵗʰ/95ᵗʰ quantiles of `ℓ̃(i,λ)/ℓ(i,λ)`).
#[derive(Clone, Debug)]
pub struct RAccStats {
    pub mean: f64,
    pub q05: f64,
    pub q95: f64,
    pub min: f64,
    pub max: f64,
}

impl RAccStats {
    /// Compute from paired approximate/exact scores.
    pub fn from_scores(approx: &[f64], exact: &[f64]) -> Self {
        assert_eq!(approx.len(), exact.len());
        assert!(!approx.is_empty());
        let mut ratios: Vec<f64> =
            approx.iter().zip(exact).map(|(a, e)| a / e.max(1e-300)).collect();
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        RAccStats {
            mean: crate::util::mean(&ratios),
            q05: quantile(&ratios, 0.05),
            q95: quantile(&ratios, 0.95),
            min: ratios[0],
            max: *ratios.last().unwrap(),
        }
    }

    /// Whether all ratios satisfy the multiplicative bound of Eq. (2)
    /// for a given `t`.
    pub fn within_bound(&self, t: f64) -> bool {
        self.min >= 1.0 / (1.0 + t) - 1e-9 && self.max <= 1.0 + t + 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_set_validation() {
        let ok = WeightedSet { indices: vec![1, 2], weights: vec![0.5, 2.0], lambda: 0.1 };
        assert!(ok.validate().is_ok());
        let bad = WeightedSet { indices: vec![1], weights: vec![0.0], lambda: 0.1 };
        assert!(bad.validate().is_err());
        let mismatch = WeightedSet { indices: vec![1], weights: vec![1.0, 1.0], lambda: 0.1 };
        assert!(mismatch.validate().is_err());
        assert_eq!(WeightedSet::uniform(vec![3, 4, 5], 0.1).weights, vec![1.0; 3]);
    }

    #[test]
    fn racc_stats_of_identical_scores() {
        let s = vec![0.1, 0.2, 0.3];
        let st = RAccStats::from_scores(&s, &s);
        assert!((st.mean - 1.0).abs() < 1e-12);
        assert!(st.within_bound(0.01));
    }

    #[test]
    fn leverage_error_display_and_source() {
        let e = LeverageError::FactorizationFailed { dim: 40, lambda: 1e-3 };
        let msg = e.to_string();
        assert!(msg.contains("40×40") && msg.contains("0.001"), "{msg}");
        // usable through the std Error trait (and therefore anyhow `?`)
        let dynamic: Box<dyn std::error::Error> = Box::new(e);
        assert!(dynamic.to_string().contains("jitter"));
        assert!(LeverageError::InvalidConfig("s = 0".into()).to_string().contains("s = 0"));
    }

    #[test]
    fn racc_detects_violation() {
        let approx = vec![0.3, 0.1];
        let exact = vec![0.1, 0.1];
        let st = RAccStats::from_scores(&approx, &exact);
        assert!(!st.within_bound(1.0));
        assert!((st.max - 3.0).abs() < 1e-12);
    }
}
