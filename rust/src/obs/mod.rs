//! Observability tier: metrics registry, latency histograms, span
//! tracing, and the HTTP scrape endpoint.
//!
//! Four pieces, all stdlib-only:
//!
//! - [`hist`] — lock-free fixed-log-bucket histograms (252 buckets,
//!   ≤25% relative bucket width) with exact counts, associative merge,
//!   and derived p50/p95/p99;
//! - [`metrics`] — a process-wide registry of named counters, gauges,
//!   and histograms with Prometheus and JSON renderings;
//! - [`span`] — a hierarchical span timer for the training pipeline
//!   (`train --trace`), gated by one atomic flag;
//! - [`http`] — a minimal HTTP/1.1 listener serving `GET /metrics`
//!   (Prometheus text exposition), `/healthz`, and `/varz` (JSON),
//!   enabled with `serve --metrics-addr`.
//!
//! The cardinal rule of the tier: instrumentation **observes, never
//! partitions**. No timer or counter feeds back into how work is split
//! or scheduled, so enabling any of it leaves every computed bit
//! unchanged (`tests/parallel_determinism.rs` enforces this for span
//! tracing), and the serve-path cost is three relaxed atomic adds per
//! request (measured in `BENCH_obs.json`).

pub mod hist;
pub mod http;
pub mod metrics;
pub mod span;

pub use hist::{HistSnapshot, Histogram, HIST_BUCKETS};
pub use http::{serve_http, HttpHandle, MetricsProvider};
pub use metrics::{escape_label, Counter, Gauge, MetricsRegistry};
pub use span::{SpanProfile, SpanStat};

/// Open a span on the calling thread (see [`span::enter`]).
pub fn span(name: &str) -> span::Span {
    span::enter(name)
}
