//! Global metrics registry: named counters, gauges, and histograms.
//!
//! The registry is a process-wide, stdlib-only store keyed by metric
//! name. Handles ([`Counter`], [`Gauge`], [`crate::obs::Histogram`]) are
//! `Arc`s to atomics: callers look them up once (a short `RwLock` read)
//! and then update them with relaxed atomic ops, so the steady-state
//! cost is independent of the registry. Everything here *observes* —
//! no computation reads a metric back to make a decision, preserving
//! the fixed-partition determinism invariant.
//!
//! Names use Prometheus conventions (`snake_case`, `_total` suffix for
//! counters); [`MetricsRegistry::render_prometheus`] emits the text
//! exposition format and [`MetricsRegistry::varz`] a JSON mirror.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, OnceLock, RwLock};

use super::hist::{HistSnapshot, Histogram};
use crate::util::json::Json;

/// Monotone event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n` events.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    /// Add one event.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }

    /// Reset to zero (trace runs, tests).
    pub fn reset(&self) {
        self.0.store(0, Relaxed);
    }
}

/// Instantaneous signed value.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Set the current value.
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Relaxed);
    }

    /// Adjust by a signed delta.
    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Relaxed)
    }
}

/// A named collection of counters, gauges, and histograms.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

fn get_or_insert<T: Default>(map: &RwLock<BTreeMap<String, Arc<T>>>, name: &str) -> Arc<T> {
    if let Some(v) = map.read().unwrap_or_else(|e| e.into_inner()).get(name) {
        return Arc::clone(v);
    }
    let mut w = map.write().unwrap_or_else(|e| e.into_inner());
    Arc::clone(w.entry(name.to_string()).or_default())
}

impl MetricsRegistry {
    /// An empty registry (the process-wide one is [`global`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up or create a counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        get_or_insert(&self.counters, name)
    }

    /// Look up or create a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        get_or_insert(&self.gauges, name)
    }

    /// Look up or create a histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        get_or_insert(&self.histograms, name)
    }

    /// Current value of every counter.
    pub fn counter_values(&self) -> BTreeMap<String, u64> {
        let map = self.counters.read().unwrap_or_else(|e| e.into_inner());
        map.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Current value of every gauge.
    pub fn gauge_values(&self) -> BTreeMap<String, i64> {
        let map = self.gauges.read().unwrap_or_else(|e| e.into_inner());
        map.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Snapshot of every histogram.
    pub fn histogram_snapshots(&self) -> BTreeMap<String, HistSnapshot> {
        let map = self.histograms.read().unwrap_or_else(|e| e.into_inner());
        map.iter().map(|(k, v)| (k.clone(), v.snapshot())).collect()
    }

    /// Zero every counter in place (handles stay valid). Used between
    /// trace runs.
    pub fn reset_counters(&self) {
        for c in self.counters.read().unwrap_or_else(|e| e.into_inner()).values() {
            c.reset();
        }
    }

    /// Append every metric in Prometheus text exposition format, each
    /// name prefixed by `prefix` (e.g. `bless_`).
    pub fn render_prometheus(&self, prefix: &str, out: &mut String) {
        use std::fmt::Write as _;
        for (name, v) in self.counter_values() {
            let _ = writeln!(out, "# TYPE {prefix}{name} counter");
            let _ = writeln!(out, "{prefix}{name} {v}");
        }
        for (name, v) in self.gauge_values() {
            let _ = writeln!(out, "# TYPE {prefix}{name} gauge");
            let _ = writeln!(out, "{prefix}{name} {v}");
        }
        for (name, snap) in self.histogram_snapshots() {
            let _ = writeln!(out, "# TYPE {prefix}{name} histogram");
            snap.render_prometheus(&format!("{prefix}{name}"), "", out);
        }
    }

    /// JSON mirror of the registry: `{counters, gauges, histograms}`
    /// with per-histogram count/sum/mean/p50/p95/p99.
    pub fn varz(&self) -> Json {
        let counters = self
            .counter_values()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let gauges = self
            .gauge_values()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let hists = self
            .histogram_snapshots()
            .into_iter()
            .map(|(k, s)| {
                let mut o = BTreeMap::new();
                o.insert("count".to_string(), Json::Num(s.count as f64));
                o.insert("sum".to_string(), Json::Num(s.sum as f64));
                o.insert("mean".to_string(), Json::Num(s.mean()));
                o.insert("p50".to_string(), Json::Num(s.percentile(0.50)));
                o.insert("p95".to_string(), Json::Num(s.percentile(0.95)));
                o.insert("p99".to_string(), Json::Num(s.percentile(0.99)));
                (k, Json::Obj(o))
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("gauges".to_string(), Json::Obj(gauges));
        root.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(root)
    }
}

/// The process-wide registry used by training and serving
/// instrumentation.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

// The serve-path recording gate exists so `benches/obs_overhead.rs` can
// measure an honest instrumented-vs-uninstrumented latency delta on one
// process. It defaults to on and nothing in the product turns it off.
static SERVE_RECORDING: AtomicBool = AtomicBool::new(true);

/// Enable/disable serve-path histogram recording (bench-only knob).
pub fn set_serve_recording(on: bool) {
    SERVE_RECORDING.store(on, Relaxed);
}

/// Whether serve-path histogram recording is on (default: yes).
#[inline]
pub fn serve_recording() -> bool {
    SERVE_RECORDING.load(Relaxed)
}

/// Escape a string for use inside a Prometheus label value: backslash,
/// double quote, and newline.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_and_persistent() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("events_total");
        let b = reg.counter("events_total");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter("events_total").get(), 3);
        assert_eq!(reg.counter_values()["events_total"], 3);

        let g = reg.gauge("depth");
        g.set(5);
        g.add(-2);
        assert_eq!(reg.gauge_values()["depth"], 3);

        let h = reg.histogram("lat_us");
        h.record(10);
        h.record(1000);
        assert_eq!(reg.histogram_snapshots()["lat_us"].count, 2);
    }

    #[test]
    fn prometheus_rendering_covers_all_kinds() {
        let reg = MetricsRegistry::new();
        reg.counter("reqs_total").add(7);
        reg.gauge("queue_depth").set(-1);
        reg.histogram("lat_us").record(42);
        let mut out = String::new();
        reg.render_prometheus("bless_", &mut out);
        assert!(out.contains("# TYPE bless_reqs_total counter"));
        assert!(out.contains("bless_reqs_total 7"));
        assert!(out.contains("bless_queue_depth -1"));
        assert!(out.contains("# TYPE bless_lat_us histogram"));
        assert!(out.contains("bless_lat_us_count 1"));
    }

    #[test]
    fn varz_is_valid_json_with_percentiles() {
        let reg = MetricsRegistry::new();
        reg.counter("c_total").inc();
        let h = reg.histogram("lat_us");
        for v in 1..=100u64 {
            h.record(v);
        }
        let j = Json::parse(&reg.varz().to_string()).unwrap();
        assert_eq!(j.get("counters").unwrap().get("c_total").unwrap().as_f64(), Some(1.0));
        let lat = j.get("histograms").unwrap().get("lat_us").unwrap();
        let p50 = lat.get("p50").unwrap().as_f64().unwrap();
        let p99 = lat.get("p99").unwrap().as_f64().unwrap();
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
