//! Lock-free fixed-log-bucket latency histograms.
//!
//! A [`Histogram`] is a fixed array of 252 atomic bucket counters plus a
//! running sum and count: recording a value is three relaxed atomic adds,
//! with no locks, no allocation, and no floating point — cheap enough for
//! the serve hot path. Buckets follow a base-2 octave layout with 4 linear
//! sub-buckets per octave, so any recorded value lands in a bucket whose
//! width is at most 25% of its lower bound; derived percentiles inherit
//! that relative-error bound. Bucket counts themselves are *exact* (every
//! recorded value increments exactly one bucket), which makes snapshot
//! merging an element-wise integer add — exactly associative, unlike
//! sampled or compressed sketches.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Number of buckets: values 0..=3 get unit buckets, then octaves
/// \[2^e, 2^(e+1)) for e in 2..=63, each split into 4 linear sub-buckets:
/// 4 + 62 * 4 = 252. Every `u64` maps to exactly one bucket.
pub const HIST_BUCKETS: usize = 252;

/// Bucket index for a recorded value (total map from `u64` onto
/// `0..HIST_BUCKETS`, monotone in `v`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 4 {
        v as usize
    } else {
        let e = 63 - v.leading_zeros() as usize; // 2..=63
        let sub = ((v >> (e - 2)) & 3) as usize; // linear quarter within the octave
        4 + (e - 2) * 4 + sub
    }
}

/// Inclusive lower bound of bucket `b`.
#[inline]
pub fn bucket_lo(b: usize) -> u64 {
    debug_assert!(b < HIST_BUCKETS);
    if b < 4 {
        b as u64
    } else {
        let e = 2 + (b - 4) / 4;
        let sub = ((b - 4) % 4) as u64;
        (1u64 << e) + sub * (1u64 << (e - 2))
    }
}

/// Exclusive upper bound of bucket `b` (saturating at `u64::MAX` for the
/// final bucket, whose true bound 2^64 does not fit).
#[inline]
pub fn bucket_hi(b: usize) -> u64 {
    debug_assert!(b < HIST_BUCKETS);
    if b < 4 {
        b as u64 + 1
    } else {
        let e = 2 + (b - 4) / 4;
        bucket_lo(b).saturating_add(1u64 << (e - 2))
    }
}

/// Concurrent histogram: record from any thread, snapshot from any thread.
pub struct Histogram {
    buckets: Vec<AtomicU64>, // HIST_BUCKETS long
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value: three relaxed atomic adds.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
    }

    /// Exact sum of all recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// Copy the current counters out. Under concurrent recording the
    /// snapshot may lag in-flight records by a few counts; each counter
    /// is individually exact and monotone.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Relaxed)).collect(),
            count: self.count.load(Relaxed),
            sum: self.sum.load(Relaxed),
        }
    }

    /// Fold a snapshot's counts back into the live histogram — the
    /// restore half of snapshot/restore (`serve --stats-file`). Exact:
    /// `h.merge_snapshot(&s)` makes `h.snapshot()` the bucket-wise sum.
    /// Snapshots shorter than `HIST_BUCKETS` (older persisted files)
    /// merge their prefix; snapshots *longer* than the live histogram
    /// fold the surplus tail into the last live bucket, so `count`
    /// always equals the sum of buckets and percentiles stay sane
    /// (the tail is pessimistically attributed to the overflow bucket).
    pub fn merge_snapshot(&self, s: &HistSnapshot) {
        let last = self.buckets.len() - 1;
        for (i, &c) in s.buckets.iter().enumerate() {
            if c > 0 {
                self.buckets[i.min(last)].fetch_add(c, Relaxed);
            }
        }
        self.count.fetch_add(s.count, Relaxed);
        self.sum.fetch_add(s.sum, Relaxed);
    }
}

/// A plain-integer copy of a [`Histogram`]: mergeable, comparable, and
/// the basis for percentile estimates and Prometheus exposition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Exact per-bucket counts (`HIST_BUCKETS` entries).
    pub buckets: Vec<u64>,
    /// Total number of recorded values.
    pub count: u64,
    /// Exact sum of recorded values.
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: vec![0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl HistSnapshot {
    /// Record into a snapshot directly (offline aggregation, tests).
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Element-wise merge; exactly associative and commutative.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Estimated `q`-quantile (`q` in \[0,1\]), linearly interpolated
    /// within the containing bucket. Monotone in `q`; exact to within the
    /// bucket width (≤25% relative). Returns 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> f64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= target {
                let lo = bucket_lo(b) as f64;
                let hi = bucket_hi(b) as f64;
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            cum = next;
        }
        // q == 1.0 lands here only by floating-point slack: report the
        // top of the last occupied bucket
        let last = self.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
        bucket_hi(last) as f64
    }

    /// Append this histogram in Prometheus text exposition format:
    /// cumulative `_bucket{le=...}` lines for occupied buckets plus
    /// `+Inf`, then `_sum` and `_count`. `labels` is a comma-joined
    /// `k="v"` list without braces, or empty.
    pub fn render_prometheus(&self, name: &str, labels: &str, out: &mut String) {
        use std::fmt::Write as _;
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            // `le` is the inclusive upper bound of the bucket
            let le = bucket_hi(b) - 1;
            if labels.is_empty() {
                let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
            } else {
                let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le}\"}} {cum}");
            }
        }
        if labels.is_empty() {
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            let _ = writeln!(out, "{name}_sum {}", self.sum);
            let _ = writeln!(out, "{name}_count {cum}");
        } else {
            let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {cum}");
            let _ = writeln!(out, "{name}_sum{{{labels}}} {}", self.sum);
            let _ = writeln!(out, "{name}_count{{{labels}}} {cum}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn bucket_boundaries_are_a_partition_of_u64() {
        // lo is the first value of its bucket, hi-1 the last, and
        // consecutive buckets tile without gap or overlap
        for b in 0..HIST_BUCKETS {
            assert_eq!(bucket_index(bucket_lo(b)), b, "lo of bucket {b}");
            assert_eq!(bucket_index(bucket_hi(b) - 1), b, "hi-1 of bucket {b}");
            if b + 1 < HIST_BUCKETS {
                assert_eq!(bucket_hi(b), bucket_lo(b + 1), "gap after bucket {b}");
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn bucket_relative_width_is_bounded() {
        // every bucket past the unit range is at most 25% of its lower
        // bound wide — the percentile error bound
        for b in 4..HIST_BUCKETS - 1 {
            let (lo, hi) = (bucket_lo(b), bucket_hi(b));
            assert!(hi - lo <= lo / 4, "bucket {b}: [{lo},{hi}) wider than 25%");
        }
    }

    fn random_snapshot(rng: &mut Rng, n: usize) -> HistSnapshot {
        let mut s = HistSnapshot::default();
        for _ in 0..n {
            // span many octaves
            let v = rng.next_u64() >> (rng.below(60) as u32);
            s.record(v);
        }
        s
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = Rng::seeded(11);
        let a = random_snapshot(&mut rng, 500);
        let b = random_snapshot(&mut rng, 300);
        let c = random_snapshot(&mut rng, 700);

        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);

        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge must be associative");

        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab, ba, "merge must be commutative");
    }

    #[test]
    fn percentiles_are_monotone_in_q() {
        let mut rng = Rng::seeded(23);
        let s = random_snapshot(&mut rng, 2000);
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=100 {
            let p = s.percentile(i as f64 / 100.0);
            assert!(p >= prev, "p({}) = {p} < p({}) = {prev}", i, i - 1);
            prev = p;
        }
    }

    #[test]
    fn percentiles_bound_the_data_within_bucket_width() {
        let mut h = HistSnapshot::default();
        for v in 1..=1000u64 {
            h.record(v);
        }
        // p50 of 1..=1000 is ~500; bucket width at 500 is ≤ 25%
        let p50 = h.percentile(0.5);
        assert!((p50 - 500.0).abs() <= 130.0, "p50 {p50} too far from 500");
        let p99 = h.percentile(0.99);
        assert!((p99 - 990.0).abs() <= 260.0, "p99 {p99} too far from 990");
        assert!(h.percentile(0.0) <= h.percentile(1.0));
        assert!(h.percentile(1.0) >= 1000.0 * 0.75);
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1_000 + i % 997);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 40_000);
        assert_eq!(s.buckets.iter().sum::<u64>(), 40_000);
    }

    #[test]
    fn merge_snapshot_restores_exactly() {
        let mut rng = Rng::seeded(42);
        let persisted = random_snapshot(&mut rng, 800);
        let live = Histogram::new();
        for v in [3u64, 97, 100_000] {
            live.record(v);
        }
        live.merge_snapshot(&persisted);
        let mut want = persisted.clone();
        for v in [3u64, 97, 100_000] {
            want.record(v);
        }
        assert_eq!(live.snapshot(), want, "restore must be bucket-exact");
    }

    #[test]
    fn merge_snapshot_folds_surplus_buckets_into_last() {
        // a snapshot from a future format with extra buckets must not
        // drop counts: the surplus tail folds into the overflow bucket
        let live = Histogram::new();
        let mut s = HistSnapshot::default();
        s.buckets[0] = 2;
        s.buckets.extend([5u64, 7]);
        s.count = 14;
        s.sum = 1_000;
        live.merge_snapshot(&s);
        let got = live.snapshot();
        assert_eq!(got.count, 14);
        assert_eq!(got.sum, 1_000);
        assert_eq!(
            got.buckets.iter().sum::<u64>(),
            got.count,
            "restored histogram must stay internally consistent"
        );
        assert_eq!(got.buckets[0], 2);
        assert_eq!(got.buckets[HIST_BUCKETS - 1], 12);
    }

    #[test]
    fn empty_histogram_is_benign() {
        let s = Histogram::new().snapshot();
        assert!(s.is_empty());
        assert_eq!(s.percentile(0.5), 0.0);
        assert_eq!(s.mean(), 0.0);
        let mut out = String::new();
        s.render_prometheus("x", "", &mut out);
        assert!(out.contains("x_bucket{le=\"+Inf\"} 0"));
        assert!(out.contains("x_count 0"));
    }

    #[test]
    fn prometheus_lines_are_cumulative_and_labelled() {
        let mut s = HistSnapshot::default();
        for v in [1u64, 1, 5, 100, 100, 100] {
            s.record(v);
        }
        let mut out = String::new();
        s.render_prometheus("lat", "model=\"m\"", &mut out);
        assert!(out.contains("lat_bucket{model=\"m\",le=\"1\"} 2"));
        assert!(out.contains("lat_bucket{model=\"m\",le=\"+Inf\"} 6"));
        assert!(out.contains("lat_sum{model=\"m\"} 307"));
        assert!(out.contains("lat_count{model=\"m\"} 6"));
        // cumulative counts never decrease down the page
        let mut prev = 0u64;
        for line in out.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "non-cumulative bucket line: {line}");
            prev = v;
        }
    }
}
