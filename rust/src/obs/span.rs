//! Hierarchical span timer for the training pipeline.
//!
//! A span is a named, scoped timer: [`enter`] returns a guard, dropping
//! it records the elapsed wall time under the slash-joined path of all
//! spans currently open *on this thread* (`falkon.fit/cg_iter`). Paths
//! aggregate into a global profile — calls and total nanoseconds per
//! path — that [`profile`] snapshots for console or JSON output.
//!
//! Tracing is off by default and gated by a single atomic flag: a
//! disabled [`enter`] is one relaxed load and no clock read, cheap
//! enough to leave in release hot paths. Spans *observe* work, they
//! never partition it — enabling tracing must not change a single bit
//! of any computed result (enforced by `tests/parallel_determinism.rs`).
//! By convention spans are placed on the coordinating thread only, above
//! the pool-dispatch level, so worker threads never see a dangling path.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(false);

fn table() -> &'static Mutex<BTreeMap<String, SpanStat>> {
    static TABLE: OnceLock<Mutex<BTreeMap<String, SpanStat>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

thread_local! {
    static STACK: RefCell<Vec<String>> = const { RefCell::new(Vec::new()) };
}

/// Turn span recording on or off globally.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Relaxed);
}

/// Whether span recording is currently on.
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Clear all recorded spans.
pub fn reset() {
    table().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Aggregated statistics for one span path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanStat {
    /// Number of times the span was entered.
    pub calls: u64,
    /// Total wall time across all calls, in nanoseconds.
    pub nanos: u64,
}

/// RAII guard returned by [`enter`]; records on drop.
pub struct Span {
    start: Option<Instant>,
}

/// Open a span. When tracing is disabled this is one atomic load and
/// returns an inert guard; when enabled, the name is pushed onto the
/// calling thread's span stack until the guard drops.
pub fn enter(name: &str) -> Span {
    if !ENABLED.load(Relaxed) {
        return Span { start: None };
    }
    STACK.with(|s| s.borrow_mut().push(name.to_string()));
    Span { start: Some(Instant::now()) }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let nanos = start.elapsed().as_nanos() as u64;
        let path = STACK.with(|s| {
            let mut stack = s.borrow_mut();
            let path = stack.join("/");
            stack.pop();
            path
        });
        let mut table = table().lock().unwrap_or_else(|e| e.into_inner());
        let stat = table.entry(path).or_default();
        stat.calls += 1;
        stat.nanos += nanos;
    }
}

/// A sorted snapshot of every recorded span path.
#[derive(Clone, Debug, Default)]
pub struct SpanProfile {
    /// `(path, stat)` pairs in lexicographic path order, which nests
    /// children directly under their parents.
    pub entries: Vec<(String, SpanStat)>,
}

/// Snapshot the global span table.
pub fn profile() -> SpanProfile {
    let table = table().lock().unwrap_or_else(|e| e.into_inner());
    SpanProfile { entries: table.iter().map(|(k, v)| (k.clone(), *v)).collect() }
}

impl SpanProfile {
    /// True when no spans were recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Stats for an exact path, if recorded.
    pub fn get(&self, path: &str) -> Option<SpanStat> {
        self.entries.iter().find(|(p, _)| p == path).map(|(_, s)| *s)
    }

    /// Indented console rendering: one line per path, total wall
    /// milliseconds and call count, children indented under parents.
    pub fn to_console(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("span profile (total wall ms × calls)\n");
        for (path, stat) in &self.entries {
            let depth = path.matches('/').count();
            let name = path.rsplit('/').next().unwrap_or(path);
            let ms = stat.nanos as f64 / 1e6;
            let indent = "  ".repeat(depth + 1);
            let label = format!("{indent}{name}");
            let _ = writeln!(out, "{label:<40} {ms:>10.2} ms  ×{}", stat.calls);
        }
        out
    }

    /// JSON rendering: an array of `{path, calls, ms}` objects.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|(path, stat)| {
                    let mut obj = BTreeMap::new();
                    obj.insert("path".to_string(), Json::Str(path.clone()));
                    obj.insert("calls".to_string(), Json::Num(stat.calls as f64));
                    obj.insert("ms".to_string(), Json::Num(stat.nanos as f64 / 1e6));
                    Json::Obj(obj)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // span state is global; serialize the tests that toggle it
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = guard();
        set_enabled(false);
        reset();
        {
            let _a = enter("off.outer");
            let _b = enter("off.inner");
        }
        assert!(profile().is_empty());
    }

    #[test]
    fn nested_spans_build_slash_paths() {
        let _g = guard();
        set_enabled(true);
        reset();
        {
            let _a = enter("outer");
            for _ in 0..3 {
                let _b = enter("inner");
            }
        }
        set_enabled(false);
        let p = profile();
        let outer = p.get("outer").expect("outer span recorded");
        let inner = p.get("outer/inner").expect("nested path recorded");
        assert_eq!(outer.calls, 1);
        assert_eq!(inner.calls, 3);
        assert!(outer.nanos >= inner.nanos, "parent includes child time");
        assert!(p.get("inner").is_none(), "child must not appear at the root");
        reset();
        assert!(profile().is_empty());
    }

    #[test]
    fn profile_renders_console_and_json() {
        let _g = guard();
        set_enabled(true);
        reset();
        {
            let _a = enter("render");
            let _b = enter("child");
        }
        set_enabled(false);
        let p = profile();
        let console = p.to_console();
        assert!(console.contains("render"));
        assert!(console.contains("child"));
        let json = p.to_json().to_string();
        let parsed = Json::parse(&json).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr.iter().any(|e| e.get("path").unwrap().as_str() == Some("render/child")));
        reset();
    }
}
