//! Minimal HTTP/1.1 listener for `/metrics`, `/healthz`, and `/varz`.
//!
//! Scrape traffic is low-rate and read-only, so the listener is a
//! deliberately small thread-per-connection loop over the stdlib
//! `TcpListener` — no framework, no keep-alive (every response closes
//! the connection), GET only. The routes are served from a
//! [`MetricsProvider`] implementation owned by the caller (the serving
//! tier bridges its registry in `serve/server.rs`).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::json::Json;

/// Source of the three scrape documents.
pub trait MetricsProvider: Send + Sync {
    /// Body for `GET /metrics` (Prometheus text exposition format).
    fn metrics_text(&self) -> String;
    /// Body for `GET /varz` (JSON mirror of the metrics).
    fn varz(&self) -> Json;
    /// Readiness and body for `GET /healthz`; `false` yields a 503.
    fn healthz(&self) -> (bool, Json);
}

/// Handle to a running metrics listener; stops on [`HttpHandle::stop`]
/// or drop.
pub struct HttpHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpHandle {
    /// The bound address (useful with a `:0` request).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join the accept thread (idempotent).
    pub fn stop(&mut self) {
        self.shutdown.store(true, SeqCst);
        // poke the blocking accept so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind `addr` and serve `/metrics`, `/healthz`, `/varz` from
/// `provider` until the handle is stopped.
pub fn serve_http(addr: &str, provider: Arc<dyn MetricsProvider>) -> anyhow::Result<HttpHandle> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("metrics listener bind {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&shutdown);
    let accept = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if flag.load(SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let provider = Arc::clone(&provider);
            std::thread::spawn(move || {
                let _ = handle_conn(stream, provider.as_ref());
            });
        }
    });
    Ok(HttpHandle { addr: bound, shutdown, accept: Some(accept) })
}

fn handle_conn(stream: TcpStream, provider: &dyn MetricsProvider) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    // drain headers up to the blank line; the bodyless GETs we serve
    // need nothing from them
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 || line == "\r\n" || line == "\n" {
            break;
        }
    }
    let mut stream = reader.into_inner();
    let mut parts = request_line.split_whitespace();
    let (method, target) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, "405 Method Not Allowed", "text/plain", "GET only\n");
    }
    let path = target.split('?').next().unwrap_or(target);
    match path {
        "/metrics" => {
            let body = provider.metrics_text();
            respond(&mut stream, "200 OK", "text/plain; version=0.0.4; charset=utf-8", &body)
        }
        "/varz" => {
            let body = provider.varz().to_string();
            respond(&mut stream, "200 OK", "application/json", &body)
        }
        "/healthz" => {
            let (ready, body) = provider.healthz();
            let status = if ready { "200 OK" } else { "503 Service Unavailable" };
            respond(&mut stream, status, "application/json", &body.to_string())
        }
        _ => respond(&mut stream, "404 Not Found", "text/plain", "not found\n"),
    }
}

fn respond(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    struct Fixed;

    impl MetricsProvider for Fixed {
        fn metrics_text(&self) -> String {
            "# TYPE t counter\nt 1\n".to_string()
        }
        fn varz(&self) -> Json {
            Json::parse(r#"{"t": 1}"#).unwrap()
        }
        fn healthz(&self) -> (bool, Json) {
            (true, Json::parse(r#"{"ok": true}"#).unwrap())
        }
    }

    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (head, body) = buf.split_once("\r\n\r\n").unwrap();
        (head.lines().next().unwrap().to_string(), body.to_string())
    }

    #[test]
    fn routes_and_shutdown() {
        let mut h = serve_http("127.0.0.1:0", Arc::new(Fixed)).unwrap();
        let addr = h.addr();

        let (status, body) = get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body, "# TYPE t counter\nt 1\n");

        let (status, body) = get(addr, "/healthz");
        assert!(status.contains("200"), "{status}");
        assert!(body.contains("\"ok\":true"));

        let (status, body) = get(addr, "/varz");
        assert!(status.contains("200"), "{status}");
        assert_eq!(Json::parse(&body).unwrap().get("t").unwrap().as_f64(), Some(1.0));

        let (status, _) = get(addr, "/nope");
        assert!(status.contains("404"), "{status}");

        h.stop();
        h.stop(); // idempotent
    }

    #[test]
    fn non_get_is_rejected() {
        let mut h = serve_http("127.0.0.1:0", Arc::new(Fixed)).unwrap();
        let mut s = TcpStream::connect(h.addr()).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 405"), "{buf}");
        h.stop();
    }
}
