//! FALKON — approximate kernel ridge regression via Nyström centers +
//! preconditioned conjugate gradient (§3 of the paper, Defs. 2–3 of the
//! appendix).
//!
//! * [`Preconditioner`] — the generalized preconditioner of Def. 2 with
//!   the BLESS weight matrix `A` (Eq. 15); uniform centers are the
//!   special case `A = I` (Eq. 14).
//! * [`Falkon`] — the solver: CG on `Wβ = b` with
//!   `W = Bᵀ(K_nMᵀK_nM + λnK_MM)B`. `K_nM` flows through the
//!   memory-budgeted [`crate::kernels::PanelCache`]: row tiles within
//!   the `--mem-budget` are evaluated **once per fit** and streamed from
//!   memory on every CG iteration; tiles beyond it are recomputed, and
//!   budget `0` recovers the pure-streaming `O(M²)`-memory path of
//!   Eq. 16 — bit-identical either way.
//! * [`nystrom_krr`] — the direct `O(nM² + M³)` Nyström solver (Def. 4),
//!   used as the convergence oracle in tests.
//! * [`ckpt`] — the checksummed `BLESSCKPT` encoding of a mid-fit CG
//!   state. [`Falkon::fit_opts`] snapshots full CG state every `k`
//!   iterations and resumes a killed fit **bit-identically** (the state
//!   is captured between iterations, so the resumed run replays the
//!   exact float sequence of an uninterrupted one);
//!   [`Falkon::refit`] warm-starts CG from an incumbent model's `α`
//!   through [`Preconditioner::apply_b_inv`], converging in a few
//!   iterations when the data has only drifted.
//!
//! FALKON-BLESS = `Falkon::fit` with centers/weights from
//! [`crate::bless::bless`]; FALKON-UNI = the same with uniform centers.
//!
//! The hot paths — the `K_MM` block behind the preconditioner and the
//! per-tile kernel blocks + matvecs of every CG iteration — run
//! data-parallel on the shared [`crate::util::pool`] with bit-identical
//! results at any `--threads` setting.

mod cg;
pub mod ckpt;
mod precond;
mod solver;

pub use cg::{cg_solve, cg_solve_resumable, CgCallback, CgSnapshotHook, CgState, CgTrace};
pub use precond::Preconditioner;
pub use solver::{nystrom_krr, CheckpointSpec, Falkon, FalkonModel, FitOptions, IterationStat};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{auc, susy_like};
    use crate::kernels::{Gaussian, KernelEngine, NativeEngine};
    use crate::leverage::WeightedSet;
    use crate::rng::Rng;

    /// End-to-end: FALKON matches exact KRR on a small problem where all
    /// n points are centers (then Nyström-KRR *is* KRR).
    #[test]
    fn falkon_matches_exact_krr_with_all_centers() {
        let mut rng = Rng::seeded(90);
        let ds = susy_like(120, &mut rng);
        let eng = NativeEngine::new(ds.x.clone(), Gaussian::new(2.0));
        let lambda = 1e-3;
        let n = eng.n();

        // exact KRR: c = (K + λnI)⁻¹ y
        let all: Vec<usize> = (0..n).collect();
        let k = eng.block(&all, &all);
        let mut reg = k.clone();
        reg.add_scaled_identity(lambda * n as f64);
        let f = crate::linalg::cholesky(&reg).unwrap();
        let c = f.solve(&ds.y);
        let krr_pred = crate::linalg::matvec(&k, &c);

        // FALKON with all centers, enough iterations
        let set = WeightedSet::uniform(all.clone(), lambda);
        let model = Falkon::new(&eng, &set, lambda)
            .unwrap()
            .fit(&ds.y, 60, None)
            .unwrap();
        let falkon_pred = model.predict(&eng, &ds.x);

        let err = crate::data::rmse(&falkon_pred, &krr_pred);
        let scale = crate::linalg::norm2(&krr_pred) / (n as f64).sqrt();
        assert!(err < 1e-4 * scale.max(1.0), "FALKON vs KRR rmse {err}");
    }

    /// FALKON generalizes: AUC on held-out data well above chance.
    #[test]
    fn falkon_learns_susy_like() {
        let mut rng = Rng::seeded(91);
        let ds = susy_like(1_200, &mut rng);
        let (train, test) = ds.split(0.25, &mut rng);
        let eng = NativeEngine::new(train.x.clone(), Gaussian::new(4.0));
        let m = 150;
        let centers = rng.sample_without_replacement(train.n(), m);
        let set = WeightedSet::uniform(centers, 1e-4);
        let model =
            Falkon::new(&eng, &set, 1e-4).unwrap().fit(&train.y, 20, None).unwrap();
        let scores = model.predict(&eng, &test.x);
        let a = auc(&scores, &test.y);
        assert!(a > 0.75, "test AUC {a} too low");
    }
}
