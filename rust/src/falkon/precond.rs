//! The generalized FALKON preconditioner (Def. 2 / Eq. 15).
//!
//! For centers `J` (size `M`), weights `A` and regularization `λ`:
//!
//! `B Bᵀ = ((n/M)·K_MM A⁻¹ K_MM + λn·K_MM)⁻¹`
//!
//! factored without ever forming the `M × M` inverse: with
//! `A^{-1/2} K_MM A^{-1/2} = L Lᵀ` and `G = (n/M)·LᵀL + λn·I = L_G L_Gᵀ`,
//!
//! `B = A^{-1/2} L^{-ᵀ} L_G^{-ᵀ}`
//!
//! so applying `B`/`Bᵀ` costs two triangular solves + a diagonal scale.
//! Uniform centers (`A = I`) recover Eq. 14.

use crate::linalg::{cholesky_jittered, cholesky_take, syrk_tn_of_lower, CholeskyFactor, Matrix};

/// Factored FALKON preconditioner.
pub struct Preconditioner {
    /// `L`: Cholesky of `A^{-1/2} K_MM A^{-1/2}` (plus jitter if needed).
    l: CholeskyFactor,
    /// `L_G`: Cholesky of `(n/M)·LᵀL + λn·I`.
    lg: CholeskyFactor,
    /// `a_isqrt[i] = A_ii^{-1/2}`.
    a_isqrt: Vec<f64>,
    /// Jitter that had to be added to make `K_MM` factor (0 if none) —
    /// reported for diagnostics.
    pub jitter: f64,
}

impl Preconditioner {
    /// Build from the raw `K_MM` block, the weight diagonal `a`, the
    /// dataset size `n` and regularization `λ`.
    pub fn new(kmm: &Matrix, a: &[f64], n: usize, lambda: f64) -> anyhow::Result<Self> {
        let m = kmm.rows();
        anyhow::ensure!(m > 0 && kmm.cols() == m, "K_MM must be square and non-empty");
        anyhow::ensure!(a.len() == m, "weight length mismatch");
        anyhow::ensure!(a.iter().all(|&w| w > 0.0), "weights must be positive");
        anyhow::ensure!(lambda > 0.0, "lambda must be positive");

        let a_isqrt: Vec<f64> = a.iter().map(|&w| 1.0 / w.sqrt()).collect();
        // S = A^{-1/2} K_MM A^{-1/2}
        let mut s = kmm.clone();
        {
            let _span = crate::obs::span("scale");
            let sd = s.as_mut_slice();
            for i in 0..m {
                for j in 0..m {
                    sd[i * m + j] *= a_isqrt[i] * a_isqrt[j];
                }
            }
        }
        // factor with escalating jitter: K_MM from close-by (or duplicate)
        // centers can be numerically rank-deficient; the QR path of
        // Example 1.2 is replaced by a diagonal shift, standard practice.
        // `cholesky_jittered` factors in place and rebuilds `S` from its
        // intact strict upper triangle between attempts, so no M×M clone
        // is made per escalation.
        let trace: f64 = (0..m).map(|i| s.get(i, i)).sum();
        let base = (trace / m as f64) * 1e-12;
        let (l, jitter) = {
            let _span = crate::obs::span("chol_kmm");
            cholesky_jittered(s, base, trace.max(1.0))
                .ok_or_else(|| anyhow::anyhow!("K_MM hopelessly singular"))?
        };

        // G = (n/M)·LᵀL + λn·I — LᵀL through the triangular rank-k
        // update (symmetry + triangularity ⇒ ~n³/6 multiply-adds versus
        // n³/2 for the dense `gemm_tn(L, L)` it replaces).
        let mut g = {
            let _span = crate::obs::span("syrk_g");
            syrk_tn_of_lower(l.l())
        };
        g.scale(n as f64 / m as f64);
        g.add_scaled_identity(lambda * n as f64);
        let lg = {
            let _span = crate::obs::span("chol_g");
            cholesky_take(g)
                .map_err(|_| anyhow::anyhow!("preconditioner G not SPD (λ={lambda})"))?
        };

        Ok(Preconditioner { l, lg, a_isqrt, jitter })
    }

    /// Number of centers `M`.
    pub fn m(&self) -> usize {
        self.a_isqrt.len()
    }

    /// `α = B β` (β-space → center-coefficient space).
    pub fn apply_b(&self, beta: &[f64]) -> Vec<f64> {
        // B = A^{-1/2} L^{-ᵀ} L_G^{-ᵀ}
        let u = self.lg.solve_lt(beta);
        let v = self.l.solve_lt(&u);
        v.iter().zip(&self.a_isqrt).map(|(x, s)| x * s).collect()
    }

    /// `β = B⁻¹ α` — the exact inverse of [`Preconditioner::apply_b`]:
    /// `B⁻¹ = L_Gᵀ Lᵀ A^{1/2}`, two triangular *multiplies* plus a
    /// diagonal scale (`O(M²)`, no solve). This is how a warm-started
    /// refit ([`super::Falkon::refit`]) maps an incumbent model's
    /// coefficients back into the preconditioned CG space: CG then
    /// starts from the incumbent solution instead of zero.
    pub fn apply_b_inv(&self, alpha: &[f64]) -> Vec<f64> {
        // A^{1/2} α (a_isqrt holds A^{-1/2}, so divide)
        let w: Vec<f64> = alpha.iter().zip(&self.a_isqrt).map(|(x, s)| x / s).collect();
        let u = mul_lt(self.l.l(), &w);
        mul_lt(self.lg.l(), &u)
    }

    /// `z = Bᵀ v`.
    pub fn apply_bt(&self, v: &[f64]) -> Vec<f64> {
        let w: Vec<f64> = v.iter().zip(&self.a_isqrt).map(|(x, s)| x * s).collect();
        let u = self.l.solve_l(&w);
        self.lg.solve_l(&u)
    }

    /// Direct access to the triangular solves (for tests).
    pub fn solve_l(&self, b: &[f64]) -> Vec<f64> {
        self.l.solve_l(b)
    }

    /// `Lᵀ x = b` via the lower-factor back substitution — no `M × M`
    /// transpose is materialized (it used to be, on every call).
    pub fn solve_lt(&self, b: &[f64]) -> Vec<f64> {
        self.l.solve_lt(b)
    }
}

/// `y = Lᵀ x` against a stored **lower** factor: `y_i = Σ_{j≥i} L_ji x_j`.
/// Small (`M × M`) and cold — runs on the calling thread.
fn mul_lt(l: &Matrix, x: &[f64]) -> Vec<f64> {
    let m = x.len();
    (0..m).map(|i| (i..m).map(|j| l.get(j, i) * x[j]).sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, KernelEngine, NativeEngine};
    use crate::linalg::{gemm, matvec};
    use crate::rng::Rng;

    fn kmm(m: usize) -> (Matrix, usize) {
        let ds = susy_like(200, &mut Rng::seeded(100));
        let eng = NativeEngine::new(ds.x, Gaussian::new(2.0));
        let idx: Vec<usize> = (0..m).map(|i| i * 200 / m).collect();
        (eng.block(&idx, &idx), 200)
    }

    /// B Bᵀ must equal ((n/M)·K A⁻¹ K + λn·K)⁻¹ — verified densely.
    #[test]
    fn bbt_is_the_target_inverse() {
        let m = 24;
        let (k, n) = kmm(m);
        let lambda = 1e-2;
        let a: Vec<f64> = (0..m).map(|i| 0.5 + (i as f64) / m as f64).collect();
        let p = Preconditioner::new(&k, &a, n, lambda).unwrap();
        assert_eq!(p.jitter, 0.0);

        // target T = (n/M)·K A⁻¹ K + λn·K
        let a_inv = Matrix::diag(&a.iter().map(|&w| 1.0 / w).collect::<Vec<_>>());
        let mut t = gemm(&gemm(&k, &a_inv), &k);
        t.scale(n as f64 / m as f64);
        let mut lk = k.clone();
        lk.scale(lambda * n as f64);
        for i in 0..m {
            for j in 0..m {
                let v = t.get(i, j) + lk.get(i, j);
                t.set(i, j, v);
            }
        }
        // check T · (B Bᵀ e_i) = e_i  for a few basis vectors
        for i in [0usize, 7, 23] {
            let mut e = vec![0.0; m];
            e[i] = 1.0;
            let bbt_e = p.apply_b(&p.apply_bt(&e));
            let t_bbt_e = matvec(&t, &bbt_e);
            for (j, &v) in t_bbt_e.iter().enumerate() {
                let expect = if j == i { 1.0 } else { 0.0 };
                assert!((v - expect).abs() < 1e-6, "T·BBᵀe_{i}[{j}] = {v}");
            }
        }
    }

    #[test]
    fn bt_is_adjoint_of_b() {
        let m = 16;
        let (k, n) = kmm(m);
        let a = vec![1.0; m];
        let p = Preconditioner::new(&k, &a, n, 1e-3).unwrap();
        let mut rng = Rng::seeded(5);
        let x: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        // ⟨Bx, y⟩ = ⟨x, Bᵀy⟩
        let lhs = crate::linalg::dot(&p.apply_b(&x), &y);
        let rhs = crate::linalg::dot(&x, &p.apply_bt(&y));
        assert!((lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0));
    }

    #[test]
    fn b_inv_inverts_b() {
        let m = 20;
        let (k, n) = kmm(m);
        let a: Vec<f64> = (0..m).map(|i| 0.4 + (i as f64) * 0.05).collect();
        let p = Preconditioner::new(&k, &a, n, 1e-3).unwrap();
        let mut rng = Rng::seeded(17);
        let beta: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        let back = p.apply_b_inv(&p.apply_b(&beta));
        for (u, v) in back.iter().zip(&beta) {
            assert!((u - v).abs() < 1e-8 * v.abs().max(1.0), "{u} vs {v}");
        }
        // and the other composition order
        let alpha: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        let back = p.apply_b(&p.apply_b_inv(&alpha));
        for (u, v) in back.iter().zip(&alpha) {
            assert!((u - v).abs() < 1e-8 * v.abs().max(1.0), "{u} vs {v}");
        }
    }

    #[test]
    fn duplicate_centers_survive_via_jitter() {
        let (k0, n) = kmm(10);
        // duplicate the first row/col to force exact singularity
        let mut k = Matrix::zeros(11, 11);
        for i in 0..11 {
            for j in 0..11 {
                let si = if i == 10 { 0 } else { i };
                let sj = if j == 10 { 0 } else { j };
                k.set(i, j, k0.get(si, sj));
            }
        }
        let a = vec![1.0; 11];
        let p = Preconditioner::new(&k, &a, n, 1e-3).unwrap();
        assert!(p.jitter > 0.0, "must have jittered");
        let out = p.apply_b(&vec![1.0; 11]);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rejects_bad_inputs() {
        let (k, n) = kmm(5);
        assert!(Preconditioner::new(&k, &[1.0; 4], n, 1e-3).is_err());
        assert!(Preconditioner::new(&k, &[0.0; 5], n, 1e-3).is_err());
        assert!(Preconditioner::new(&k, &[1.0; 5], n, 0.0).is_err());
    }
}
