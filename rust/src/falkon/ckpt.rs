//! `BLESSCKPT` — the checksummed on-disk encoding of a mid-fit CG state
//! ([`CgState`]), written every `k` iterations through
//! [`crate::util::fsio::atomic_write`] so a `train --checkpoint` run
//! killed at CG iteration 19/20 resumes from iteration 19 instead of 0.
//!
//! Byte layout (all integers and float bit patterns little-endian):
//!
//! ```text
//! offset  size  field
//!      0     8  magic "BLESSCKP"
//!      8     4  version (u32, currently 1)
//!     12     4  reserved (u32, zero)
//!     16     8  problem fingerprint (u64, FNV-1a over the CG right-hand
//!               side's f64 bit patterns + λn — a checkpoint never
//!               resumes a *different* fit)
//!     24     8  m — state vector length (u64)
//!     32     8  iter — completed CG iterations (u64)
//!     40     8  rs_old — ‖r‖² bit pattern (f64)
//!     48    8m  x section (f64 bit patterns)
//!  48+8m    8m  r section
//! 48+16m    8m  p section
//! 48+24m     8  FNV-1a checksum over every preceding byte (u64)
//! ```
//!
//! The same failure contract as the `BLESSBIN` artifact codec
//! ([`crate::serve::codec`]): decoding validates magic, length, checksum
//! and version **in that order** and reports each as a clean typed
//! error. One difference in spirit — a damaged *checkpoint* is not fatal
//! the way a damaged *artifact* is, because the fit can always cold
//! start; [`load`] therefore degrades to `None` with a loud `stderr`
//! warning and never panics or aborts the run. The
//! [`crate::faults::FaultPoint::CkptCorrupt`] injection point mutilates
//! the bytes between disk read and decode to prove exactly that.

use super::CgState;
use crate::serve::codec::fnv1a;
use std::path::Path;

/// Magic prefix of every checkpoint file.
pub const MAGIC: [u8; 8] = *b"BLESSCKP";
/// Current encoding version.
pub const CKPT_VERSION: u32 = 1;
/// Fixed-size header: magic + version + reserved + fingerprint + m +
/// iter + rs_old.
const HEADER_LEN: usize = 8 + 4 + 4 + 8 + 8 + 8 + 8;
/// Smallest well-formed file: header + checksum trailer (m = 0).
const MIN_LEN: usize = HEADER_LEN + 8;

/// Fingerprint of the linear system a checkpoint belongs to: FNV-1a over
/// the right-hand side's f64 bit patterns plus `λn`. Two fits with the
/// same data, centers, weights and regularization produce the same `b`
/// bit-for-bit (the determinism contract), so their checkpoints are
/// interchangeable; anything else is rejected at [`load`].
pub fn problem_fingerprint(b: &[f64], lam_n: f64) -> u64 {
    let mut bytes = Vec::with_capacity(b.len() * 8 + 8);
    for v in b {
        bytes.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    bytes.extend_from_slice(&lam_n.to_bits().to_le_bytes());
    fnv1a(&bytes)
}

/// Encode a CG state to the `BLESSCKPT` byte layout.
pub fn encode(state: &CgState, fingerprint: u64) -> Vec<u8> {
    let m = state.x.len();
    debug_assert_eq!(state.r.len(), m);
    debug_assert_eq!(state.p.len(), m);
    let mut out = Vec::with_capacity(HEADER_LEN + 24 * m + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&(m as u64).to_le_bytes());
    out.extend_from_slice(&(state.iter as u64).to_le_bytes());
    out.extend_from_slice(&state.rs_old.to_bits().to_le_bytes());
    for section in [&state.x, &state.r, &state.p] {
        for v in section.iter() {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Sequential little-endian reader with checked bounds.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow::anyhow!("truncated checkpoint (at byte {})", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_section(&mut self, len: usize) -> anyhow::Result<Vec<f64>> {
        let bytes = self.take(len.checked_mul(8).ok_or_else(|| {
            anyhow::anyhow!("checkpoint section length overflow ({len} values)")
        })?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

/// Decode a `BLESSCKPT` byte string, returning the CG state and the
/// problem fingerprint it was written under. Every class of damage —
/// wrong magic, truncation at any depth, a flipped bit anywhere (caught
/// by the checksum trailer), an unknown version, internal length
/// mismatches — surfaces as a clean typed error.
pub fn decode(bytes: &[u8]) -> anyhow::Result<(CgState, u64)> {
    anyhow::ensure!(bytes.len() >= 8 && bytes[..8] == MAGIC, "bad checkpoint magic");
    anyhow::ensure!(bytes.len() >= MIN_LEN, "truncated checkpoint");
    let (payload, trailer) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(trailer.try_into().unwrap());
    let computed = fnv1a(payload);
    anyhow::ensure!(
        stored == computed,
        "checkpoint checksum mismatch (stored {stored:016x}, computed {computed:016x}) — \
         checkpoint corrupted"
    );
    let mut r = Reader { b: payload, i: 8 };
    let version = r.u32()?;
    anyhow::ensure!(version == CKPT_VERSION, "unsupported checkpoint version {version}");
    let _reserved = r.u32()?;
    let fingerprint = r.u64()?;
    let m = r.u64()? as usize;
    let iter = r.u64()? as usize;
    let rs_old = f64::from_bits(r.u64()?);
    let x = r.f64_section(m)?;
    let rr = r.f64_section(m)?;
    let p = r.f64_section(m)?;
    anyhow::ensure!(
        r.i == payload.len(),
        "checkpoint length mismatch ({} bytes, consumed {})",
        payload.len(),
        r.i
    );
    Ok((CgState { x, r: rr, p, iter, rs_old }, fingerprint))
}

/// Persist a checkpoint crash-safely (temp file + fsync + atomic
/// rename): a crash mid-save leaves the *previous* checkpoint intact,
/// never a torn file.
pub fn save(path: impl AsRef<Path>, state: &CgState, fingerprint: u64) -> anyhow::Result<()> {
    crate::util::fsio::atomic_write(path, &encode(state, fingerprint))
}

/// Load a checkpoint for the fit identified by `expected_fingerprint`.
///
/// Degrades, never fails: a missing file returns `None` silently (first
/// run), and *any* damage — truncation, bit rot, a foreign or stale fit's
/// fingerprint, an injected `ckpt.corrupt` fault — returns `None` with a
/// loud warning on stderr so the caller cold-starts. Training must never
/// panic because a checkpoint went bad; the checkpoint is an
/// optimization, not a dependency.
pub fn load(path: impl AsRef<Path>, expected_fingerprint: u64) -> Option<CgState> {
    let path = path.as_ref();
    let mut bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
        Err(e) => {
            eprintln!(
                "warning: reading checkpoint {}: {e} — falling back to cold start",
                path.display()
            );
            return None;
        }
    };
    // chaos hook: the ckpt.corrupt fault point mutilates the bytes here,
    // between read and decode, exactly like a torn disk would
    crate::faults::corrupt_checkpoint(&mut bytes);
    let (state, fingerprint) = match decode(&bytes) {
        Ok(v) => v,
        Err(e) => {
            eprintln!(
                "warning: checkpoint {} unusable: {e} — falling back to cold start",
                path.display()
            );
            return None;
        }
    };
    if fingerprint != expected_fingerprint {
        eprintln!(
            "warning: checkpoint {} belongs to a different fit \
             (fingerprint {fingerprint:016x}, expected {expected_fingerprint:016x}) — \
             falling back to cold start",
            path.display()
        );
        return None;
    }
    Some(state)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(m: usize) -> CgState {
        CgState {
            x: (0..m).map(|i| (i as f64 * 0.37).sin()).collect(),
            r: (0..m).map(|i| (i as f64 * 0.11).cos()).collect(),
            p: (0..m).map(|i| i as f64 - 2.5).collect(),
            iter: 7,
            rs_old: 1.25e-3,
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("bless-ckpt-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn round_trips_bit_exactly() {
        let s = state(9);
        let (back, fp) = decode(&encode(&s, 0xfeed)).unwrap();
        assert_eq!(fp, 0xfeed);
        assert_eq!(back, s);
        // subnormals, infinities and negative zero all survive
        let odd = CgState {
            x: vec![f64::MIN_POSITIVE / 8.0, -0.0, f64::INFINITY],
            r: vec![0.0; 3],
            p: vec![1.0; 3],
            iter: 1,
            rs_old: 0.0,
        };
        let (back, _) = decode(&encode(&odd, 1)).unwrap();
        assert_eq!(
            back.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            odd.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn damage_is_always_a_clean_error() {
        let bytes = encode(&state(6), 42);
        assert!(decode(b"BLESSBIN").unwrap_err().to_string().contains("magic"));
        for cut in [bytes.len() - 1, bytes.len() / 2, 16, 1, 0] {
            let e = decode(&bytes[..cut]).unwrap_err().to_string();
            assert!(
                e.contains("truncated") || e.contains("magic"),
                "cut {cut}: {e}"
            );
        }
        for idx in [8, 20, 50, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[idx] ^= 0x04;
            let e = decode(&bad).unwrap_err().to_string();
            assert!(e.contains("checksum"), "flip at {idx}: {e}");
        }
    }

    #[test]
    fn save_load_round_trips_and_rejects_wrong_fingerprint() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("fit.ckpt");
        let s = state(12);
        save(&path, &s, 77).unwrap();
        assert_eq!(load(&path, 77), Some(s));
        // a different fit's fingerprint → cold start, not a panic
        assert_eq!(load(&path, 78), None);
        // missing file → silent cold start
        assert_eq!(load(dir.join("nope.ckpt"), 77), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_separates_problems() {
        let b1 = vec![1.0, 2.0, 3.0];
        let mut b2 = b1.clone();
        b2[2] += 1e-15;
        assert_eq!(problem_fingerprint(&b1, 0.5), problem_fingerprint(&b1, 0.5));
        assert_ne!(problem_fingerprint(&b1, 0.5), problem_fingerprint(&b2, 0.5));
        assert_ne!(problem_fingerprint(&b1, 0.5), problem_fingerprint(&b1, 0.25));
    }
}
