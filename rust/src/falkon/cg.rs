//! Conjugate gradient on an SPD operator given as a closure, with a
//! per-iteration callback (the paper's Figures 4–5 plot AUC after every
//! FALKON iteration, so the solver must expose intermediate iterates).

/// Per-iteration trace entry.
#[derive(Clone, Debug)]
pub struct CgTrace {
    pub iter: usize,
    /// ‖r_t‖ / ‖b‖ relative residual.
    pub rel_residual: f64,
}

/// Callback invoked after each CG iteration with `(iter, current β)`.
pub type CgCallback<'a> = dyn FnMut(usize, &[f64]) + 'a;

/// Solve `W β = b` by CG, where `matvec` applies the SPD operator `W`,
/// writing `W·p` into the provided output buffer.
///
/// The buffer-passing operator shape lets the solver hold **one** scratch
/// vector for the whole run instead of allocating a fresh `W·p` every
/// iteration (together with the iterate/residual/direction vectors, all
/// CG state is allocated once up front and reused across iterations).
///
/// Runs exactly `max_iter` iterations unless the relative residual drops
/// below `tol` first. Returns `(β, trace)`.
pub fn cg_solve(
    mut matvec: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    max_iter: usize,
    tol: f64,
    mut callback: Option<&mut CgCallback<'_>>,
) -> (Vec<f64>, Vec<CgTrace>) {
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut wp = vec![0.0; n];
    let b_norm = crate::linalg::norm2(b).max(1e-300);
    let mut rs_old = crate::linalg::dot(&r, &r);
    let mut trace = Vec::with_capacity(max_iter);

    for it in 1..=max_iter {
        if rs_old.sqrt() / b_norm < tol {
            break;
        }
        matvec(&p, &mut wp);
        let p_wp = crate::linalg::dot(&p, &wp);
        if p_wp <= 0.0 || !p_wp.is_finite() {
            // operator numerically lost positive-definiteness — stop with
            // the current iterate rather than diverge
            break;
        }
        let alpha = rs_old / p_wp;
        crate::linalg::axpy(alpha, &p, &mut x);
        crate::linalg::axpy(-alpha, &wp, &mut r);
        let rs_new = crate::linalg::dot(&r, &r);
        trace.push(CgTrace { iter: it, rel_residual: rs_new.sqrt() / b_norm });
        if let Some(cb) = callback.as_deref_mut() {
            cb(it, &x);
        }
        let beta = rs_new / rs_old;
        for (pi, ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs_old = rs_new;
    }
    (x, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, matvec, matvec_into, Matrix};

    fn spd(n: usize) -> Matrix {
        let m = Matrix::from_fn(n, n, |i, j| (((i * 7 + j * 3) % 13) as f64 - 6.0) * 0.1);
        let mut a = gemm(&m, &m.transpose());
        a.add_scaled_identity(1.0);
        a
    }

    #[test]
    fn solves_spd_system() {
        let n = 40;
        let a = spd(n);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let (x, trace) = cg_solve(|v, out| matvec_into(&a, v, out), &b, 200, 1e-12, None);
        let ax = matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-7);
        }
        assert!(!trace.is_empty());
        assert!(trace.last().unwrap().rel_residual < 1e-10);
    }

    #[test]
    fn residual_monotone_ish_and_callback_fires() {
        let n = 30;
        let a = spd(n);
        let b = vec![1.0; n];
        let mut calls = 0usize;
        let mut cb = |_it: usize, x: &[f64]| {
            calls += 1;
            assert_eq!(x.len(), n);
        };
        let (_, trace) = cg_solve(|v, out| matvec_into(&a, v, out), &b, 15, 0.0, Some(&mut cb));
        assert_eq!(calls, trace.len());
        assert_eq!(trace.len(), 15);
        // residual at end lower than at start
        assert!(trace.last().unwrap().rel_residual < trace[0].rel_residual);
    }

    #[test]
    fn exact_after_n_iterations() {
        // CG converges in ≤ n steps in exact arithmetic
        let n = 12;
        let a = spd(n);
        let b: Vec<f64> = (0..n).map(|i| i as f64 - 3.0).collect();
        let (x, _) = cg_solve(|v, out| matvec_into(&a, v, out), &b, n + 2, 0.0, None);
        let ax = matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn identity_converges_in_one_step() {
        let b = vec![3.0, -1.0, 2.0];
        let (x, trace) = cg_solve(|v, out: &mut [f64]| out.copy_from_slice(v), &b, 10, 1e-14, None);
        assert_eq!(trace.len(), 1);
        for (u, v) in x.iter().zip(&b) {
            assert!((u - v).abs() < 1e-14);
        }
    }
}
