//! Conjugate gradient on an SPD operator given as a closure, with a
//! per-iteration callback (the paper's Figures 4–5 plot AUC after every
//! FALKON iteration, so the solver must expose intermediate iterates).

/// Per-iteration trace entry.
#[derive(Clone, Debug)]
pub struct CgTrace {
    pub iter: usize,
    /// ‖r_t‖ / ‖b‖ relative residual.
    pub rel_residual: f64,
}

/// Callback invoked after each CG iteration with `(iter, current β)`.
pub type CgCallback<'a> = dyn FnMut(usize, &[f64]) + 'a;

/// Complete CG iteration state, captured at the **end** of an iteration
/// (after the direction update, so `rs_old` already holds `‖r_t‖²`).
/// Feeding a captured state back through [`cg_solve_resumable`] continues
/// the run with bit-identical arithmetic: iteration `t+1` sees exactly
/// the `(x, r, p, rs_old)` it would have seen in an uninterrupted run.
/// This is the payload of the `BLESSCKPT` checkpoint format.
#[derive(Clone, Debug, PartialEq)]
pub struct CgState {
    /// Current iterate `x_t` (β in preconditioned space for FALKON).
    pub x: Vec<f64>,
    /// Current residual `r_t = b − W x_t`.
    pub r: Vec<f64>,
    /// Current search direction `p_{t+1}` (already β-updated).
    pub p: Vec<f64>,
    /// Iterations completed so far (the resume runs `iter+1..=max_iter`).
    pub iter: usize,
    /// `‖r_t‖²` — carried so resume recomputes nothing.
    pub rs_old: f64,
}

/// Callback invoked at the end of each CG iteration with the complete
/// resumable state; the hook decides whether to persist it (e.g. every
/// `k`-th iteration to a `BLESSCKPT` file).
pub type CgSnapshotHook<'a> = dyn FnMut(&CgState) + 'a;

/// Solve `W β = b` by CG, where `matvec` applies the SPD operator `W`,
/// writing `W·p` into the provided output buffer.
///
/// The buffer-passing operator shape lets the solver hold **one** scratch
/// vector for the whole run instead of allocating a fresh `W·p` every
/// iteration (together with the iterate/residual/direction vectors, all
/// CG state is allocated once up front and reused across iterations).
///
/// Runs exactly `max_iter` iterations unless the relative residual drops
/// below `tol` first. Returns `(β, trace)`.
pub fn cg_solve(
    matvec: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    max_iter: usize,
    tol: f64,
    callback: Option<&mut CgCallback<'_>>,
) -> (Vec<f64>, Vec<CgTrace>) {
    cg_solve_resumable(matvec, b, max_iter, tol, callback, None, None)
}

/// [`cg_solve`] plus crash tolerance: `resume` continues a run from a
/// previously captured [`CgState`] (a warm start is the same mechanism
/// with `iter == 0` and state derived from an incumbent solution), and
/// `snapshot` observes the complete state at the end of every iteration
/// so callers can checkpoint it.
///
/// Because the state is captured after the direction update, resuming
/// from the iteration-`j` snapshot and running to `max_iter` performs
/// the *same* float operations in the *same* order as an uninterrupted
/// run — the resumed result is bit-identical, at any thread width.
pub fn cg_solve_resumable(
    mut matvec: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    max_iter: usize,
    tol: f64,
    mut callback: Option<&mut CgCallback<'_>>,
    resume: Option<CgState>,
    mut snapshot: Option<&mut CgSnapshotHook<'_>>,
) -> (Vec<f64>, Vec<CgTrace>) {
    let n = b.len();
    let (mut x, mut r, mut p, start, mut rs_old) = match resume {
        Some(s) => {
            assert_eq!(s.x.len(), n, "resume state dimension mismatch");
            assert_eq!(s.r.len(), n, "resume state dimension mismatch");
            assert_eq!(s.p.len(), n, "resume state dimension mismatch");
            (s.x, s.r, s.p, s.iter, s.rs_old)
        }
        None => {
            let x = vec![0.0; n];
            let r = b.to_vec();
            let p = r.clone();
            let rs = crate::linalg::dot(&r, &r);
            (x, r, p, 0, rs)
        }
    };
    let mut wp = vec![0.0; n];
    let b_norm = crate::linalg::norm2(b).max(1e-300);
    let mut trace = Vec::with_capacity(max_iter.saturating_sub(start));

    for it in (start + 1)..=max_iter {
        if rs_old.sqrt() / b_norm < tol {
            break;
        }
        matvec(&p, &mut wp);
        let p_wp = crate::linalg::dot(&p, &wp);
        if p_wp <= 0.0 || !p_wp.is_finite() {
            // operator numerically lost positive-definiteness — stop with
            // the current iterate rather than diverge
            break;
        }
        let alpha = rs_old / p_wp;
        crate::linalg::axpy(alpha, &p, &mut x);
        crate::linalg::axpy(-alpha, &wp, &mut r);
        let rs_new = crate::linalg::dot(&r, &r);
        trace.push(CgTrace { iter: it, rel_residual: rs_new.sqrt() / b_norm });
        if let Some(cb) = callback.as_deref_mut() {
            cb(it, &x);
        }
        let beta = rs_new / rs_old;
        for (pi, ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs_old = rs_new;
        if let Some(snap) = snapshot.as_deref_mut() {
            snap(&CgState { x: x.clone(), r: r.clone(), p: p.clone(), iter: it, rs_old });
        }
    }
    (x, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, matvec, matvec_into, Matrix};

    fn spd(n: usize) -> Matrix {
        let m = Matrix::from_fn(n, n, |i, j| (((i * 7 + j * 3) % 13) as f64 - 6.0) * 0.1);
        let mut a = gemm(&m, &m.transpose());
        a.add_scaled_identity(1.0);
        a
    }

    #[test]
    fn solves_spd_system() {
        let n = 40;
        let a = spd(n);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let (x, trace) = cg_solve(|v, out| matvec_into(&a, v, out), &b, 200, 1e-12, None);
        let ax = matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-7);
        }
        assert!(!trace.is_empty());
        assert!(trace.last().unwrap().rel_residual < 1e-10);
    }

    #[test]
    fn residual_monotone_ish_and_callback_fires() {
        let n = 30;
        let a = spd(n);
        let b = vec![1.0; n];
        let mut calls = 0usize;
        let mut cb = |_it: usize, x: &[f64]| {
            calls += 1;
            assert_eq!(x.len(), n);
        };
        let (_, trace) = cg_solve(|v, out| matvec_into(&a, v, out), &b, 15, 0.0, Some(&mut cb));
        assert_eq!(calls, trace.len());
        assert_eq!(trace.len(), 15);
        // residual at end lower than at start
        assert!(trace.last().unwrap().rel_residual < trace[0].rel_residual);
    }

    #[test]
    fn exact_after_n_iterations() {
        // CG converges in ≤ n steps in exact arithmetic
        let n = 12;
        let a = spd(n);
        let b: Vec<f64> = (0..n).map(|i| i as f64 - 3.0).collect();
        let (x, _) = cg_solve(|v, out| matvec_into(&a, v, out), &b, n + 2, 0.0, None);
        let ax = matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn snapshot_resume_is_bit_identical() {
        let n = 24;
        let a = spd(n);
        let b: Vec<f64> = (0..n).map(|i| ((i * 5) % 7) as f64 - 3.0).collect();
        let total = 14;
        let (straight, straight_trace) =
            cg_solve(|v, out| matvec_into(&a, v, out), &b, total, 0.0, None);

        // capture the state after iteration 6, then "crash" and resume
        let cut = 6;
        let mut captured: Option<CgState> = None;
        let mut hook = |s: &CgState| {
            if s.iter == cut {
                captured = Some(s.clone());
            }
        };
        let _ = cg_solve_resumable(
            |v, out| matvec_into(&a, v, out),
            &b,
            cut,
            0.0,
            None,
            None,
            Some(&mut hook),
        );
        let state = captured.expect("snapshot hook must fire at the cut iteration");
        assert_eq!(state.iter, cut);
        let (resumed, resumed_trace) = cg_solve_resumable(
            |v, out| matvec_into(&a, v, out),
            &b,
            total,
            0.0,
            None,
            Some(state),
            None,
        );
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&straight), bits(&resumed), "resume must be bit-identical");
        assert_eq!(resumed_trace.len(), total - cut);
        assert_eq!(resumed_trace[0].iter, cut + 1);
        let tail = &straight_trace[cut..];
        for (a_, b_) in tail.iter().zip(&resumed_trace) {
            assert_eq!(a_.iter, b_.iter);
            assert_eq!(a_.rel_residual.to_bits(), b_.rel_residual.to_bits());
        }
    }

    #[test]
    fn snapshot_fires_every_iteration_with_consistent_state() {
        let n = 16;
        let a = spd(n);
        let b = vec![1.0; n];
        let mut iters_seen = Vec::new();
        let mut hook = |s: &CgState| {
            assert_eq!(s.x.len(), n);
            assert_eq!(s.r.len(), n);
            assert_eq!(s.p.len(), n);
            assert!(s.rs_old.is_finite() && s.rs_old >= 0.0);
            iters_seen.push(s.iter);
        };
        let _ = cg_solve_resumable(
            |v, out| matvec_into(&a, v, out),
            &b,
            8,
            0.0,
            None,
            None,
            Some(&mut hook),
        );
        assert_eq!(iters_seen, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn identity_converges_in_one_step() {
        let b = vec![3.0, -1.0, 2.0];
        let (x, trace) = cg_solve(|v, out: &mut [f64]| out.copy_from_slice(v), &b, 10, 1e-14, None);
        assert_eq!(trace.len(), 1);
        for (u, v) in x.iter().zip(&b) {
            assert!((u - v).abs() < 1e-14);
        }
    }
}
