//! The FALKON estimator (Def. 3) and the direct Nyström-KRR oracle
//! (Def. 4).
//!
//! The solver executes against the [`PanelCache`] layer: the `K_nM`
//! panel is planned once per fit (`--mem-budget`), the cached prefix is
//! evaluated exactly once, and the preconditioner right-hand side, every
//! CG iteration and training-set prediction stream the same bit-identical
//! tiles — so training costs ~1 kernel sweep instead of `t` of them.

use super::{cg_solve_resumable, CgSnapshotHook, CgState, Preconditioner};
use crate::kernels::{tile_indices, Centers, KernelEngine, PanelCache};
use crate::leverage::WeightedSet;
use crate::linalg::{self, Matrix};
use std::sync::Arc;

/// Mid-fit checkpointing for [`Falkon::fit_opts`]: where to write the
/// `BLESSCKPT` file, how often, and whether to resume from one.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Checkpoint file (`BLESSCKPT`, written via atomic rename).
    pub path: std::path::PathBuf,
    /// Snapshot every `every`-th CG iteration (0 is treated as 1).
    pub every: usize,
    /// Resume from an existing checkpoint at `path` if one is present
    /// and valid for this exact fit; damage or a fingerprint mismatch
    /// degrades to a cold start with a warning.
    pub resume: bool,
}

/// Options for [`Falkon::fit_opts`]. `Default` reproduces
/// [`Falkon::fit`] exactly: no tolerance stop, cold start, no
/// checkpointing.
#[derive(Debug, Default)]
pub struct FitOptions<'o> {
    /// CG stop tolerance on the relative residual (`0.0` = run all `t`
    /// iterations, the paper-faithful fixed-iteration regime).
    pub tol: f64,
    /// Warm-start CG from an incumbent model's coefficients `α`
    /// (mapped into β-space through [`Preconditioner::apply_b_inv`]).
    /// A valid resumable checkpoint takes precedence.
    pub warm_start: Option<&'o [f64]>,
    /// Mid-fit crash tolerance (see [`CheckpointSpec`]).
    pub checkpoint: Option<CheckpointSpec>,
}

/// Statistics captured after each CG iteration via the fit callback.
#[derive(Clone, Debug)]
pub struct IterationStat {
    pub iter: usize,
    pub seconds: f64,
    /// Relative residual `‖r‖/‖b‖` after this iteration (from
    /// [`super::CgTrace`]; `train --verbose` prints the table).
    pub rel_residual: f64,
    /// Optional user metric (e.g. test AUC) computed by the callback.
    pub metric: Option<f64>,
}

/// A fitted FALKON model: centers + coefficients.
///
/// The center **rows** are gathered out of the training set once at
/// construction and shared (cheaply, via [`Arc`]) by every snapshot and
/// clone — prediction never re-gathers them per call.
#[derive(Clone, Debug)]
pub struct FalkonModel {
    /// Center indices into the training set.
    pub centers: Vec<usize>,
    /// Coefficients `α` (same length).
    pub alpha: Vec<f64>,
    /// Per-iteration statistics from the fit.
    pub iterations: Vec<IterationStat>,
    /// The gathered center rows + norms (shared, gathered once).
    pub(crate) center_set: Arc<Centers>,
}

impl FalkonModel {
    /// Assemble a model from raw parts, gathering the center rows from
    /// `engine` once (the only gather this model will ever perform).
    pub fn from_parts(
        engine: &dyn KernelEngine,
        centers: Vec<usize>,
        alpha: Vec<f64>,
    ) -> FalkonModel {
        let center_set = Arc::new(engine.gather_centers(&centers));
        FalkonModel { centers, alpha, iterations: vec![], center_set }
    }

    /// Predict scores for query points: `f(x) = Σ_j α_j K(x, x̃_j)`,
    /// streamed in row tiles of the query matrix against the model's
    /// pre-gathered center rows (no per-call, per-tile center gather).
    ///
    /// `engine` supplies the kernel function and the cross-block
    /// evaluator; it must be built over the training dataset (or any
    /// dataset whose rows at `self.centers` equal the training rows) —
    /// backends without a pre-gathered-centers fast path resolve the
    /// center indices against `engine`'s own data.
    pub fn predict(&self, engine: &dyn KernelEngine, q: &Matrix) -> Vec<f64> {
        let mut out = vec![0.0; q.rows()];
        for (s, e) in tile_indices(q.rows(), crate::kernels::DEFAULT_ROW_TILE) {
            let k = engine.cross_block_range(q, s, e, &self.center_set);
            linalg::matvec_into(&k, &self.alpha, &mut out[s..e]);
        }
        out
    }

    /// The center rows (`M × d`), gathered once at model construction:
    /// with these and `α` the model predicts without the training data —
    /// the basis of the [`crate::serve`] model artifact.
    pub fn center_rows(&self) -> &Matrix {
        &self.center_set.points
    }
}

/// FALKON solver bound to an engine, a weighted center set and λ.
///
/// Holds one [`PanelCache`] for its whole lifetime: the right-hand side,
/// all CG iterations and [`Falkon::predict_train`] serve `K_nM` tiles
/// from it instead of re-evaluating the kernel.
pub struct Falkon<'a> {
    engine: &'a dyn KernelEngine,
    panel: PanelCache<'a>,
    precond: Preconditioner,
    kmm: Matrix,
    lambda: f64,
}

impl<'a> Falkon<'a> {
    /// Prepare the solver with the process-default panel budget
    /// ([`crate::kernels::default_budget_bytes`]); see
    /// [`Falkon::with_budget`].
    pub fn new(
        engine: &'a dyn KernelEngine,
        set: &WeightedSet,
        lambda: f64,
    ) -> anyhow::Result<Self> {
        Self::with_budget(engine, set, lambda, crate::kernels::default_budget_bytes())
    }

    /// Prepare the solver: dedupe centers (with-replacement samplers can
    /// repeat them — a repeated center adds nothing to the model span),
    /// build the `K_nM` panel cache within `budget_bytes` (`0` = pure
    /// streaming; results are bit-identical at any budget), evaluate
    /// `K_MM` once from the cached center gather, and factor the Def.-2
    /// preconditioner with the BLESS weights (Eq. 15). Uniform weights
    /// give FALKON-UNI (Eq. 14).
    pub fn with_budget(
        engine: &'a dyn KernelEngine,
        set: &WeightedSet,
        lambda: f64,
        budget_bytes: usize,
    ) -> anyhow::Result<Self> {
        set.validate()?;
        anyhow::ensure!(!set.is_empty(), "FALKON needs at least one center");
        // dedupe, merging duplicate weights harmonically (the Ĉ estimator
        // sums A_ii⁻¹ contributions, so 1/w_merged = Σ 1/w_dup).
        let mut seen: std::collections::BTreeMap<usize, f64> = std::collections::BTreeMap::new();
        for (&i, &w) in set.indices.iter().zip(&set.weights) {
            *seen.entry(i).or_insert(0.0) += 1.0 / w;
        }
        let centers: Vec<usize> = seen.keys().copied().collect();
        let weights: Vec<f64> = seen.values().map(|&inv| 1.0 / inv).collect();

        let _setup = crate::obs::span("falkon.setup");
        let panel = {
            let _s = crate::obs::span("panel");
            PanelCache::new(engine, &centers, budget_bytes)
        };
        let kmm = {
            let _s = crate::obs::span("kmm");
            engine.centers_square(panel.centers())
        };
        let precond = {
            let _s = crate::obs::span("precond");
            Preconditioner::new(&kmm, &weights, engine.n(), lambda)?
        };
        Ok(Falkon { engine, panel, precond, kmm, lambda })
    }

    /// Number of (deduplicated) centers.
    pub fn m(&self) -> usize {
        self.panel.m()
    }

    /// The deduplicated center indices.
    pub fn centers(&self) -> &[usize] {
        &self.panel.centers().indices
    }

    /// The panel cache backing this solver (plan + work counters).
    pub fn panel(&self) -> &PanelCache<'a> {
        &self.panel
    }

    /// Training-set predictions for a coefficient vector: `K_nM · α`
    /// served from the panel cache (no kernel re-evaluation within
    /// budget).
    pub fn predict_train(&self, alpha: &[f64]) -> Vec<f64> {
        self.panel.knm_matvec(alpha)
    }

    /// Run `t` CG iterations on `Wβ = b` (Def. 3) and return the model.
    ///
    /// `per_iter` is invoked after every iteration with the *current
    /// model* (α-space), enabling the paper's AUC-per-iteration curves;
    /// its return value is stored in [`IterationStat::metric`].
    pub fn fit(
        &self,
        y: &[f64],
        t: usize,
        per_iter: Option<&mut dyn FnMut(usize, &FalkonModel) -> Option<f64>>,
    ) -> anyhow::Result<FalkonModel> {
        self.fit_opts(y, t, per_iter, FitOptions::default())
    }

    /// Warm-started refit: seed CG from an incumbent model's `α` and stop
    /// as soon as the relative residual drops below `tol`. When the data
    /// has only drifted, the incumbent is already near the solution and
    /// CG converges in a few iterations instead of a full cold fit —
    /// the number actually run is `model.iterations.len()`.
    pub fn refit(
        &self,
        y: &[f64],
        t: usize,
        tol: f64,
        incumbent_alpha: &[f64],
    ) -> anyhow::Result<FalkonModel> {
        self.fit_opts(
            y,
            t,
            None,
            FitOptions { tol, warm_start: Some(incumbent_alpha), checkpoint: None },
        )
    }

    /// [`Falkon::fit`] with the full option set: a CG stop tolerance, a
    /// warm start from incumbent coefficients, and `BLESSCKPT`
    /// checkpointing with crash-safe resume.
    ///
    /// Resume precedence: a valid checkpoint (right fingerprint, intact
    /// checksum) beats a warm start beats a cold start. Because the
    /// checkpoint captures the complete CG state *between* iterations,
    /// a resumed run replays the exact float sequence of an
    /// uninterrupted one — the fitted model is bit-identical, at any
    /// thread width and panel budget.
    pub fn fit_opts(
        &self,
        y: &[f64],
        t: usize,
        mut per_iter: Option<&mut dyn FnMut(usize, &FalkonModel) -> Option<f64>>,
        opts: FitOptions<'_>,
    ) -> anyhow::Result<FalkonModel> {
        anyhow::ensure!(y.len() == self.engine.n(), "label length mismatch");
        anyhow::ensure!(t > 0, "need at least one iteration");
        let _fit = crate::obs::span("falkon.fit");
        let lam_n = self.lambda * self.engine.n() as f64;
        let m = self.m();

        // b = Bᵀ K_nMᵀ y — one pass over the panel
        let kty = {
            let _s = crate::obs::span("rhs");
            self.panel.knm_t_matvec(y)
        };
        let b = self.precond.apply_bt(&kty);

        // W β = Bᵀ (K_nMᵀ K_nM + λn K_MM) B β — the K_nM products stream
        // from the panel cache; `reg` is reused across iterations.
        let mut reg = vec![0.0; m];
        let mut matvec = |beta: &[f64], out: &mut [f64]| {
            let _s = crate::obs::span("cg_iter");
            let alpha = self.precond.apply_b(beta);
            self.panel.knm_t_knm_matvec_into(&alpha, out);
            linalg::matvec_into(&self.kmm, &alpha, &mut reg);
            linalg::axpy(lam_n, &reg, out);
            let z = self.precond.apply_bt(out);
            out.copy_from_slice(&z);
        };

        // the fingerprint binds a checkpoint to this exact linear system
        // (same data + centers + weights + λ ⇒ same `b` bit-for-bit)
        let fingerprint =
            opts.checkpoint.as_ref().map(|_| super::ckpt::problem_fingerprint(&b, lam_n));
        let mreg = crate::obs::metrics::global();
        let mut resume: Option<CgState> = None;
        if let (Some(spec), Some(fp)) = (&opts.checkpoint, fingerprint) {
            if spec.resume {
                resume = super::ckpt::load(&spec.path, fp);
                if let Some(state) = &resume {
                    mreg.counter("falkon_resumed_fits_total").inc();
                    println!(
                        "resuming fit from checkpoint {} (CG iteration {})",
                        spec.path.display(),
                        state.iter
                    );
                }
            }
        }
        if resume.is_none() {
            if let Some(alpha) = opts.warm_start {
                anyhow::ensure!(alpha.len() == m, "warm-start coefficient length mismatch");
                // β₀ = B⁻¹α, r₀ = b − Wβ₀: one extra operator application
                // buys CG a start at the incumbent solution
                let x = self.precond.apply_b_inv(alpha);
                let mut wx = vec![0.0; m];
                matvec(&x, &mut wx);
                let r: Vec<f64> = b.iter().zip(&wx).map(|(bv, wv)| bv - wv).collect();
                let rs_old = linalg::dot(&r, &r);
                let p = r.clone();
                mreg.counter("falkon_warm_fits_total").inc();
                resume = Some(CgState { x, r, p, iter: 0, rs_old });
            }
        }

        let mut stats: Vec<IterationStat> = Vec::with_capacity(t);
        let t0 = std::time::Instant::now();
        let mut cb = |it: usize, beta: &[f64]| {
            let secs = t0.elapsed().as_secs_f64();
            let metric = per_iter.as_deref_mut().map(|f| {
                let snapshot = FalkonModel {
                    centers: self.centers().to_vec(),
                    alpha: self.precond.apply_b(beta),
                    iterations: vec![],
                    center_set: self.panel.centers_arc(),
                };
                f(it, &snapshot)
            });
            stats.push(IterationStat {
                iter: it,
                seconds: secs,
                rel_residual: f64::NAN,
                metric: metric.flatten(),
            });
        };

        let mut snap_hook;
        let snapshot: Option<&mut CgSnapshotHook<'_>> = match (&opts.checkpoint, fingerprint) {
            (Some(spec), Some(fp)) => {
                let every = spec.every.max(1);
                let path = spec.path.clone();
                snap_hook = move |s: &CgState| {
                    if s.iter % every == 0 {
                        // a failed checkpoint write must not kill the fit
                        if let Err(e) = super::ckpt::save(&path, s, fp) {
                            eprintln!("warning: writing checkpoint {}: {e}", path.display());
                        }
                    }
                };
                Some(&mut snap_hook)
            }
            _ => None,
        };

        let (beta, trace) =
            cg_solve_resumable(&mut matvec, &b, t, opts.tol, Some(&mut cb), resume, snapshot);
        // the solver pushes its trace entry immediately before invoking
        // the callback each iteration, so the vectors align one-to-one
        for (stat, tr) in stats.iter_mut().zip(&trace) {
            stat.rel_residual = tr.rel_residual;
        }
        mreg.counter("falkon_fits_total").inc();
        mreg.counter("falkon_cg_iterations_total").add(trace.len() as u64);

        Ok(FalkonModel {
            centers: self.centers().to_vec(),
            alpha: self.precond.apply_b(&beta),
            iterations: stats,
            center_set: self.panel.centers_arc(),
        })
    }
}

/// Direct Nyström-KRR (Def. 4): `α = (K_nMᵀK_nM + λn·K_MM)⁻¹ K_nMᵀ y`.
///
/// `O(nM²)` to build the Gram block + `O(M³)` to solve — the convergence
/// oracle FALKON must approach as `t → ∞` (Thm. 6). Streams `K_nM` row
/// tiles through the cached-center range evaluator (single pass, so no
/// panel cache is needed).
pub fn nystrom_krr(
    engine: &dyn KernelEngine,
    centers: &[usize],
    lambda: f64,
    y: &[f64],
) -> anyhow::Result<FalkonModel> {
    anyhow::ensure!(!centers.is_empty(), "need centers");
    anyhow::ensure!(y.len() == engine.n(), "label length mismatch");
    let n = engine.n();
    let m = centers.len();
    let center_set = Arc::new(engine.gather_centers(centers));
    let kmm = engine.centers_square(&center_set);

    // H = K_nMᵀ K_nM accumulated over row tiles via the symmetric
    // rank-k update (half the multiply-adds of a dense `gemm_tn`, no
    // per-tile M×M temporary): lower triangles per tile, one mirror at
    // the end — the jittered factorization below relies on H being
    // exactly symmetric. rhs = K_nMᵀ y.
    let mut h = Matrix::zeros(m, m);
    let mut rhs = vec![0.0; m];
    for (s, e) in tile_indices(n, crate::kernels::DEFAULT_ROW_TILE) {
        let blk = engine.block_range(s, e, &center_set);
        linalg::MatMul::tn().accumulate().lower().run_into(&blk, &blk, &mut h);
        linalg::matvec_t_acc(&blk, &y[s..e], &mut rhs);
    }
    h.mirror_lower_to_upper();
    let lam_n = lambda * n as f64;
    for (hv, kv) in h.as_mut_slice().iter_mut().zip(kmm.as_slice()) {
        *hv += lam_n * kv;
    }
    // jittered Cholesky (K_MM may be numerically rank-deficient): factor
    // in place, rebuilding the lower triangle from the intact strict
    // upper (H is exactly symmetric) between attempts instead of cloning
    // the M×M matrix per attempt.
    let trace: f64 = h.diagonal().iter().sum();
    let (f, _jitter) = linalg::cholesky_jittered(h, trace * 1e-12 / m as f64, trace.max(1.0))
        .ok_or_else(|| anyhow::anyhow!("normal equations singular"))?;
    let alpha = f.solve(&rhs);
    Ok(FalkonModel { centers: centers.to_vec(), alpha, iterations: vec![], center_set })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::rng::Rng;

    fn setup(n: usize) -> (NativeEngine, Vec<f64>, Vec<usize>) {
        let mut rng = Rng::seeded(110);
        let ds = susy_like(n, &mut rng);
        let eng = NativeEngine::new(ds.x, Gaussian::new(3.0));
        let centers = rng.sample_without_replacement(n, (n / 6).max(5));
        (eng, ds.y, centers)
    }

    #[test]
    fn falkon_converges_to_nystrom_krr() {
        // Thm. 6 shape: after enough CG iterations FALKON ≈ direct Nyström.
        let (eng, y, centers) = setup(300);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers.clone(), lambda);
        let falkon =
            Falkon::new(&eng, &set, lambda).unwrap().fit(&y, 80, None).unwrap();
        let direct = nystrom_krr(&eng, &falkon.centers, lambda, &y).unwrap();
        // compare predictions on the training inputs
        let q = eng.points().clone();
        let pf = falkon.predict(&eng, &q);
        let pd = direct.predict(&eng, &q);
        let err = crate::data::rmse(&pf, &pd);
        let scale = linalg::norm2(&pd) / (y.len() as f64).sqrt();
        assert!(err < 1e-5 * scale.max(1.0), "rmse {err} vs scale {scale}");
    }

    #[test]
    fn duplicate_centers_deduped() {
        let (eng, y, mut centers) = setup(150);
        let m0 = centers.len();
        centers.extend_from_slice(&centers.clone()[..3]); // add dups
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let f = Falkon::new(&eng, &set, lambda).unwrap();
        assert_eq!(f.m(), m0);
        let model = f.fit(&y, 5, None).unwrap();
        assert_eq!(model.alpha.len(), m0);
    }

    #[test]
    fn per_iteration_callback_collects_metrics() {
        let (eng, y, centers) = setup(200);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let f = Falkon::new(&eng, &set, lambda).unwrap();
        let q = eng.points().clone();
        let mut aucs = Vec::new();
        let mut cb = |_it: usize, m: &FalkonModel| -> Option<f64> {
            let s = m.predict(&eng, &q);
            let a = crate::data::auc(&s, &y);
            aucs.push(a);
            Some(a)
        };
        let model = f.fit(&y, 8, Some(&mut cb)).unwrap();
        assert_eq!(model.iterations.len(), 8);
        assert_eq!(aucs.len(), 8);
        // training AUC should improve over iterations (first vs last)
        assert!(aucs.last().unwrap() >= aucs.first().unwrap());
        assert!(model.iterations.iter().all(|s| s.metric.is_some()));
        // the CG trace is zipped into the stats: finite residuals, and
        // the last one no worse than the first (CG minimizes in A-norm;
        // the 2-norm residual can wiggle, but not explode)
        assert!(model.iterations.iter().all(|s| s.rel_residual.is_finite()));
        let (first, last) = (
            model.iterations.first().unwrap().rel_residual,
            model.iterations.last().unwrap().rel_residual,
        );
        assert!(last <= first * 10.0, "residual exploded: {first} → {last}");
        // timing is monotone
        for w in model.iterations.windows(2) {
            assert!(w[1].seconds >= w[0].seconds);
        }
    }

    #[test]
    fn budgets_do_not_change_the_model() {
        // streaming (0), partial (one tile) and unbounded budgets must
        // produce bitwise-identical coefficients and predictions.
        let (eng, y, centers) = setup(260);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let fit_at = |budget: usize| {
            let f = Falkon::with_budget(&eng, &set, lambda, budget).unwrap();
            let model = f.fit(&y, 6, None).unwrap();
            let preds = model.predict(&eng, eng.points());
            (model.alpha, preds)
        };
        let (a0, p0) = fit_at(0);
        for budget in [1 << 20, usize::MAX] {
            let (a, p) = fit_at(budget);
            assert_eq!(
                a0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "α diverged at budget {budget}"
            );
            assert_eq!(
                p0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                p.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "predictions diverged at budget {budget}"
            );
        }
    }

    #[test]
    fn predict_train_matches_predict_on_training_points() {
        let (eng, y, centers) = setup(220);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let f = Falkon::new(&eng, &set, lambda).unwrap();
        let model = f.fit(&y, 6, None).unwrap();
        let via_panel = f.predict_train(&model.alpha);
        let via_cross = model.predict(&eng, eng.points());
        for (a, b) in via_panel.iter().zip(&via_cross) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let (eng, y, centers) = setup(100);
        let empty = WeightedSet::uniform(vec![], 1e-3);
        assert!(Falkon::new(&eng, &empty, 1e-3).is_err());
        let set = WeightedSet::uniform(centers, 1e-3);
        let f = Falkon::new(&eng, &set, 1e-3).unwrap();
        assert!(f.fit(&y[..50], 5, None).is_err()); // wrong label length
        assert!(f.fit(&y, 0, None).is_err()); // zero iterations
        assert!(f.refit(&y, 5, 0.0, &y[..3]).is_err()); // wrong α length
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn checkpoint_resume_is_bit_identical_to_uninterrupted_fit() {
        let (eng, y, centers) = setup(240);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let f = Falkon::new(&eng, &set, lambda).unwrap();
        let full = f.fit(&y, 10, None).unwrap();

        let dir =
            std::env::temp_dir().join(format!("bless-solver-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fit.ckpt");
        // a fit killed after 6 of 10 iterations = run exactly 6 with
        // checkpointing on (state lands on disk at iteration 6)
        let spec = |resume: bool| CheckpointSpec { path: path.clone(), every: 2, resume };
        let partial = f
            .fit_opts(
                &y,
                6,
                None,
                FitOptions { tol: 0.0, warm_start: None, checkpoint: Some(spec(false)) },
            )
            .unwrap();
        assert_eq!(partial.iterations.len(), 6);
        let resumed = f
            .fit_opts(
                &y,
                10,
                None,
                FitOptions { tol: 0.0, warm_start: None, checkpoint: Some(spec(true)) },
            )
            .unwrap();
        assert_eq!(resumed.iterations.len(), 4, "must resume at iteration 7");
        assert_eq!(resumed.iterations[0].iter, 7);
        assert_eq!(bits(&full.alpha), bits(&resumed.alpha), "resume must be bit-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn a_damaged_checkpoint_cold_starts_with_the_same_result() {
        let (eng, y, centers) = setup(180);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let f = Falkon::new(&eng, &set, lambda).unwrap();
        let full = f.fit(&y, 5, None).unwrap();

        let dir = std::env::temp_dir().join(format!("bless-solver-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fit.ckpt");
        std::fs::write(&path, b"BLESSCKP garbage that will not checksum").unwrap();
        let spec = CheckpointSpec { path: path.clone(), every: 1, resume: true };
        let model = f
            .fit_opts(
                &y,
                5,
                None,
                FitOptions { tol: 0.0, warm_start: None, checkpoint: Some(spec) },
            )
            .unwrap();
        assert_eq!(model.iterations.len(), 5, "damage must cold-start, not resume");
        assert_eq!(bits(&full.alpha), bits(&model.alpha));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_refit_converges_in_fewer_iterations() {
        let (eng, y, centers) = setup(300);
        let lambda = 1e-3;
        let set = WeightedSet::uniform(centers, lambda);
        let f = Falkon::new(&eng, &set, lambda).unwrap();
        let tol = 1e-8;
        let cold = f.fit_opts(&y, 200, None, FitOptions { tol, ..Default::default() }).unwrap();

        // drifted labels: the incumbent is already near the new solution
        let y2: Vec<f64> =
            y.iter().enumerate().map(|(i, v)| v + 0.01 * ((i as f64) * 0.1).sin()).collect();
        let cold2 = f.fit_opts(&y2, 200, None, FitOptions { tol, ..Default::default() }).unwrap();
        let warm = f.refit(&y2, 200, tol, &cold.alpha).unwrap();
        assert!(
            warm.iterations.len() < cold2.iterations.len(),
            "warm {} vs cold {} iterations",
            warm.iterations.len(),
            cold2.iterations.len()
        );
        // and the warm answer matches the cold one to the shared tolerance
        let pw = f.predict_train(&warm.alpha);
        let pc = f.predict_train(&cold2.alpha);
        let err = crate::data::rmse(&pw, &pc);
        let scale = linalg::norm2(&pc) / (y.len() as f64).sqrt();
        assert!(err < 1e-5 * scale.max(1.0), "warm vs cold rmse {err}");
    }
}
