//! Model persistence: a self-contained serialized FALKON artifact.
//!
//! The compressed model the paper motivates shipping to an inference tier
//! is tiny: the M Nyström center *rows* (gathered out of the training set
//! so inference needs no training data), the coefficient vector `α`, and
//! the kernel configuration. This module defines that artifact, its
//! versioned + checksummed JSON encoding (via [`crate::util::json`] — the
//! offline registry has no `serde`), and the [`Predictor`] that serves it.
//!
//! Two on-disk encodings share the artifact ([`crate::serve::codec`]):
//! human-readable JSON for small models, and a raw little-endian binary
//! layout for large M. [`ModelArtifact::save`] picks by extension
//! (`.bin`/`.bless` → binary), [`ModelArtifact::load`] sniffs the magic
//! bytes, so every consumer reads both transparently.
//!
//! Round-trip fidelity: the binary format stores raw `f64` bit patterns;
//! the JSON format writes Rust's shortest round-trip `Display` and
//! re-reads with `str::parse::<f64>`. Either way a save→load cycle
//! reproduces predictions *bit-exactly*.

use crate::falkon::FalkonModel;
use crate::kernels::{Gaussian, KernelEngine, NativeEngine};
use crate::linalg::Matrix;
use crate::serve::codec::{self, Format};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Magic format tag in the artifact header.
pub const FORMAT: &str = "bless-falkon-model";
/// Current artifact schema version. Bump on incompatible layout changes.
pub const VERSION: u64 = 1;

/// A self-contained fitted model: everything `f(x) = Σ_j α_j K(x, x̃_j)`
/// needs, independent of the training set and the training engine.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    /// Gaussian kernel bandwidth σ.
    pub sigma: f64,
    /// The M center rows, gathered from the training set (`M × d`).
    pub centers: Matrix,
    /// Coefficients `α` (length M).
    pub alpha: Vec<f64>,
    /// Number of training points the model was fitted on (provenance).
    pub trained_n: usize,
    /// Human-readable dataset tag (provenance; free-form).
    pub dataset: String,
}

impl ModelArtifact {
    /// Package a fitted [`FalkonModel`] with the training engine it was
    /// fitted on: gathers the center rows so the artifact stands alone.
    pub fn from_fitted(
        model: &FalkonModel,
        engine: &dyn KernelEngine,
        dataset: &str,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!model.centers.is_empty(), "model has no centers");
        anyhow::ensure!(
            model.alpha.len() == model.centers.len(),
            "alpha/centers length mismatch: {} vs {}",
            model.alpha.len(),
            model.centers.len()
        );
        let art = ModelArtifact {
            sigma: engine.kernel().sigma(),
            centers: model.center_rows().clone(),
            alpha: model.alpha.clone(),
            trained_n: engine.n(),
            dataset: dataset.to_string(),
        };
        art.validate()?;
        Ok(art)
    }

    /// Number of centers M.
    pub fn m(&self) -> usize {
        self.centers.rows()
    }

    /// Feature dimension d.
    pub fn d(&self) -> usize {
        self.centers.cols()
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.m() > 0, "artifact has no centers");
        anyhow::ensure!(self.d() > 0, "artifact has zero feature dimension");
        anyhow::ensure!(
            self.alpha.len() == self.m(),
            "alpha length {} != center count {}",
            self.alpha.len(),
            self.m()
        );
        anyhow::ensure!(self.sigma > 0.0, "non-positive bandwidth {}", self.sigma);
        anyhow::ensure!(
            self.alpha.iter().all(|v| v.is_finite()) && self.centers.is_finite(),
            "artifact contains non-finite values"
        );
        Ok(())
    }

    /// Encode as a JSON document including the versioned header and a
    /// checksum over the payload.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("format".to_string(), Json::Str(FORMAT.to_string()));
        obj.insert("version".to_string(), Json::Num(VERSION as f64));
        obj.insert("sigma".to_string(), Json::Num(self.sigma));
        obj.insert("m".to_string(), Json::Num(self.m() as f64));
        obj.insert("d".to_string(), Json::Num(self.d() as f64));
        obj.insert("trained_n".to_string(), Json::Num(self.trained_n as f64));
        obj.insert("dataset".to_string(), Json::Str(self.dataset.clone()));
        obj.insert(
            "alpha".to_string(),
            Json::Arr(self.alpha.iter().map(|&v| Json::Num(v)).collect()),
        );
        let rows: Vec<Json> = (0..self.m())
            .map(|i| Json::Arr(self.centers.row(i).iter().map(|&v| Json::Num(v)).collect()))
            .collect();
        obj.insert("centers".to_string(), Json::Arr(rows));
        let sum = payload_checksum(&Json::Obj(obj.clone()));
        obj.insert("checksum".to_string(), Json::Str(sum));
        Json::Obj(obj)
    }

    /// Decode and fully validate a JSON document: format tag, schema
    /// version, checksum, shape and finiteness — every failure is a clean
    /// `Err`, never a panic.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("artifact is not a JSON object"))?;
        let format = j
            .get("format")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing format tag"))?;
        anyhow::ensure!(format == FORMAT, "not a {FORMAT} file (format tag {format:?})");
        let version = j
            .get("version")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing version field"))? as u64;
        anyhow::ensure!(
            version == VERSION,
            "unsupported artifact version {version} (this build reads version {VERSION})"
        );

        // checksum covers everything except the checksum field itself
        let stored = j
            .get("checksum")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing checksum field"))?;
        let mut payload = obj.clone();
        payload.remove("checksum");
        let computed = payload_checksum(&Json::Obj(payload));
        anyhow::ensure!(
            stored == computed,
            "checksum mismatch (stored {stored}, computed {computed}) — artifact corrupted"
        );

        let sigma = j
            .get("sigma")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing sigma"))?;
        let m = j
            .get("m")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing m"))?;
        let d = j
            .get("d")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing d"))?;
        let trained_n = j.get("trained_n").and_then(|v| v.as_usize()).unwrap_or(0);
        let dataset =
            j.get("dataset").and_then(|v| v.as_str()).unwrap_or("unknown").to_string();

        let alpha_j = j
            .get("alpha")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing alpha array"))?;
        anyhow::ensure!(alpha_j.len() == m, "alpha length {} != m {m}", alpha_j.len());
        let mut alpha = Vec::with_capacity(m);
        for v in alpha_j {
            alpha.push(v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric alpha entry"))?);
        }

        let rows_j = j
            .get("centers")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing centers array"))?;
        anyhow::ensure!(rows_j.len() == m, "centers row count {} != m {m}", rows_j.len());
        // capacity is a hint only — don't trust the header's m×d before
        // the per-row length checks below have run
        let mut data = Vec::with_capacity(m.saturating_mul(d).min(1 << 24));
        for (i, row) in rows_j.iter().enumerate() {
            let row = row
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("center row {i} is not an array"))?;
            anyhow::ensure!(row.len() == d, "center row {i} has {} cols, want {d}", row.len());
            for v in row {
                data.push(
                    v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric center entry"))?,
                );
            }
        }

        let art = ModelArtifact {
            sigma,
            centers: Matrix::from_vec(m, d, data),
            alpha,
            trained_n,
            dataset,
        };
        art.validate()?;
        Ok(art)
    }

    /// Save to disk, choosing the encoding by extension: `.bin` /
    /// `.bless` write the binary layout, anything else writes JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let format = Format::from_path(path.as_ref());
        self.save_as(path, format)
    }

    /// Save to disk in an explicit encoding (the `repro convert` path).
    /// The write is crash-safe ([`crate::util::fsio::atomic_write`]):
    /// staged in a same-directory temp file, fsynced, then renamed into
    /// place — a crash mid-save can never leave a torn artifact under
    /// the final name, only the complete old file or the complete new
    /// one.
    pub fn save_as(&self, path: impl AsRef<Path>, format: Format) -> anyhow::Result<()> {
        self.validate()?;
        let path = path.as_ref();
        let bytes = match format {
            Format::Json => self.to_json().to_string().into_bytes(),
            Format::Binary => codec::encode(self),
        };
        crate::util::fsio::atomic_write(path, &bytes)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Load and validate an artifact from disk, auto-detecting the
    /// encoding from the leading bytes. Truncated or corrupted files
    /// and version mismatches all return errors.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let mut bytes =
            std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        // fault-injection point: a chaos plan may mutilate the bytes
        // between read and decode; the decoders below must answer with a
        // clean typed error either way
        crate::faults::corrupt_artifact(&mut bytes);
        match Format::detect(&bytes) {
            Format::Binary => {
                let art = codec::decode(&bytes)
                    .map_err(|e| anyhow::anyhow!("decoding {}: {e}", path.display()))?;
                // the codec deliberately skips the finiteness policy (it
                // must roundtrip NaN payloads); loads enforce it
                art.validate()?;
                Ok(art)
            }
            Format::Json => {
                let text = String::from_utf8(bytes)
                    .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
                let j = Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
                Self::from_json(&j) // from_json validates
            }
        }
    }
}

/// FNV-1a 64-bit over the canonical payload serialization (`BTreeMap`
/// field order is deterministic), rendered as 16 hex digits.
fn payload_checksum(payload: &Json) -> String {
    format!("{:016x}", codec::fnv1a(payload.to_string().as_bytes()))
}

/// The inference-side engine: a loaded artifact bound to a
/// [`NativeEngine`] built over the *center rows* (not the training set).
/// The centers are rows `0..M` of that engine, so the artifact is
/// exactly a [`FalkonModel`] again and prediction goes through
/// [`FalkonModel::predict`] — one implementation of the tiled
/// `K(Q, centers) · α` arithmetic, bit-identical on both sides.
pub struct Predictor {
    engine: NativeEngine,
    model: FalkonModel,
}

impl Predictor {
    /// Build from a (loaded or freshly packaged) artifact.
    pub fn new(artifact: &ModelArtifact) -> Predictor {
        let engine = NativeEngine::new(artifact.centers.clone(), Gaussian::new(artifact.sigma));
        // `from_parts` gathers the center rows once; every batch predict
        // afterwards reuses that gather instead of re-copying M×d rows.
        // The engine's dataset here *is* the center matrix, so the model
        // holds a second M×d copy — accepted: it is small (a few hundred
        // KiB at M=2000, d=18) and keeps predict engine-agnostic.
        let model =
            FalkonModel::from_parts(&engine, (0..artifact.m()).collect(), artifact.alpha.clone());
        Predictor { engine, model }
    }

    /// Feature dimension queries must have.
    pub fn dim(&self) -> usize {
        self.engine.points().cols()
    }

    /// Number of centers M.
    pub fn m(&self) -> usize {
        self.model.centers.len()
    }

    /// Predict scores for a batch of query rows (the training-side
    /// [`FalkonModel::predict`] path, over the center-rows engine).
    pub fn predict_batch(&self, q: &Matrix) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(
            q.cols() == self.dim(),
            "query dimension {} != model dimension {}",
            q.cols(),
            self.dim()
        );
        Ok(self.model.predict(&self.engine, q))
    }

    /// Predict a single query point.
    pub fn predict_one(&self, x: &[f64]) -> anyhow::Result<f64> {
        anyhow::ensure!(
            x.len() == self.dim(),
            "query dimension {} != model dimension {}",
            x.len(),
            self.dim()
        );
        let q = Matrix::from_vec(1, x.len(), x.to_vec());
        Ok(self.predict_batch(&q)?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::falkon::nystrom_krr;
    use crate::rng::Rng;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bless-model-{}-{tag}.json", std::process::id()))
    }

    fn tmp_path_ext(tag: &str, ext: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bless-model-{}-{tag}.{ext}", std::process::id()))
    }

    fn fitted() -> (NativeEngine, FalkonModel, Matrix) {
        let mut rng = Rng::seeded(21);
        let ds = susy_like(300, &mut rng);
        let queries = Matrix::from_fn(40, ds.d(), |i, j| ds.x.get(200 + i, j));
        let eng = NativeEngine::new(ds.x.clone(), Gaussian::new(3.0));
        let centers = rng.sample_without_replacement(300, 40);
        let model = nystrom_krr(&eng, &centers, 1e-3, &ds.y).unwrap();
        (eng, model, queries)
    }

    #[test]
    fn save_load_round_trip_is_bit_exact() {
        let (eng, model, q) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let path = tmp_path("roundtrip");
        art.save(&path).unwrap();
        let loaded = ModelArtifact::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.m(), art.m());
        assert_eq!(loaded.d(), art.d());
        assert_eq!(loaded.trained_n, 300);
        assert_eq!(loaded.dataset, "susy-like");
        // every stored f64 survives the text round trip bit-for-bit
        for (a, b) in art.alpha.iter().zip(&loaded.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in art.centers.as_slice().iter().zip(loaded.centers.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and so do the predictions vs the training-side predict path
        let direct = model.predict(&eng, &q);
        let served = Predictor::new(&loaded).predict_batch(&q).unwrap();
        assert_eq!(direct.len(), served.len());
        for (a, b) in direct.iter().zip(&served) {
            assert_eq!(a.to_bits(), b.to_bits(), "prediction drifted: {a} vs {b}");
        }
    }

    #[test]
    fn binary_save_load_round_trip_is_bit_exact() {
        let (eng, model, q) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let path = tmp_path_ext("binroundtrip", "bin");
        art.save(&path).unwrap(); // .bin extension → binary encoding
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(&codec::MAGIC), "save did not pick the binary codec");
        let loaded = ModelArtifact::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        for (a, b) in art.alpha.iter().zip(&loaded.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in art.centers.as_slice().iter().zip(loaded.centers.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let direct = model.predict(&eng, &q);
        let served = Predictor::new(&loaded).predict_batch(&q).unwrap();
        for (a, b) in direct.iter().zip(&served) {
            assert_eq!(a.to_bits(), b.to_bits(), "binary artifact drifted: {a} vs {b}");
        }
    }

    #[test]
    fn truncated_binary_artifact_errors_cleanly() {
        let (eng, model, _) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let path = tmp_path_ext("bintrunc", "bin");
        art.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("decoding"), "unexpected error: {err}");
    }

    #[test]
    fn truncated_artifact_errors_cleanly() {
        let (eng, model, _) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let path = tmp_path("truncated");
        art.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(err.to_string().contains("parsing"), "unexpected error: {err}");
    }

    #[test]
    fn corrupted_artifact_fails_checksum() {
        let (eng, model, _) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let path = tmp_path("corrupt");
        art.save(&path).unwrap();
        // flip one digit inside the alpha payload, keeping valid JSON
        let text = std::fs::read_to_string(&path).unwrap();
        let k = text.find("\"alpha\":[").unwrap() + "\"alpha\":[".len();
        let mut bytes = text.into_bytes();
        let digit = (k..bytes.len())
            .find(|&i| bytes[i].is_ascii_digit() && bytes[i] != b'9')
            .unwrap();
        bytes[digit] = b'9';
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(err.to_string().contains("checksum"), "unexpected error: {err}");
    }

    #[test]
    fn version_mismatch_rejected() {
        let (eng, model, _) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let mut obj = match art.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        obj.insert("version".to_string(), Json::Num(99.0));
        let err = ModelArtifact::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(err.to_string().contains("version 99"), "unexpected error: {err}");
    }

    #[test]
    fn wrong_format_and_shapes_rejected() {
        assert!(ModelArtifact::from_json(&Json::parse("{\"format\":\"nope\"}").unwrap())
            .is_err());
        assert!(ModelArtifact::from_json(&Json::Num(3.0)).is_err());
        let (eng, mut model, _) = fitted();
        model.alpha.pop();
        assert!(ModelArtifact::from_fitted(&model, &eng, "x").is_err());
    }

    #[test]
    fn predictor_rejects_bad_dimension() {
        let (eng, model, _) = fitted();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let p = Predictor::new(&art);
        assert!(p.predict_one(&vec![0.0; p.dim() + 1]).is_err());
        assert!(p.predict_batch(&Matrix::zeros(3, p.dim() + 2)).is_err());
    }
}
