//! Multi-model registry: N named models served by one process.
//!
//! Each [`ModelEntry`] owns its own micro-batching queue, LRU cache,
//! counters and queue-depth cap, around a hot-swappable predictor:
//!
//! * **Routing** — requests carry `"model":"name"`; with exactly one
//!   model loaded the name may be omitted ([`Registry::resolve`]).
//! * **Hot reload** — [`ModelEntry::reload`] loads a new artifact (JSON
//!   or binary, auto-detected) and swaps the predictor behind an
//!   `RwLock<Arc<…>>`. Engine workers snapshot the `Arc` per batch, so
//!   in-flight requests complete against whichever predictor they
//!   started with and nothing is dropped; the query cache is cleared
//!   under the same swap (a stale score must not outlive its model) and
//!   a monotone version counter fences late cache inserts from batches
//!   that ran against the replaced predictor.
//! * **Backpressure** — [`ModelEntry::enqueue`] applies the per-model
//!   depth cap; beyond it the request is shed with [`Push::Full`] and
//!   the server answers a structured `overloaded` error instead of
//!   buffering without bound.

use crate::obs::{HistSnapshot, Histogram};
use crate::serve::batcher::{BatchQueue, PredictJob, Push};
use crate::serve::cache::{PredictionCache, QueryKey};
use crate::serve::model_store::{ModelArtifact, Predictor};
use crate::serve::protocol::StatsSnapshot;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Per-model monotone counters and latency/batch-size histograms
/// (lock-free; read via [`StatsSnapshot`]).
#[derive(Default)]
pub struct ModelStats {
    /// Predict requests routed to this model.
    pub requests: AtomicU64,
    /// Batches executed by this model's workers.
    pub batches: AtomicU64,
    /// Requests answered through batches.
    pub batched: AtomicU64,
    /// Requests answered from the cache.
    pub cache_hits: AtomicU64,
    /// Requests answered with an error.
    pub errors: AtomicU64,
    /// Requests shed by the queue-depth cap.
    pub shed: AtomicU64,
    /// Hot reloads applied.
    pub reloads: AtomicU64,
    /// Per-request predict latency in microseconds. The histogram's
    /// exact running sum is what the wire protocol still reports as
    /// `latency_us`, so pre-histogram clients keep working.
    pub latency: Histogram,
    /// Executed batch sizes (requests per batch).
    pub batch_sizes: Histogram,
}

impl ModelStats {
    /// Point-in-time copy of the counters, with p50/p95/p99 derived
    /// from the latency and batch-size histograms.
    pub fn snapshot(&self) -> StatsSnapshot {
        let lat = self.latency.snapshot();
        let batch = self.batch_sizes.snapshot();
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            latency_us: lat.sum,
            latency_p50_us: lat.percentile(0.50),
            latency_p95_us: lat.percentile(0.95),
            latency_p99_us: lat.percentile(0.99),
            batch_p50: batch.percentile(0.50),
            batch_p95: batch.percentile(0.95),
            batch_p99: batch.percentile(0.99),
        }
    }
}

/// Cache-lookup outcome: either a served score, or the key + model
/// version to use for the post-predict insert (`None` when caching is
/// off for this entry).
pub enum CacheProbe {
    /// The quantized query was cached; serve this score.
    Hit(f64),
    /// Miss — insert with [`ModelEntry::cache_insert`] after predicting.
    Miss(Option<(QueryKey, u64)>),
}

/// One named model: hot-swappable predictor + queue + cache + counters.
pub struct ModelEntry {
    name: String,
    source: Mutex<Option<PathBuf>>,
    predictor: RwLock<Arc<Predictor>>,
    /// Bumped on every swap; fences stale cache inserts.
    version: AtomicU64,
    /// This model's micro-batching queue (workers pop, handlers push).
    pub queue: BatchQueue<PredictJob>,
    cache: Option<Mutex<PredictionCache>>,
    /// This model's traffic counters.
    pub stats: ModelStats,
    max_queue: usize,
}

impl ModelEntry {
    fn new(
        name: String,
        artifact: &ModelArtifact,
        source: Option<PathBuf>,
        cache_capacity: usize,
        cache_quant: f64,
        max_queue: usize,
    ) -> ModelEntry {
        ModelEntry {
            name,
            source: Mutex::new(source),
            predictor: RwLock::new(Arc::new(Predictor::new(artifact))),
            version: AtomicU64::new(1),
            queue: BatchQueue::new(),
            cache: (cache_capacity > 0)
                .then(|| Mutex::new(PredictionCache::new(cache_capacity, cache_quant))),
            stats: ModelStats::default(),
            max_queue,
        }
    }

    /// The registry name requests route on.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Snapshot of the current predictor (workers hold this across a
    /// whole batch, so a concurrent reload never invalidates it).
    pub fn predictor(&self) -> Arc<Predictor> {
        Arc::clone(&self.predictor.read().unwrap())
    }

    /// Current feature dimension.
    pub fn dim(&self) -> usize {
        self.predictor.read().unwrap().dim()
    }

    /// Current number of centers M.
    pub fn m(&self) -> usize {
        self.predictor.read().unwrap().m()
    }

    /// Monotone model version: 1 at load, +1 per reload.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    /// Queue-depth cap (0 = unbounded).
    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// Enqueue a job under this model's depth cap.
    pub fn enqueue(&self, job: PredictJob) -> Push {
        self.queue.push_bounded(job, self.max_queue)
    }

    /// Probe the cache for a query.
    pub fn cache_probe(&self, x: &[f64]) -> CacheProbe {
        match &self.cache {
            None => CacheProbe::Miss(None),
            Some(cache) => {
                let mut c = cache.lock().unwrap();
                let key = c.key(x);
                match c.get(&key) {
                    Some(y) => CacheProbe::Hit(y),
                    // capture the version under the cache lock: a swap
                    // bumps it under the same lock, so a stale insert is
                    // reliably fenced
                    None => CacheProbe::Miss(Some((key, self.version.load(Ordering::SeqCst)))),
                }
            }
        }
    }

    /// Insert a freshly computed score, unless the model was swapped
    /// since the probe (the score may belong to the replaced predictor).
    pub fn cache_insert(&self, key: QueryKey, version: u64, y: f64) {
        if let Some(cache) = &self.cache {
            let mut c = cache.lock().unwrap();
            if self.version.load(Ordering::SeqCst) == version {
                c.insert(key, y);
            }
        }
    }

    /// Atomically swap in a new artifact. In-flight batches keep their
    /// predictor snapshot; new batches see the replacement; the cache is
    /// emptied under the swap so no stale score survives.
    pub fn swap(&self, artifact: &ModelArtifact) {
        let next = Arc::new(Predictor::new(artifact)); // built outside the lock
        let mut guard = self.predictor.write().unwrap();
        *guard = next;
        match &self.cache {
            Some(cache) => {
                let mut c = cache.lock().unwrap();
                self.version.fetch_add(1, Ordering::SeqCst);
                c.clear();
            }
            None => {
                self.version.fetch_add(1, Ordering::SeqCst);
            }
        }
        drop(guard);
        self.stats.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Hot-reload from `path`, or from the recorded source path when
    /// `None`. On success the source is updated and `(m, d, version)`
    /// of the new model returned; on failure the old model keeps
    /// serving untouched.
    pub fn reload(&self, path: Option<&Path>) -> anyhow::Result<(usize, usize, u64)> {
        // hold the source lock across resolve+load+swap+record: two
        // concurrent reloads serialize, so the recorded source always
        // names the artifact the active predictor actually came from
        let mut source = self.source.lock().unwrap();
        let target: PathBuf = match path {
            Some(p) => p.to_path_buf(),
            None => source.clone().ok_or_else(|| {
                anyhow::anyhow!(
                    "model {:?} was not loaded from a file; pass \"path\" in the reload request",
                    self.name
                )
            })?,
        };
        let artifact = ModelArtifact::load(&target)?;
        let (m, d) = (artifact.m(), artifact.d());
        self.swap(&artifact);
        *source = Some(target);
        Ok((m, d, self.version()))
    }
}

/// A model to register at server start.
pub struct ModelSpec {
    /// Registry name requests route on.
    pub name: String,
    /// The loaded artifact.
    pub artifact: ModelArtifact,
    /// Where it came from (enables path-less hot reload).
    pub source: Option<PathBuf>,
}

impl ModelSpec {
    /// Load a spec from a `name=path` CLI argument (`--models a=x.bin,…`).
    pub fn from_cli_arg(arg: &str) -> anyhow::Result<ModelSpec> {
        let (name, path) = arg
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad model spec {arg:?} (want name=path)"))?;
        let (name, path) = (name.trim(), path.trim());
        anyhow::ensure!(!name.is_empty() && !path.is_empty(), "bad model spec {arg:?}");
        Ok(ModelSpec {
            name: name.to_string(),
            artifact: ModelArtifact::load(path)?,
            source: Some(PathBuf::from(path)),
        })
    }
}

/// The model table. Names are seeded at startup and may grow or shrink
/// at run time ([`add`](Self::add) / [`remove`](Self::remove), driven
/// by the `admin add`/`admin remove` wire verbs); each entry's
/// predictor is hot-swappable independently of the table.
pub struct Registry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Per-model knobs recorded at startup so dynamically added models
    /// get the same cache and backpressure behaviour.
    cache_capacity: usize,
    cache_quant: f64,
    max_queue: usize,
    /// Set by [`close_all`](Self::close_all); fences late `add`s so no
    /// model can join after shutdown closed every queue.
    closed: std::sync::atomic::AtomicBool,
}

impl Registry {
    /// Build from the startup specs; names must be unique and nonempty.
    pub fn new(
        specs: Vec<ModelSpec>,
        cache_capacity: usize,
        cache_quant: f64,
        max_queue: usize,
    ) -> anyhow::Result<Registry> {
        anyhow::ensure!(!specs.is_empty(), "registry needs at least one model");
        let registry = Registry {
            models: RwLock::new(BTreeMap::new()),
            cache_capacity,
            cache_quant,
            max_queue,
            closed: std::sync::atomic::AtomicBool::new(false),
        };
        for spec in specs {
            registry.add(spec)?;
        }
        Ok(registry)
    }

    /// Register a new model at run time. Fails on a duplicate or empty
    /// name, or once [`close_all`](Self::close_all) has run. Returns the
    /// new entry so the caller can spawn its worker pool.
    pub fn add(&self, spec: ModelSpec) -> anyhow::Result<Arc<ModelEntry>> {
        anyhow::ensure!(!spec.name.is_empty(), "empty model name");
        let mut models = self.models.write().unwrap();
        // checked under the write lock: close_all takes the same lock,
        // so an add serializes against shutdown
        anyhow::ensure!(
            !self.closed.load(Ordering::SeqCst),
            "registry is shut down; cannot add {:?}",
            spec.name
        );
        anyhow::ensure!(
            !models.contains_key(&spec.name),
            "duplicate model name {:?}",
            spec.name
        );
        let entry = Arc::new(ModelEntry::new(
            spec.name.clone(),
            &spec.artifact,
            spec.source,
            self.cache_capacity,
            self.cache_quant,
            self.max_queue,
        ));
        models.insert(spec.name, Arc::clone(&entry));
        Ok(entry)
    }

    /// Unregister a model and close its queue: in-flight jobs drain,
    /// its workers exit, and the name immediately resolves to
    /// `unknown model` for new requests.
    pub fn remove(&self, name: &str) -> anyhow::Result<Arc<ModelEntry>> {
        let entry = {
            let mut models = self.models.write().unwrap();
            models.remove(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model {name:?} (loaded: {})",
                    models.keys().cloned().collect::<Vec<_>>().join(", ")
                )
            })?
        };
        entry.queue.close();
        Ok(entry)
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    /// Whether the registry is empty (only possible after `remove`).
    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    /// Look up a model by exact name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().unwrap().get(name).cloned()
    }

    /// All entries (cloned handles, for spawning per-model workers).
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    /// Route a request: an explicit name must exist; no name is allowed
    /// only when exactly one model is loaded.
    pub fn resolve(&self, name: Option<&str>) -> anyhow::Result<Arc<ModelEntry>> {
        let models = self.models.read().unwrap();
        let joined = || models.keys().cloned().collect::<Vec<_>>().join(", ");
        match name {
            Some(n) => models
                .get(n)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("unknown model {n:?} (loaded: {})", joined())),
            None if models.len() == 1 => Ok(models.values().next().unwrap().clone()),
            None => anyhow::bail!(
                "{} models loaded ({}); set \"model\" in the request",
                models.len(),
                joined()
            ),
        }
    }

    /// Close every model queue (shutdown: drain then stop workers) and
    /// fence out further [`add`](Self::add)s.
    pub fn close_all(&self) {
        let models = self.models.write().unwrap();
        self.closed.store(true, Ordering::SeqCst);
        for entry in models.values() {
            entry.queue.close();
        }
    }

    /// Sum of all per-model counters. Percentiles are recomputed from
    /// the *merged* histograms (summing per-model percentiles would be
    /// meaningless), so the aggregate p50/p95/p99 are exactly what one
    /// histogram over all traffic would report.
    pub fn aggregate_stats(&self) -> StatsSnapshot {
        let mut total = StatsSnapshot::default();
        let mut lat = HistSnapshot::default();
        let mut batch = HistSnapshot::default();
        for entry in self.entries() {
            total.add(&entry.stats.snapshot());
            lat.merge(&entry.stats.latency.snapshot());
            batch.merge(&entry.stats.batch_sizes.snapshot());
        }
        total.latency_p50_us = lat.percentile(0.50);
        total.latency_p95_us = lat.percentile(0.95);
        total.latency_p99_us = lat.percentile(0.99);
        total.batch_p50 = batch.percentile(0.50);
        total.batch_p95 = batch.percentile(0.95);
        total.batch_p99 = batch.percentile(0.99);
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn artifact(scale: f64, d: usize) -> ModelArtifact {
        ModelArtifact {
            sigma: 1.5,
            centers: Matrix::from_fn(5, d, |i, j| ((i * d + j) as f64 * 0.37).sin()),
            alpha: (0..5).map(|i| scale * (0.3 + i as f64 * 0.11)).collect(),
            trained_n: 5,
            dataset: "unit".to_string(),
        }
    }

    fn spec(name: &str, scale: f64) -> ModelSpec {
        ModelSpec { name: name.to_string(), artifact: artifact(scale, 3), source: None }
    }

    #[test]
    fn resolve_routes_by_name_and_defaults_when_unambiguous() {
        let one = Registry::new(vec![spec("only", 1.0)], 0, 1e-9, 0).unwrap();
        assert_eq!(one.resolve(None).unwrap().name(), "only");
        assert_eq!(one.resolve(Some("only")).unwrap().name(), "only");
        let err = one.resolve(Some("nope")).err().unwrap().to_string();
        assert!(err.contains("unknown model"), "got {err}");

        let two = Registry::new(vec![spec("a", 1.0), spec("b", 2.0)], 0, 1e-9, 0).unwrap();
        assert_eq!(two.resolve(Some("b")).unwrap().name(), "b");
        let err = two.resolve(None).err().unwrap().to_string();
        assert!(err.contains("set \"model\""), "got {err}");
        assert_eq!(two.names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn duplicate_and_empty_registries_rejected() {
        assert!(Registry::new(vec![], 0, 1e-9, 0).is_err());
        assert!(Registry::new(vec![spec("a", 1.0), spec("a", 2.0)], 0, 1e-9, 0)
            .err()
            .unwrap()
            .to_string()
            .contains("duplicate"));
    }

    #[test]
    fn swap_changes_predictions_bumps_version_and_clears_cache() {
        let reg = Registry::new(vec![spec("a", 1.0)], 16, 1e-9, 0).unwrap();
        let entry = reg.get("a").unwrap();
        let q = [0.1, -0.2, 0.3];
        let before = entry.predictor().predict_one(&q).unwrap();
        assert_eq!(entry.version(), 1);

        // prime the cache
        let probe = entry.cache_probe(&q);
        let pending = match probe {
            CacheProbe::Miss(p) => p.expect("cache enabled"),
            CacheProbe::Hit(_) => panic!("cold cache cannot hit"),
        };
        entry.cache_insert(pending.0.clone(), pending.1, before);
        assert!(matches!(entry.cache_probe(&q), CacheProbe::Hit(_)));

        entry.swap(&artifact(3.0, 3));
        assert_eq!(entry.version(), 2);
        assert_eq!(entry.stats.reloads.load(Ordering::Relaxed), 1);
        // cache was cleared with the swap
        assert!(matches!(entry.cache_probe(&q), CacheProbe::Miss(_)));
        let after = entry.predictor().predict_one(&q).unwrap();
        assert!(
            (after - 3.0 * before).abs() <= 1e-12 * before.abs().max(1.0),
            "α scaled by 3 should triple the score: {before} → {after}"
        );

        // a stale insert carrying the pre-swap version is fenced out
        entry.cache_insert(pending.0.clone(), pending.1, before);
        assert!(matches!(entry.cache_probe(&q), CacheProbe::Miss(_)));
    }

    #[test]
    fn reload_reads_either_format_from_disk_and_updates_source() {
        let reg = Registry::new(vec![spec("a", 1.0)], 0, 1e-9, 0).unwrap();
        let entry = reg.get("a").unwrap();
        // no source recorded and no path given → clean error, model intact
        let err = entry.reload(None).unwrap_err().to_string();
        assert!(err.contains("path"), "got {err}");
        assert_eq!(entry.version(), 1);

        let path = std::env::temp_dir()
            .join(format!("bless-registry-reload-{}.bin", std::process::id()));
        artifact(2.0, 3).save(&path).unwrap();
        let (m, d, version) = entry.reload(Some(path.as_path())).unwrap();
        assert_eq!((m, d, version), (5, 3, 2));
        // source is now recorded: path-less reload works
        let (_, _, version) = entry.reload(None).unwrap();
        assert_eq!(version, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn add_and_remove_models_at_run_time() {
        let reg = Registry::new(vec![spec("a", 1.0)], 0, 1e-9, 0).unwrap();
        let entry = reg.add(spec("b", 2.0)).unwrap();
        assert_eq!(entry.name(), "b");
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.add(spec("b", 3.0)).unwrap_err().to_string().contains("duplicate"));

        let removed = reg.remove("a").unwrap();
        // the removed entry's queue is closed: new work is refused, so
        // its workers drain and exit
        let (tx, _rx) = std::sync::mpsc::channel();
        assert_eq!(
            removed.enqueue(PredictJob { x: vec![0.0; 3], reply: tx }),
            Push::Closed
        );
        assert!(reg.remove("a").is_err(), "double remove must fail");
        assert_eq!(reg.names(), vec!["b".to_string()]);

        // after close_all, add is fenced out
        reg.close_all();
        let err = reg.add(spec("c", 1.0)).unwrap_err().to_string();
        assert!(err.contains("shut down"), "got {err}");
    }

    #[test]
    fn enqueue_applies_the_depth_cap() {
        let reg = Registry::new(vec![spec("a", 1.0)], 0, 1e-9, 2).unwrap();
        let entry = reg.get("a").unwrap();
        let job = |x: f64| {
            let (tx, rx) = std::sync::mpsc::channel();
            (PredictJob { x: vec![x, 0.0, 0.0], reply: tx }, rx)
        };
        let (j1, _r1) = job(0.1);
        let (j2, _r2) = job(0.2);
        let (j3, _r3) = job(0.3);
        assert_eq!(entry.enqueue(j1), Push::Accepted);
        assert_eq!(entry.enqueue(j2), Push::Accepted);
        assert_eq!(entry.enqueue(j3), Push::Full);
        assert_eq!(entry.queue.len(), 2);
    }

    #[test]
    fn aggregate_stats_sums_models() {
        let reg = Registry::new(vec![spec("a", 1.0), spec("b", 2.0)], 0, 1e-9, 0).unwrap();
        reg.get("a").unwrap().stats.requests.fetch_add(3, Ordering::Relaxed);
        reg.get("b").unwrap().stats.requests.fetch_add(4, Ordering::Relaxed);
        reg.get("b").unwrap().stats.shed.fetch_add(1, Ordering::Relaxed);
        let total = reg.aggregate_stats();
        assert_eq!(total.requests, 7);
        assert_eq!(total.shed, 1);
    }

    #[test]
    fn snapshot_derives_percentiles_and_aggregate_merges_histograms() {
        let reg = Registry::new(vec![spec("a", 1.0), spec("b", 2.0)], 0, 1e-9, 0).unwrap();
        let a = reg.get("a").unwrap();
        let b = reg.get("b").unwrap();
        // model a: fast (≈100 µs), model b: slow (≈10 ms)
        for _ in 0..100 {
            a.stats.latency.record(100);
            b.stats.latency.record(10_000);
        }
        let sa = a.stats.snapshot();
        assert_eq!(sa.latency_us, 100 * 100, "wire sum must stay exact");
        assert!(sa.latency_p50_us >= 100.0 && sa.latency_p50_us <= 125.0);
        assert!(sa.latency_p50_us <= sa.latency_p95_us);
        assert!(sa.latency_p95_us <= sa.latency_p99_us);
        // the aggregate percentiles come from the merged histogram: p50
        // of 100 fast + 100 slow requests sits at the fast/slow boundary,
        // not at the sum of per-model medians
        let total = reg.aggregate_stats();
        assert_eq!(total.latency_us, 100 * 100 + 100 * 10_000);
        assert!(total.latency_p50_us < 10_000.0, "p50 {}", total.latency_p50_us);
        assert!(total.latency_p99_us >= 10_000.0, "p99 {}", total.latency_p99_us);
    }
}
