//! Multi-model registry: N named models served by one process.
//!
//! Each [`ModelEntry`] owns its own micro-batching queue, LRU cache,
//! counters, queue-depth cap, and circuit breaker, around a
//! hot-swappable predictor:
//!
//! * **Routing** — requests carry `"model":"name"`; with exactly one
//!   model loaded the name may be omitted ([`Registry::resolve`]).
//! * **Hot reload** — [`ModelEntry::reload`] loads a new artifact (JSON
//!   or binary, auto-detected) and swaps the predictor behind an
//!   `RwLock<Arc<…>>`. Engine workers snapshot the `Arc` per batch, so
//!   in-flight requests complete against whichever predictor they
//!   started with and nothing is dropped; the query cache is cleared
//!   under the same swap (a stale score must not outlive its model) and
//!   a monotone version counter fences late cache inserts from batches
//!   that ran against the replaced predictor.
//! * **Backpressure** — [`ModelEntry::enqueue`] applies the per-model
//!   depth cap; beyond it the request is shed with [`Push::Full`] and
//!   the server answers a structured `overloaded` error instead of
//!   buffering without bound.
//! * **Quarantine** — each entry's [`Breaker`] counts consecutive
//!   worker-side failures (panics, engine errors). At the threshold the
//!   model is quarantined: new requests are refused up front with a
//!   structured `quarantined` error (the failing engine is not even
//!   asked), `/healthz` reports the model degraded, and after a cooldown
//!   one half-open probe request is let through — success re-admits the
//!   model, failure re-opens the breaker for another cooldown.

use crate::obs::{HistSnapshot, Histogram};
use crate::serve::batcher::{BatchQueue, PredictJob, Push};
use crate::serve::cache::{PredictionCache, QueryKey};
use crate::serve::model_store::{ModelArtifact, Predictor};
use crate::serve::protocol::StatsSnapshot;
use crate::util::sync as psync;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Per-model monotone counters and latency/batch-size histograms
/// (lock-free; read via [`StatsSnapshot`]).
#[derive(Default)]
pub struct ModelStats {
    /// Predict requests routed to this model.
    pub requests: AtomicU64,
    /// Batches executed by this model's workers.
    pub batches: AtomicU64,
    /// Requests answered through batches.
    pub batched: AtomicU64,
    /// Requests answered from the cache.
    pub cache_hits: AtomicU64,
    /// Requests answered with an error.
    pub errors: AtomicU64,
    /// Requests shed by the queue-depth cap.
    pub shed: AtomicU64,
    /// Hot reloads applied.
    pub reloads: AtomicU64,
    /// Requests answered `deadline_exceeded` (expired in queue or timed
    /// out waiting for the batch result).
    pub deadline_exceeded: AtomicU64,
    /// Requests refused up front because the breaker was open.
    pub quarantined: AtomicU64,
    /// Worker panics caught by the supervisor.
    pub worker_panics: AtomicU64,
    /// Supervised worker respawns (the pool never shrinks, so this
    /// tracks `worker_panics`).
    pub worker_respawns: AtomicU64,
    /// Lifecycle promotions: gate-passing candidates swapped in.
    pub promotions: AtomicU64,
    /// Lifecycle rollbacks: promotions undone inside the probation
    /// window after the breaker tripped.
    pub rollbacks: AtomicU64,
    /// Per-request predict latency in microseconds. The histogram's
    /// exact running sum is what the wire protocol still reports as
    /// `latency_us`, so pre-histogram clients keep working.
    pub latency: Histogram,
    /// Executed batch sizes (requests per batch).
    pub batch_sizes: Histogram,
}

impl ModelStats {
    /// Point-in-time copy of the counters, with p50/p95/p99 derived
    /// from the latency and batch-size histograms.
    pub fn snapshot(&self) -> StatsSnapshot {
        let lat = self.latency.snapshot();
        let batch = self.batch_sizes.snapshot();
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            worker_respawns: self.worker_respawns.load(Ordering::Relaxed),
            promotions: self.promotions.load(Ordering::Relaxed),
            rollbacks: self.rollbacks.load(Ordering::Relaxed),
            latency_us: lat.sum,
            latency_p50_us: lat.percentile(0.50),
            latency_p95_us: lat.percentile(0.95),
            latency_p99_us: lat.percentile(0.99),
            batch_p50: batch.percentile(0.50),
            batch_p95: batch.percentile(0.95),
            batch_p99: batch.percentile(0.99),
        }
    }

    /// Restore persisted counters (`serve --stats-file`): add the
    /// snapshot's counts onto the live atomics and fold the histograms
    /// back bucket-exactly where the snapshot carries them.
    pub fn restore(&self, s: &StatsSnapshot) {
        self.requests.fetch_add(s.requests, Ordering::Relaxed);
        self.batches.fetch_add(s.batches, Ordering::Relaxed);
        self.batched.fetch_add(s.batched, Ordering::Relaxed);
        self.cache_hits.fetch_add(s.cache_hits, Ordering::Relaxed);
        self.errors.fetch_add(s.errors, Ordering::Relaxed);
        self.shed.fetch_add(s.shed, Ordering::Relaxed);
        self.reloads.fetch_add(s.reloads, Ordering::Relaxed);
        self.deadline_exceeded.fetch_add(s.deadline_exceeded, Ordering::Relaxed);
        self.quarantined.fetch_add(s.quarantined, Ordering::Relaxed);
        self.worker_panics.fetch_add(s.worker_panics, Ordering::Relaxed);
        self.worker_respawns.fetch_add(s.worker_respawns, Ordering::Relaxed);
        self.promotions.fetch_add(s.promotions, Ordering::Relaxed);
        self.rollbacks.fetch_add(s.rollbacks, Ordering::Relaxed);
    }
}

/// Breaker admission decision for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Breaker closed (or disabled): serve normally.
    Allowed,
    /// Breaker half-open and this request won the probe slot: serve it;
    /// its outcome decides whether the model is re-admitted.
    Probe,
    /// Breaker open (or half-open with the probe already in flight):
    /// refuse with a structured `quarantined` error.
    Quarantined,
}

const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Per-model circuit breaker: `threshold` consecutive worker-side
/// failures open it; after `cooldown` one half-open probe is admitted,
/// and its outcome closes or re-opens the breaker. `threshold == 0`
/// disables the breaker entirely ([`admit`](Self::admit) always allows).
///
/// State machine (all transitions lock-free, CAS-guarded):
///
/// ```text
/// closed --K consecutive failures--> open --cooldown--> half-open
///   ^                                 ^                   |    |
///   |                                 +----probe fails----+    |
///   |         (probe released without a verdict: back to open,  |
///   |          cooldown already spent, so the next request      |
///   |          re-probes immediately)                           |
///   +-------------------probe succeeds-------------------------+
/// ```
///
/// Every admitted probe must resolve via exactly one of
/// [`record_success`](Self::record_success),
/// [`record_failure`](Self::record_failure), or
/// [`release_probe`](Self::release_probe) — otherwise the breaker
/// wedges half-open and quarantines the model forever.
pub struct Breaker {
    threshold: u32,
    cooldown: Duration,
    consecutive: AtomicU32,
    state: AtomicU8,
    /// When the breaker last opened, as millis since `epoch` (an
    /// `Instant` can't live in an atomic).
    opened_at_ms: AtomicU64,
    epoch: Instant,
    /// Times the breaker has opened (monotone; for metrics).
    trips: AtomicU64,
}

impl Breaker {
    /// A closed breaker with the given policy.
    pub fn new(threshold: u32, cooldown: Duration) -> Breaker {
        Breaker {
            threshold,
            cooldown,
            consecutive: AtomicU32::new(0),
            state: AtomicU8::new(BREAKER_CLOSED),
            opened_at_ms: AtomicU64::new(0),
            epoch: Instant::now(),
            trips: AtomicU64::new(0),
        }
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Admission decision for one incoming request.
    pub fn admit(&self) -> Admission {
        if self.threshold == 0 {
            return Admission::Allowed;
        }
        match self.state.load(Ordering::Acquire) {
            BREAKER_CLOSED => Admission::Allowed,
            BREAKER_OPEN => {
                let opened = self.opened_at_ms.load(Ordering::Acquire);
                if self.now_ms().saturating_sub(opened) >= self.cooldown.as_millis() as u64 {
                    // cooldown elapsed: exactly one caller wins the CAS
                    // and carries the half-open probe
                    if self
                        .state
                        .compare_exchange(
                            BREAKER_OPEN,
                            BREAKER_HALF_OPEN,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        return Admission::Probe;
                    }
                }
                Admission::Quarantined
            }
            _ => Admission::Quarantined, // half-open: probe already in flight
        }
    }

    /// A worker-side success for this model (a batch predicted cleanly).
    /// Resets the failure streak and closes the breaker — including from
    /// half-open, which is the probe succeeding.
    pub fn record_success(&self) {
        if self.threshold == 0 {
            return;
        }
        self.consecutive.store(0, Ordering::Release);
        self.state.store(BREAKER_CLOSED, Ordering::Release);
    }

    /// A worker-side failure (panic or engine error). From half-open
    /// this is the probe failing: re-open immediately for another
    /// cooldown. From closed, `threshold` consecutive failures open the
    /// breaker.
    pub fn record_failure(&self) {
        if self.threshold == 0 {
            return;
        }
        let state = self.state.load(Ordering::Acquire);
        if state == BREAKER_HALF_OPEN {
            self.open();
            return;
        }
        let streak = self.consecutive.fetch_add(1, Ordering::AcqRel) + 1;
        if streak >= self.threshold && state == BREAKER_CLOSED {
            self.open();
        }
    }

    fn open(&self) {
        self.opened_at_ms.store(self.now_ms(), Ordering::Release);
        if self.state.swap(BREAKER_OPEN, Ordering::AcqRel) != BREAKER_OPEN {
            self.trips.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The half-open probe ended without a verdict on the engine — the
    /// request hit the cache, was malformed, was shed by a full queue,
    /// or expired before a worker saw it. Returns the slot by moving
    /// half-open back to open *without* refreshing `opened_at_ms`, so
    /// the already-spent cooldown lets the very next request re-probe
    /// instead of quarantining everyone for another cooldown. No-op
    /// from any other state.
    pub fn release_probe(&self) {
        if self.threshold == 0 {
            return;
        }
        let _ = self.state.compare_exchange(
            BREAKER_HALF_OPEN,
            BREAKER_OPEN,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    /// Whether new requests are currently refused (open, cooldown not
    /// yet spent by a probe). Half-open reports `false`: the model is
    /// probing its way back.
    pub fn is_open(&self) -> bool {
        self.threshold != 0 && self.state.load(Ordering::Acquire) == BREAKER_OPEN
    }

    /// Numeric state for metrics: 0 closed, 1 open, 2 half-open.
    pub fn state_code(&self) -> u8 {
        if self.threshold == 0 {
            BREAKER_CLOSED
        } else {
            self.state.load(Ordering::Acquire)
        }
    }

    /// Times the breaker has opened.
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Force the breaker closed and clear the failure streak — called
    /// when a *new* model is promoted into this entry: the failures
    /// belonged to the replaced predictor, and the candidate earned its
    /// admission through the holdout gate. The trip count is monotone
    /// history and is deliberately preserved.
    pub fn reset(&self) {
        self.consecutive.store(0, Ordering::Release);
        self.state.store(BREAKER_CLOSED, Ordering::Release);
    }
}

/// Cache-lookup outcome: either a served score, or the key + model
/// version to use for the post-predict insert (`None` when caching is
/// off for this entry).
pub enum CacheProbe {
    /// The quantized query was cached; serve this score.
    Hit(f64),
    /// Miss — insert with [`ModelEntry::cache_insert`] after predicting.
    Miss(Option<(QueryKey, u64)>),
}

/// One named model: hot-swappable predictor + queue + cache + counters
/// + circuit breaker.
pub struct ModelEntry {
    name: String,
    source: Mutex<Option<PathBuf>>,
    predictor: RwLock<Arc<Predictor>>,
    /// Bumped on every swap; fences stale cache inserts.
    version: AtomicU64,
    /// This model's micro-batching queue (workers pop, handlers push).
    pub queue: BatchQueue<PredictJob>,
    cache: Option<Mutex<PredictionCache>>,
    /// This model's traffic counters.
    pub stats: ModelStats,
    /// This model's circuit breaker (threshold 0 = disabled).
    pub breaker: Breaker,
    max_queue: usize,
}

impl ModelEntry {
    fn new(
        name: String,
        artifact: &ModelArtifact,
        source: Option<PathBuf>,
        cfg: &RegistryConfig,
    ) -> ModelEntry {
        ModelEntry {
            name,
            source: Mutex::new(source),
            predictor: RwLock::new(Arc::new(Predictor::new(artifact))),
            version: AtomicU64::new(1),
            queue: BatchQueue::new(),
            cache: (cfg.cache_capacity > 0)
                .then(|| Mutex::new(PredictionCache::new(cfg.cache_capacity, cfg.cache_quant))),
            stats: ModelStats::default(),
            breaker: Breaker::new(cfg.breaker_threshold, cfg.breaker_cooldown),
            max_queue: cfg.max_queue,
        }
    }

    /// The registry name requests route on.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Snapshot of the current predictor (workers hold this across a
    /// whole batch, so a concurrent reload never invalidates it).
    pub fn predictor(&self) -> Arc<Predictor> {
        Arc::clone(&psync::read(&self.predictor))
    }

    /// Current feature dimension.
    pub fn dim(&self) -> usize {
        psync::read(&self.predictor).dim()
    }

    /// Current number of centers M.
    pub fn m(&self) -> usize {
        psync::read(&self.predictor).m()
    }

    /// Monotone model version: 1 at load, +1 per reload.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    /// Queue-depth cap (0 = unbounded).
    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// Enqueue a job under this model's depth cap.
    pub fn enqueue(&self, job: PredictJob) -> Push {
        self.queue.push_bounded(job, self.max_queue)
    }

    /// Probe the cache for a query.
    pub fn cache_probe(&self, x: &[f64]) -> CacheProbe {
        match &self.cache {
            None => CacheProbe::Miss(None),
            Some(cache) => {
                let mut c = psync::lock(cache);
                let key = c.key(x);
                match c.get(&key) {
                    Some(y) => CacheProbe::Hit(y),
                    // capture the version under the cache lock: a swap
                    // bumps it under the same lock, so a stale insert is
                    // reliably fenced
                    None => CacheProbe::Miss(Some((key, self.version.load(Ordering::SeqCst)))),
                }
            }
        }
    }

    /// Insert a freshly computed score, unless the model was swapped
    /// since the probe (the score may belong to the replaced predictor).
    pub fn cache_insert(&self, key: QueryKey, version: u64, y: f64) {
        if let Some(cache) = &self.cache {
            let mut c = psync::lock(cache);
            if self.version.load(Ordering::SeqCst) == version {
                c.insert(key, y);
            }
        }
    }

    /// Atomically swap in a new artifact. In-flight batches keep their
    /// predictor snapshot; new batches see the replacement; the cache is
    /// emptied under the swap so no stale score survives.
    pub fn swap(&self, artifact: &ModelArtifact) {
        let next = Arc::new(Predictor::new(artifact)); // built outside the lock
        let mut guard = psync::write(&self.predictor);
        *guard = next;
        match &self.cache {
            Some(cache) => {
                let mut c = psync::lock(cache);
                self.version.fetch_add(1, Ordering::SeqCst);
                c.clear();
            }
            None => {
                self.version.fetch_add(1, Ordering::SeqCst);
            }
        }
        drop(guard);
        self.stats.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Hot-reload from `path`, or from the recorded source path when
    /// `None`. On success the source is updated and `(m, d, version)`
    /// of the new model returned; on failure the old model keeps
    /// serving untouched.
    pub fn reload(&self, path: Option<&Path>) -> anyhow::Result<(usize, usize, u64)> {
        // hold the source lock across resolve+load+swap+record: two
        // concurrent reloads serialize, so the recorded source always
        // names the artifact the active predictor actually came from
        let mut source = psync::lock(&self.source);
        let target: PathBuf = match path {
            Some(p) => p.to_path_buf(),
            None => source.clone().ok_or_else(|| {
                anyhow::anyhow!(
                    "model {:?} was not loaded from a file; pass \"path\" in the reload request",
                    self.name
                )
            })?,
        };
        let artifact = ModelArtifact::load(&target)?;
        let (m, d) = (artifact.m(), artifact.d());
        // reject a dimension change *before* the swap: in-flight and
        // queued requests were validated against the current input dim,
        // and silently changing it mid-stream would turn every one of
        // them into a bad_request. The incumbent keeps serving.
        anyhow::ensure!(
            d == self.dim(),
            "refusing reload of model {:?}: artifact input dimension {} != serving dimension {}",
            self.name,
            d,
            self.dim()
        );
        self.swap(&artifact);
        *source = Some(target);
        Ok((m, d, self.version()))
    }
}

/// A model to register at server start.
pub struct ModelSpec {
    /// Registry name requests route on.
    pub name: String,
    /// The loaded artifact.
    pub artifact: ModelArtifact,
    /// Where it came from (enables path-less hot reload).
    pub source: Option<PathBuf>,
}

impl ModelSpec {
    /// Load a spec from a `name=path` CLI argument (`--models a=x.bin,…`).
    pub fn from_cli_arg(arg: &str) -> anyhow::Result<ModelSpec> {
        let (name, path) = arg
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad model spec {arg:?} (want name=path)"))?;
        let (name, path) = (name.trim(), path.trim());
        anyhow::ensure!(!name.is_empty() && !path.is_empty(), "bad model spec {arg:?}");
        Ok(ModelSpec {
            name: name.to_string(),
            artifact: ModelArtifact::load(path)?,
            source: Some(PathBuf::from(path)),
        })
    }
}

/// Per-model knobs applied to every entry (startup and dynamically
/// added alike).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct RegistryConfig {
    /// LRU query-cache capacity per model (0 = caching off).
    pub cache_capacity: usize,
    /// Cache quantization step.
    pub cache_quant: f64,
    /// Queue-depth cap per model (0 = unbounded).
    pub max_queue: usize,
    /// Consecutive worker-side failures that quarantine a model
    /// (0 = breaker disabled).
    pub breaker_threshold: u32,
    /// How long a quarantined model waits before its half-open probe.
    pub breaker_cooldown: Duration,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            cache_capacity: 0,
            cache_quant: 1e-9,
            max_queue: 0,
            breaker_threshold: 0,
            breaker_cooldown: Duration::from_secs(1),
        }
    }
}

/// The model table. Names are seeded at startup and may grow or shrink
/// at run time ([`add`](Self::add) / [`remove`](Self::remove), driven
/// by the `admin add`/`admin remove` wire verbs); each entry's
/// predictor is hot-swappable independently of the table.
pub struct Registry {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Per-model knobs recorded at startup so dynamically added models
    /// get the same cache, backpressure, and breaker behaviour.
    config: RegistryConfig,
    /// Set by [`close_all`](Self::close_all); fences late `add`s so no
    /// model can join after shutdown closed every queue.
    closed: std::sync::atomic::AtomicBool,
}

impl Registry {
    /// Build from the startup specs; names must be unique and nonempty.
    pub fn new(specs: Vec<ModelSpec>, config: RegistryConfig) -> anyhow::Result<Registry> {
        anyhow::ensure!(!specs.is_empty(), "registry needs at least one model");
        let registry = Registry {
            models: RwLock::new(BTreeMap::new()),
            config,
            closed: std::sync::atomic::AtomicBool::new(false),
        };
        for spec in specs {
            registry.add(spec)?;
        }
        Ok(registry)
    }

    /// Register a new model at run time. Fails on a duplicate or empty
    /// name, or once [`close_all`](Self::close_all) has run. Returns the
    /// new entry so the caller can spawn its worker pool.
    pub fn add(&self, spec: ModelSpec) -> anyhow::Result<Arc<ModelEntry>> {
        anyhow::ensure!(!spec.name.is_empty(), "empty model name");
        let mut models = psync::write(&self.models);
        // checked under the write lock: close_all takes the same lock,
        // so an add serializes against shutdown
        anyhow::ensure!(
            !self.closed.load(Ordering::SeqCst),
            "registry is shut down; cannot add {:?}",
            spec.name
        );
        anyhow::ensure!(
            !models.contains_key(&spec.name),
            "duplicate model name {:?}",
            spec.name
        );
        let entry = Arc::new(ModelEntry::new(
            spec.name.clone(),
            &spec.artifact,
            spec.source,
            &self.config,
        ));
        models.insert(spec.name, Arc::clone(&entry));
        Ok(entry)
    }

    /// Unregister a model and close its queue: in-flight jobs drain,
    /// its workers exit, and the name immediately resolves to
    /// `unknown model` for new requests.
    pub fn remove(&self, name: &str) -> anyhow::Result<Arc<ModelEntry>> {
        let entry = {
            let mut models = psync::write(&self.models);
            models.remove(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model {name:?} (loaded: {})",
                    models.keys().cloned().collect::<Vec<_>>().join(", ")
                )
            })?
        };
        entry.queue.close();
        Ok(entry)
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        psync::read(&self.models).len()
    }

    /// Whether the registry is empty (only possible after `remove`).
    pub fn is_empty(&self) -> bool {
        psync::read(&self.models).is_empty()
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        psync::read(&self.models).keys().cloned().collect()
    }

    /// Look up a model by exact name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        psync::read(&self.models).get(name).cloned()
    }

    /// All entries (cloned handles, for spawning per-model workers).
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        psync::read(&self.models).values().cloned().collect()
    }

    /// Route a request: an explicit name must exist; no name is allowed
    /// only when exactly one model is loaded.
    pub fn resolve(&self, name: Option<&str>) -> anyhow::Result<Arc<ModelEntry>> {
        let models = psync::read(&self.models);
        let joined = || models.keys().cloned().collect::<Vec<_>>().join(", ");
        match name {
            Some(n) => models
                .get(n)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("unknown model {n:?} (loaded: {})", joined())),
            None if models.len() == 1 => Ok(models.values().next().unwrap().clone()),
            None => anyhow::bail!(
                "{} models loaded ({}); set \"model\" in the request",
                models.len(),
                joined()
            ),
        }
    }

    /// Close every model queue (shutdown: drain then stop workers) and
    /// fence out further [`add`](Self::add)s.
    pub fn close_all(&self) {
        let models = psync::write(&self.models);
        self.closed.store(true, Ordering::SeqCst);
        for entry in models.values() {
            entry.queue.close();
        }
    }

    /// Sum of all per-model counters. Percentiles are recomputed from
    /// the *merged* histograms (summing per-model percentiles would be
    /// meaningless), so the aggregate p50/p95/p99 are exactly what one
    /// histogram over all traffic would report.
    pub fn aggregate_stats(&self) -> StatsSnapshot {
        let mut total = StatsSnapshot::default();
        let mut lat = HistSnapshot::default();
        let mut batch = HistSnapshot::default();
        for entry in self.entries() {
            total.add(&entry.stats.snapshot());
            lat.merge(&entry.stats.latency.snapshot());
            batch.merge(&entry.stats.batch_sizes.snapshot());
        }
        total.latency_p50_us = lat.percentile(0.50);
        total.latency_p95_us = lat.percentile(0.95);
        total.latency_p99_us = lat.percentile(0.99);
        total.batch_p50 = batch.percentile(0.50);
        total.batch_p95 = batch.percentile(0.95);
        total.batch_p99 = batch.percentile(0.99);
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn artifact(scale: f64, d: usize) -> ModelArtifact {
        ModelArtifact {
            sigma: 1.5,
            centers: Matrix::from_fn(5, d, |i, j| ((i * d + j) as f64 * 0.37).sin()),
            alpha: (0..5).map(|i| scale * (0.3 + i as f64 * 0.11)).collect(),
            trained_n: 5,
            dataset: "unit".to_string(),
        }
    }

    fn spec(name: &str, scale: f64) -> ModelSpec {
        ModelSpec { name: name.to_string(), artifact: artifact(scale, 3), source: None }
    }

    #[test]
    fn resolve_routes_by_name_and_defaults_when_unambiguous() {
        let one = Registry::new(vec![spec("only", 1.0)], RegistryConfig::default()).unwrap();
        assert_eq!(one.resolve(None).unwrap().name(), "only");
        assert_eq!(one.resolve(Some("only")).unwrap().name(), "only");
        let err = one.resolve(Some("nope")).err().unwrap().to_string();
        assert!(err.contains("unknown model"), "got {err}");

        let two = Registry::new(vec![spec("a", 1.0), spec("b", 2.0)], RegistryConfig::default())
            .unwrap();
        assert_eq!(two.resolve(Some("b")).unwrap().name(), "b");
        let err = two.resolve(None).err().unwrap().to_string();
        assert!(err.contains("set \"model\""), "got {err}");
        assert_eq!(two.names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn duplicate_and_empty_registries_rejected() {
        assert!(Registry::new(vec![], RegistryConfig::default()).is_err());
        assert!(
            Registry::new(vec![spec("a", 1.0), spec("a", 2.0)], RegistryConfig::default())
                .err()
                .unwrap()
                .to_string()
                .contains("duplicate")
        );
    }

    #[test]
    fn swap_changes_predictions_bumps_version_and_clears_cache() {
        let cfg = RegistryConfig { cache_capacity: 16, ..RegistryConfig::default() };
        let reg = Registry::new(vec![spec("a", 1.0)], cfg).unwrap();
        let entry = reg.get("a").unwrap();
        let q = [0.1, -0.2, 0.3];
        let before = entry.predictor().predict_one(&q).unwrap();
        assert_eq!(entry.version(), 1);

        // prime the cache
        let probe = entry.cache_probe(&q);
        let pending = match probe {
            CacheProbe::Miss(p) => p.expect("cache enabled"),
            CacheProbe::Hit(_) => panic!("cold cache cannot hit"),
        };
        entry.cache_insert(pending.0.clone(), pending.1, before);
        assert!(matches!(entry.cache_probe(&q), CacheProbe::Hit(_)));

        entry.swap(&artifact(3.0, 3));
        assert_eq!(entry.version(), 2);
        assert_eq!(entry.stats.reloads.load(Ordering::Relaxed), 1);
        // cache was cleared with the swap
        assert!(matches!(entry.cache_probe(&q), CacheProbe::Miss(_)));
        let after = entry.predictor().predict_one(&q).unwrap();
        assert!(
            (after - 3.0 * before).abs() <= 1e-12 * before.abs().max(1.0),
            "α scaled by 3 should triple the score: {before} → {after}"
        );

        // a stale insert carrying the pre-swap version is fenced out
        entry.cache_insert(pending.0.clone(), pending.1, before);
        assert!(matches!(entry.cache_probe(&q), CacheProbe::Miss(_)));
    }

    #[test]
    fn reload_reads_either_format_from_disk_and_updates_source() {
        let reg = Registry::new(vec![spec("a", 1.0)], RegistryConfig::default()).unwrap();
        let entry = reg.get("a").unwrap();
        // no source recorded and no path given → clean error, model intact
        let err = entry.reload(None).unwrap_err().to_string();
        assert!(err.contains("path"), "got {err}");
        assert_eq!(entry.version(), 1);

        let path = std::env::temp_dir()
            .join(format!("bless-registry-reload-{}.bin", std::process::id()));
        artifact(2.0, 3).save(&path).unwrap();
        let (m, d, version) = entry.reload(Some(path.as_path())).unwrap();
        assert_eq!((m, d, version), (5, 3, 2));
        // source is now recorded: path-less reload works
        let (_, _, version) = entry.reload(None).unwrap();
        assert_eq!(version, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reload_rejects_dimension_mismatch_before_swap() {
        let reg = Registry::new(vec![spec("a", 1.0)], RegistryConfig::default()).unwrap();
        let entry = reg.get("a").unwrap();
        let q = [0.1, -0.2, 0.3];
        let before = entry.predictor().predict_one(&q).unwrap();

        let path = std::env::temp_dir()
            .join(format!("bless-registry-dim-mismatch-{}.bin", std::process::id()));
        artifact(2.0, 4).save(&path).unwrap();
        let err = entry.reload(Some(path.as_path())).unwrap_err().to_string();
        assert!(err.contains("dimension 4"), "got {err}");
        std::fs::remove_file(&path).ok();

        // the swap never happened: version, dim and predictions intact
        assert_eq!(entry.version(), 1);
        assert_eq!(entry.dim(), 3);
        assert_eq!(entry.stats.reloads.load(Ordering::Relaxed), 0);
        let after = entry.predictor().predict_one(&q).unwrap();
        assert_eq!(after.to_bits(), before.to_bits(), "incumbent must be untouched");
    }

    #[test]
    fn promotion_reset_closes_an_open_breaker_and_keeps_history() {
        let b = Breaker::new(2, Duration::from_secs(3600));
        b.record_failure();
        b.record_failure();
        assert!(b.is_open());
        assert_eq!(b.trips(), 1);

        // promotion: breaker force-closed, trip history preserved
        b.reset();
        assert!(!b.is_open(), "reset must close the breaker immediately");
        assert_eq!(b.trips(), 1, "trip count is history, not state");

        // and the failure streak restarted from zero
        b.record_failure();
        assert!(!b.is_open(), "one failure after reset is below threshold");
        b.record_failure();
        assert!(b.is_open(), "breaker still functions after reset");
        assert_eq!(b.trips(), 2);
    }

    #[test]
    fn add_and_remove_models_at_run_time() {
        let reg = Registry::new(vec![spec("a", 1.0)], RegistryConfig::default()).unwrap();
        let entry = reg.add(spec("b", 2.0)).unwrap();
        assert_eq!(entry.name(), "b");
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.add(spec("b", 3.0)).unwrap_err().to_string().contains("duplicate"));

        let removed = reg.remove("a").unwrap();
        // the removed entry's queue is closed: new work is refused, so
        // its workers drain and exit
        let (tx, _rx) = std::sync::mpsc::channel();
        assert_eq!(
            removed.enqueue(PredictJob { x: vec![0.0; 3], reply: tx, deadline: None }),
            Push::Closed
        );
        assert!(reg.remove("a").is_err(), "double remove must fail");
        assert_eq!(reg.names(), vec!["b".to_string()]);

        // after close_all, add is fenced out
        reg.close_all();
        let err = reg.add(spec("c", 1.0)).unwrap_err().to_string();
        assert!(err.contains("shut down"), "got {err}");
    }

    #[test]
    fn enqueue_applies_the_depth_cap() {
        let cfg = RegistryConfig { max_queue: 2, ..RegistryConfig::default() };
        let reg = Registry::new(vec![spec("a", 1.0)], cfg).unwrap();
        let entry = reg.get("a").unwrap();
        let job = |x: f64| {
            let (tx, rx) = std::sync::mpsc::channel();
            (PredictJob { x: vec![x, 0.0, 0.0], reply: tx, deadline: None }, rx)
        };
        let (j1, _r1) = job(0.1);
        let (j2, _r2) = job(0.2);
        let (j3, _r3) = job(0.3);
        assert_eq!(entry.enqueue(j1), Push::Accepted);
        assert_eq!(entry.enqueue(j2), Push::Accepted);
        assert_eq!(entry.enqueue(j3), Push::Full);
        assert_eq!(entry.queue.len(), 2);
    }

    #[test]
    fn aggregate_stats_sums_models() {
        let reg = Registry::new(vec![spec("a", 1.0), spec("b", 2.0)], RegistryConfig::default())
            .unwrap();
        reg.get("a").unwrap().stats.requests.fetch_add(3, Ordering::Relaxed);
        reg.get("b").unwrap().stats.requests.fetch_add(4, Ordering::Relaxed);
        reg.get("b").unwrap().stats.shed.fetch_add(1, Ordering::Relaxed);
        reg.get("a").unwrap().stats.deadline_exceeded.fetch_add(2, Ordering::Relaxed);
        reg.get("b").unwrap().stats.quarantined.fetch_add(5, Ordering::Relaxed);
        let total = reg.aggregate_stats();
        assert_eq!(total.requests, 7);
        assert_eq!(total.shed, 1);
        assert_eq!(total.deadline_exceeded, 2);
        assert_eq!(total.quarantined, 5);
    }

    #[test]
    fn snapshot_derives_percentiles_and_aggregate_merges_histograms() {
        let reg = Registry::new(vec![spec("a", 1.0), spec("b", 2.0)], RegistryConfig::default())
            .unwrap();
        let a = reg.get("a").unwrap();
        let b = reg.get("b").unwrap();
        // model a: fast (≈100 µs), model b: slow (≈10 ms)
        for _ in 0..100 {
            a.stats.latency.record(100);
            b.stats.latency.record(10_000);
        }
        let sa = a.stats.snapshot();
        assert_eq!(sa.latency_us, 100 * 100, "wire sum must stay exact");
        assert!(sa.latency_p50_us >= 100.0 && sa.latency_p50_us <= 125.0);
        assert!(sa.latency_p50_us <= sa.latency_p95_us);
        assert!(sa.latency_p95_us <= sa.latency_p99_us);
        // the aggregate percentiles come from the merged histogram: p50
        // of 100 fast + 100 slow requests sits at the fast/slow boundary,
        // not at the sum of per-model medians
        let total = reg.aggregate_stats();
        assert_eq!(total.latency_us, 100 * 100 + 100 * 10_000);
        assert!(total.latency_p50_us < 10_000.0, "p50 {}", total.latency_p50_us);
        assert!(total.latency_p99_us >= 10_000.0, "p99 {}", total.latency_p99_us);
    }

    #[test]
    fn stats_restore_adds_counters_back() {
        let stats = ModelStats::default();
        stats.requests.fetch_add(2, Ordering::Relaxed);
        let mut snap = StatsSnapshot::default();
        snap.requests = 40;
        snap.deadline_exceeded = 7;
        snap.worker_respawns = 3;
        stats.restore(&snap);
        let s = stats.snapshot();
        assert_eq!(s.requests, 42);
        assert_eq!(s.deadline_exceeded, 7);
        assert_eq!(s.worker_respawns, 3);
    }

    #[test]
    fn breaker_trips_at_threshold_and_recovers_through_half_open() {
        let b = Breaker::new(3, Duration::from_millis(20));
        assert_eq!(b.admit(), Admission::Allowed);
        assert_eq!(b.state_code(), 0);

        b.record_failure();
        b.record_failure();
        assert_eq!(b.admit(), Admission::Allowed, "below threshold stays closed");
        b.record_failure();
        assert!(b.is_open());
        assert_eq!(b.state_code(), 1);
        assert_eq!(b.trips(), 1);
        assert_eq!(b.admit(), Admission::Quarantined, "open refuses before cooldown");

        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(b.admit(), Admission::Probe, "cooldown elapsed: one probe");
        assert_eq!(b.state_code(), 2);
        assert_eq!(b.admit(), Admission::Quarantined, "only one probe in flight");
        assert!(!b.is_open(), "half-open is probing, not refusing outright");

        // probe fails → re-open for another cooldown
        b.record_failure();
        assert!(b.is_open());
        assert_eq!(b.trips(), 2);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(b.admit(), Admission::Probe);
        // probe succeeds → closed, traffic flows again
        b.record_success();
        assert_eq!(b.state_code(), 0);
        assert_eq!(b.admit(), Admission::Allowed);
    }

    #[test]
    fn breaker_success_resets_the_failure_streak() {
        let b = Breaker::new(3, Duration::from_millis(10));
        b.record_failure();
        b.record_failure();
        b.record_success();
        b.record_failure();
        b.record_failure();
        assert!(!b.is_open(), "streak was reset; 2 < 3 failures since");
        b.record_failure();
        assert!(b.is_open());
    }

    #[test]
    fn released_probe_reopens_and_readmits_immediately() {
        let b = Breaker::new(1, Duration::from_millis(5));
        b.record_failure();
        assert!(b.is_open());
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(b.admit(), Admission::Probe);
        // the probe exits without an engine verdict (cache hit, bad
        // dims, shed queue, expired deadline): the slot must come back
        b.release_probe();
        assert!(b.is_open(), "slot returned: breaker is open again");
        // cooldown was already spent, so the next request re-probes at
        // once instead of the model quarantining for another cooldown
        assert_eq!(b.admit(), Admission::Probe);
        b.record_success();
        assert_eq!(b.admit(), Admission::Allowed);
    }

    #[test]
    fn release_probe_is_a_noop_outside_half_open() {
        let b = Breaker::new(2, Duration::from_millis(5));
        b.release_probe();
        assert_eq!(b.admit(), Admission::Allowed, "closed stays closed");
        b.record_failure();
        b.record_failure();
        assert!(b.is_open());
        b.release_probe();
        assert!(b.is_open(), "open stays open");
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn disabled_breaker_never_trips() {
        let b = Breaker::new(0, Duration::from_millis(1));
        for _ in 0..100 {
            b.record_failure();
        }
        assert!(!b.is_open());
        assert_eq!(b.admit(), Admission::Allowed);
        assert_eq!(b.state_code(), 0);
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn probe_race_admits_exactly_one() {
        let b = Arc::new(Breaker::new(1, Duration::from_millis(5)));
        b.record_failure();
        assert!(b.is_open());
        std::thread::sleep(Duration::from_millis(10));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || b.admit())
            })
            .collect();
        let decisions: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        let probes = decisions.iter().filter(|d| **d == Admission::Probe).count();
        assert_eq!(probes, 1, "got {decisions:?}");
        assert!(decisions.iter().all(|d| *d != Admission::Allowed));
    }
}
