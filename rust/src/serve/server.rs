//! The TCP prediction server: stdlib-only (`std::net` + threads).
//!
//! Topology (one process, N registered models):
//!
//! ```text
//! accept loop ──spawns──▶ connection threads (parse, route, cache,
//!                              │              bounded enqueue)
//!                              │ PredictJob (per model)
//!                              ▼
//!                  ModelEntry.queue  ◀─ micro-batching (linger + max)
//!                              │      ◀─ depth cap → `overloaded` shed
//!                              │ batch
//!                              ▼
//!                 per-model engine workers (snapshot the entry's
//!                 Arc<Predictor> per batch — one cross_block GEMM)
//! ```
//!
//! Hot reload (`{"op":"admin","cmd":"reload",…}`) swaps one entry's
//! predictor atomically: queued jobs are answered by whichever predictor
//! the worker snapshots, nothing in flight is dropped.
//!
//! Shutdown (`{"op":"shutdown"}` or [`ServerHandle::shutdown`]) closes
//! every model queue (in-flight work drains, new work is refused), pokes
//! the accept loop and joins the worker pool. Idle keep-alive
//! connections are dropped when the process exits.
//!
//! With [`ServeConfig::metrics_addr`] set, a second listener serves
//! `GET /metrics` (Prometheus), `/healthz` and `/varz` through
//! [`MetricsBridge`]-over-[`crate::obs::serve_http`] — scrape traffic
//! never touches the prediction socket.
//!
//! ## Robustness
//!
//! The tier is hardened against the failure modes the
//! [`crate::faults`] chaos harness injects (`tests/chaos_soak.rs`
//! proves each one):
//!
//! * **Deadlines** — a request's `deadline_ms` (or
//!   [`ServeConfig::default_deadline`]) bounds enqueue→reply; expired
//!   jobs are discarded at dequeue and answered `deadline_exceeded`.
//! * **Socket timeouts** — every connection gets
//!   [`ServeConfig::io_timeout`] read/write timeouts, so a slowloris
//!   peer (or an injected stall) cannot pin a connection thread forever.
//! * **Panic isolation** — each engine worker runs its batch ticks
//!   under `catch_unwind` inside a supervision loop: a panicking batch
//!   answers its jobs with a structured `internal` error (a drop guard
//!   replies even mid-unwind), the worker respawns in place, and the
//!   pool never shrinks.
//! * **Circuit breaker** — consecutive worker-side failures quarantine
//!   a model ([`crate::serve::registry::Breaker`]): requests are
//!   refused up front with `quarantined`, `/healthz` degrades, and a
//!   half-open probe re-admits the model after
//!   [`ServeConfig::breaker_cooldown`].
//! * **Crash-safe stats** — with [`ServeConfig::stats_file`] set,
//!   per-model counters and histograms persist across restarts
//!   ([`crate::serve::stats_io`]); [`ServeConfig::stats_flush`] also
//!   flushes them periodically (atomic replace), so even a SIGKILL
//!   loses at most one interval of history.

use crate::linalg::Matrix;
use crate::obs::{escape_label, serve_http, HttpHandle, MetricsProvider};
use crate::serve::batcher::{JobError, PredictJob, Push};
use crate::serve::model_store::ModelArtifact;
use crate::serve::protocol::{
    self, AdminRequest, AdminResponse, ModelInfo, Request, StatsSnapshot,
};
use crate::serve::registry::{
    Admission, CacheProbe, ModelEntry, ModelSpec, ModelStats, Registry, RegistryConfig,
};
use crate::util::json::Json;
use crate::util::sync as psync;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server tuning knobs.
///
/// Construct with [`ServeConfig::builder`] — the builder validates the
/// combination before handing back a config — or start from
/// [`ServeConfig::default`] and override fields. The struct is
/// `#[non_exhaustive]`: downstream crates cannot use struct literals,
/// so new knobs can be added without breaking them.
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct ServeConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Engine worker threads **per model** (each model batches
    /// independently; workers share that model's hot-swappable predictor).
    pub workers: usize,
    /// Largest coalesced batch per GEMM.
    pub max_batch: usize,
    /// How long a worker lingers for stragglers after the first request.
    pub linger: Duration,
    /// Prediction-cache capacity in entries per model (0 disables).
    pub cache_capacity: usize,
    /// Cache quantization step for query coordinates.
    pub cache_quant: f64,
    /// Max queued (not yet batched) requests per model; beyond this the
    /// request is shed with a structured `overloaded` error. 0 =
    /// unbounded (the PR-1 behaviour).
    pub max_queue: usize,
    /// Width of the process-wide compute pool
    /// ([`crate::util::pool`]) that every model's batch GEMMs run on —
    /// one thread policy per process, shared with training if both run
    /// in-process. `0` leaves the global setting untouched (default:
    /// all available cores). `workers` controls per-model batching
    /// concurrency; this controls per-batch compute parallelism.
    ///
    /// Two deliberate consequences of "one global policy": (1) a
    /// non-zero value is applied with `set_threads` for the server's
    /// lifetime — the prior setting is snapshotted at start and restored
    /// when the handle shuts down, joins or drops, so a stopped server
    /// no longer leaks its width into later training runs; (2)
    /// concurrent dispatches from independent worker threads are not
    /// coordinated, so keep `workers × threads` within the machine's
    /// core budget when batches are large enough to dispatch (> 64
    /// rows).
    pub threads: usize,
    /// Optional bind address for the HTTP observability listener
    /// (`GET /metrics`, `/healthz`, `/varz`). `None` (the default)
    /// disables it; use port 0 for an ephemeral port (tests).
    pub metrics_addr: Option<String>,
    /// Deadline applied to predict requests that carry no
    /// `deadline_ms` of their own. `None` (the default) means such
    /// requests wait indefinitely, as before this knob existed.
    pub default_deadline: Option<Duration>,
    /// Socket read/write timeout per connection — the slowloris
    /// defense. A peer that stalls mid-line for longer than this gets
    /// its connection dropped. `None` disables; default 30s.
    pub io_timeout: Option<Duration>,
    /// Consecutive worker-side failures (panics or engine errors) that
    /// trip a model's circuit breaker into quarantine. 0 disables the
    /// breaker entirely. Default 8 — a healthy model never comes close,
    /// so serving output is unchanged unless a model is actually sick.
    pub breaker_threshold: u32,
    /// How long a tripped breaker stays fully open before admitting a
    /// single half-open probe request.
    pub breaker_cooldown: Duration,
    /// Persist per-model stats here on graceful shutdown and fold them
    /// back in at start ([`crate::serve::stats_io`]). `None` disables.
    pub stats_file: Option<PathBuf>,
    /// Additionally flush the stats file on this period while serving
    /// (`serve --stats-flush-secs`), so a SIGKILL loses at most one
    /// interval of counter history instead of the whole run. Each flush
    /// is an [`crate::util::fsio::atomic_write`] — a crash mid-flush
    /// leaves the previous snapshot intact. Requires `stats_file`;
    /// `None` (the default) keeps the shutdown-only behaviour.
    pub stats_flush: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 2,
            max_batch: 64,
            linger: Duration::from_millis(2),
            cache_capacity: 1024,
            cache_quant: 1e-9,
            max_queue: 1024,
            threads: 0,
            metrics_addr: None,
            default_deadline: None,
            io_timeout: Some(Duration::from_secs(30)),
            breaker_threshold: 8,
            breaker_cooldown: Duration::from_secs(1),
            stats_file: None,
            stats_flush: None,
        }
    }
}

impl ServeConfig {
    /// A builder seeded with the defaults; chain setters and finish with
    /// [`ServeConfigBuilder::build`], which validates the combination.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }
}

/// Fluent, validating constructor for [`ServeConfig`] — the only way
/// for downstream crates to set fields the struct gains later (the
/// config is `#[non_exhaustive]`).
///
/// ```
/// use bless::serve::ServeConfig;
/// use std::time::Duration;
/// let cfg = ServeConfig::builder()
///     .addr("127.0.0.1:0")
///     .workers(1)
///     .linger(Duration::from_millis(1))
///     .build()
///     .unwrap();
/// assert_eq!(cfg.workers, 1);
/// assert!(ServeConfig::builder().max_batch(0).build().is_err());
/// ```
#[derive(Clone, Debug)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    /// Bind address (port 0 for an ephemeral port).
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.addr = addr.into();
        self
    }

    /// Engine worker threads per model.
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    /// Largest coalesced batch per GEMM.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Straggler linger window per batch.
    pub fn linger(mut self, d: Duration) -> Self {
        self.cfg.linger = d;
        self
    }

    /// Prediction-cache capacity in entries per model (0 disables).
    pub fn cache_capacity(mut self, n: usize) -> Self {
        self.cfg.cache_capacity = n;
        self
    }

    /// Cache quantization step for query coordinates.
    pub fn cache_quant(mut self, q: f64) -> Self {
        self.cfg.cache_quant = q;
        self
    }

    /// Queue-depth cap per model (0 = unbounded).
    pub fn max_queue(mut self, n: usize) -> Self {
        self.cfg.max_queue = n;
        self
    }

    /// Process-wide compute-pool width (0 leaves the global setting).
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Bind address for the HTTP observability listener.
    pub fn metrics_addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.metrics_addr = Some(addr.into());
        self
    }

    /// Deadline for requests that carry no `deadline_ms` (None = wait
    /// indefinitely).
    pub fn default_deadline(mut self, d: Option<Duration>) -> Self {
        self.cfg.default_deadline = d;
        self
    }

    /// Socket read/write timeout per connection (None disables).
    pub fn io_timeout(mut self, d: Option<Duration>) -> Self {
        self.cfg.io_timeout = d;
        self
    }

    /// Consecutive worker failures that quarantine a model (0 disables
    /// the breaker).
    pub fn breaker_threshold(mut self, n: u32) -> Self {
        self.cfg.breaker_threshold = n;
        self
    }

    /// Open-state dwell time before a half-open probe.
    pub fn breaker_cooldown(mut self, d: Duration) -> Self {
        self.cfg.breaker_cooldown = d;
        self
    }

    /// Stats persistence file (save on shutdown, restore on start).
    pub fn stats_file(mut self, path: impl Into<PathBuf>) -> Self {
        self.cfg.stats_file = Some(path.into());
        self
    }

    /// Periodic stats-file flush interval (None = shutdown-only).
    pub fn stats_flush(mut self, d: Option<Duration>) -> Self {
        self.cfg.stats_flush = d;
        self
    }

    /// Validate the combination and hand back the config.
    pub fn build(self) -> anyhow::Result<ServeConfig> {
        let cfg = self.cfg;
        anyhow::ensure!(!cfg.addr.is_empty(), "addr must not be empty");
        anyhow::ensure!(cfg.workers >= 1, "workers must be at least 1");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
        anyhow::ensure!(
            cfg.cache_quant.is_finite() && cfg.cache_quant > 0.0,
            "cache_quant must be a positive finite number (got {})",
            cfg.cache_quant
        );
        if let Some(addr) = &cfg.metrics_addr {
            anyhow::ensure!(!addr.is_empty(), "metrics_addr must not be empty when set");
        }
        if let Some(d) = cfg.default_deadline {
            anyhow::ensure!(!d.is_zero(), "default_deadline must be positive when set");
        }
        if let Some(d) = cfg.io_timeout {
            anyhow::ensure!(!d.is_zero(), "io_timeout must be positive when set");
        }
        anyhow::ensure!(
            cfg.breaker_threshold == 0 || !cfg.breaker_cooldown.is_zero(),
            "breaker_cooldown must be positive when the breaker is enabled"
        );
        if let Some(d) = cfg.stats_flush {
            anyhow::ensure!(!d.is_zero(), "stats_flush must be positive when set");
            anyhow::ensure!(
                cfg.stats_file.is_some(),
                "stats_flush requires a stats_file to flush to"
            );
        }
        Ok(cfg)
    }
}

/// State shared by the accept loop, connection threads and workers.
struct Shared {
    registry: Registry,
    /// Errors not attributable to a model (parse failures, bad routes).
    conn_errors: AtomicU64,
    shutdown: AtomicBool,
    addr: SocketAddr,
    /// Per-model batching knobs, kept so `admin add` spawns worker
    /// pools identical to the ones started at boot.
    workers_per_model: usize,
    max_batch: usize,
    linger: Duration,
    /// Deadline for requests without their own `deadline_ms`.
    default_deadline: Option<Duration>,
    /// Per-connection socket read/write timeout.
    io_timeout: Option<Duration>,
    /// Engine worker threads — boot-time pools plus any spawned for
    /// dynamically added models; joined by [`ServerHandle`].
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn request_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return; // already shutting down
        }
        self.registry.close_all();
        // poke the accept loop so it re-checks the flag
        let _ = TcpStream::connect(self.addr);
    }

    /// Aggregate counters: every model plus the connection-level errors.
    fn aggregate_stats(&self) -> StatsSnapshot {
        let mut s = self.registry.aggregate_stats();
        s.errors += self.conn_errors.load(Ordering::Relaxed);
        s
    }
}

/// Bridges the serving registry into the scrape endpoints: `/metrics`
/// renders per-model counters and histograms (each series carries a
/// `model="…"` label) followed by the process-wide
/// [`crate::obs::metrics::global`] registry, `/varz` mirrors the same
/// data as JSON, and `/healthz` reports per-model readiness.
struct MetricsBridge {
    shared: Arc<Shared>,
}

impl MetricsProvider for MetricsBridge {
    fn metrics_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let entries = self.shared.registry.entries();
        type StatGetter = fn(&ModelStats) -> u64;
        let kinds: [(&str, StatGetter); 13] = [
            ("bless_serve_requests_total", |s| s.requests.load(Ordering::Relaxed)),
            ("bless_serve_batches_total", |s| s.batches.load(Ordering::Relaxed)),
            ("bless_serve_batched_total", |s| s.batched.load(Ordering::Relaxed)),
            ("bless_serve_cache_hits_total", |s| s.cache_hits.load(Ordering::Relaxed)),
            ("bless_serve_errors_total", |s| s.errors.load(Ordering::Relaxed)),
            ("bless_serve_shed_total", |s| s.shed.load(Ordering::Relaxed)),
            ("bless_serve_reloads_total", |s| s.reloads.load(Ordering::Relaxed)),
            ("bless_serve_deadline_exceeded_total", |s| {
                s.deadline_exceeded.load(Ordering::Relaxed)
            }),
            ("bless_serve_quarantined_total", |s| s.quarantined.load(Ordering::Relaxed)),
            ("bless_serve_worker_panics_total", |s| s.worker_panics.load(Ordering::Relaxed)),
            ("bless_serve_worker_respawns_total", |s| {
                s.worker_respawns.load(Ordering::Relaxed)
            }),
            ("bless_serve_promotions_total", |s| s.promotions.load(Ordering::Relaxed)),
            ("bless_serve_rollbacks_total", |s| s.rollbacks.load(Ordering::Relaxed)),
        ];
        for (name, get) in kinds {
            let _ = writeln!(out, "# TYPE {name} counter");
            for e in &entries {
                let model = escape_label(e.name());
                let _ = writeln!(out, "{name}{{model=\"{model}\"}} {}", get(&e.stats));
            }
        }
        let _ = writeln!(out, "# TYPE bless_serve_queue_depth gauge");
        for e in &entries {
            let model = escape_label(e.name());
            let depth = e.queue.len();
            let _ = writeln!(out, "bless_serve_queue_depth{{model=\"{model}\"}} {depth}");
        }
        let _ = writeln!(out, "# TYPE bless_serve_model_version gauge");
        for e in &entries {
            let model = escape_label(e.name());
            let v = e.version();
            let _ = writeln!(out, "bless_serve_model_version{{model=\"{model}\"}} {v}");
        }
        // 0 = closed, 1 = open (quarantined), 2 = half-open (probing)
        let _ = writeln!(out, "# TYPE bless_serve_breaker_state gauge");
        for e in &entries {
            let model = escape_label(e.name());
            let s = e.breaker.state_code();
            let _ = writeln!(out, "bless_serve_breaker_state{{model=\"{model}\"}} {s}");
        }
        let _ = writeln!(out, "# TYPE bless_serve_conn_errors_total counter");
        let _ = writeln!(
            out,
            "bless_serve_conn_errors_total {}",
            self.shared.conn_errors.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE bless_serve_latency_us histogram");
        for e in &entries {
            let label = format!("model=\"{}\"", escape_label(e.name()));
            e.stats
                .latency
                .snapshot()
                .render_prometheus("bless_serve_latency_us", &label, &mut out);
        }
        let _ = writeln!(out, "# TYPE bless_serve_batch_size histogram");
        for e in &entries {
            let label = format!("model=\"{}\"", escape_label(e.name()));
            e.stats
                .batch_sizes
                .snapshot()
                .render_prometheus("bless_serve_batch_size", &label, &mut out);
        }
        let pool = crate::util::pool::stats();
        let _ = writeln!(out, "# TYPE bless_pool_dispatches_total counter");
        let _ = writeln!(out, "bless_pool_dispatches_total {}", pool.dispatches);
        let _ = writeln!(out, "# TYPE bless_pool_inline_runs_total counter");
        let _ = writeln!(out, "bless_pool_inline_runs_total {}", pool.inline_runs);
        let _ = writeln!(out, "# TYPE bless_pool_blocks_run_total counter");
        let _ = writeln!(out, "bless_pool_blocks_run_total {}", pool.blocks_run);
        // training-side counters/histograms land in the global registry
        crate::obs::metrics::global().render_prometheus("bless_", &mut out);
        out
    }

    fn varz(&self) -> Json {
        let mut models = BTreeMap::new();
        for e in self.shared.registry.entries() {
            let s = e.stats.snapshot();
            let mut o = BTreeMap::new();
            o.insert("requests".to_string(), Json::Num(s.requests as f64));
            o.insert("cache_hits".to_string(), Json::Num(s.cache_hits as f64));
            o.insert("errors".to_string(), Json::Num(s.errors as f64));
            o.insert("shed".to_string(), Json::Num(s.shed as f64));
            o.insert("reloads".to_string(), Json::Num(s.reloads as f64));
            o.insert("promotions".to_string(), Json::Num(s.promotions as f64));
            o.insert("rollbacks".to_string(), Json::Num(s.rollbacks as f64));
            o.insert("latency_us".to_string(), Json::Num(s.latency_us as f64));
            o.insert("latency_p50_us".to_string(), Json::Num(s.latency_p50_us));
            o.insert("latency_p95_us".to_string(), Json::Num(s.latency_p95_us));
            o.insert("latency_p99_us".to_string(), Json::Num(s.latency_p99_us));
            o.insert("mean_batch".to_string(), Json::Num(s.mean_batch()));
            o.insert("batch_p95".to_string(), Json::Num(s.batch_p95));
            o.insert("queue_depth".to_string(), Json::Num(e.queue.len() as f64));
            o.insert("version".to_string(), Json::Num(e.version() as f64));
            models.insert(e.name().to_string(), Json::Obj(o));
        }
        let mut root = BTreeMap::new();
        root.insert("models".to_string(), Json::Obj(models));
        root.insert(
            "conn_errors".to_string(),
            Json::Num(self.shared.conn_errors.load(Ordering::Relaxed) as f64),
        );
        root.insert("registry".to_string(), crate::obs::metrics::global().varz());
        Json::Obj(root)
    }

    fn healthz(&self) -> (bool, Json) {
        let up = !self.shared.shutdown.load(Ordering::SeqCst);
        let mut all_ready = up;
        let mut models = BTreeMap::new();
        for e in self.shared.registry.entries() {
            // a quarantined (breaker-open) model degrades health even
            // while the rest of the fleet keeps serving
            let quarantined = e.breaker.is_open();
            let ready = up && !quarantined;
            all_ready &= ready;
            let mut o = BTreeMap::new();
            o.insert("ready".to_string(), Json::Bool(ready));
            o.insert("quarantined".to_string(), Json::Bool(quarantined));
            o.insert("version".to_string(), Json::Num(e.version() as f64));
            o.insert("m".to_string(), Json::Num(e.m() as f64));
            o.insert("d".to_string(), Json::Num(e.dim() as f64));
            models.insert(e.name().to_string(), Json::Obj(o));
        }
        let mut root = BTreeMap::new();
        root.insert("ok".to_string(), Json::Bool(all_ready));
        root.insert("models".to_string(), Json::Obj(models));
        (all_ready, Json::Obj(root))
    }
}

/// A running server; dropping (or calling [`shutdown`](Self::shutdown))
/// stops it and joins its threads.
pub struct ServerHandle {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    metrics: Option<HttpHandle>,
    /// Periodic stats flusher ([`ServeConfig::stats_flush`]); exits on
    /// the shutdown flag and is joined before the final stats save.
    flusher: Option<JoinHandle<()>>,
    /// The pool width configured before this server applied
    /// [`ServeConfig::threads`]; restored when the handle goes away.
    prev_threads: Option<usize>,
    /// Where to persist per-model stats once the workers have drained
    /// ([`ServeConfig::stats_file`]); taken on the first join.
    stats_file: Option<PathBuf>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// The `/metrics` listener's address, when one was configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics.as_ref().map(|m| m.addr())
    }

    /// Aggregate counters across all models.
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.aggregate_stats()
    }

    /// One model's counters (None for an unknown name).
    pub fn model_stats(&self, name: &str) -> Option<StatsSnapshot> {
        self.shared.registry.get(name).map(|e| e.stats.snapshot())
    }

    /// Registered model names.
    pub fn model_names(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    /// Handle to one model's live registry entry — the continuous-
    /// training tier ([`crate::lifecycle`]) retrains against this:
    /// promotion swaps its predictor, the probation watch reads its
    /// breaker, and rollback swaps the retained artifact back, all while
    /// the entry keeps serving.
    pub fn entry(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.shared.registry.get(name)
    }

    /// Whether a shutdown has been requested (locally or over the wire).
    pub fn is_shut_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain in-flight work and join all threads.
    pub fn shutdown(mut self) {
        self.shared.request_shutdown();
        self.join_threads();
    }

    /// Block until the server shuts down (e.g. a client sends
    /// `{"op":"shutdown"}`) — the `repro serve` foreground mode.
    pub fn join(mut self) {
        self.join_threads();
    }

    fn join_threads(&mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // take the handles out before joining: a connection thread
        // servicing `admin add` locks the same list to register new
        // workers, and must never find us holding it across a join
        let drained: Vec<_> = psync::lock(&self.shared.workers).drain(..).collect();
        for w in drained {
            let _ = w.join();
        }
        // the flusher exits on the shutdown flag; join it before the
        // final save so the two writers never interleave
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // workers are quiescent, so the snapshot is complete and stable;
        // atomic_write means a crash mid-save leaves the old file intact
        if let Some(path) = self.stats_file.take() {
            if let Err(e) = crate::serve::stats_io::save(&path, &self.shared.registry) {
                eprintln!("warning: {e}");
            }
        }
        // only after the prediction side is down: the foreground `join`
        // path must keep scrapes answering while the server runs
        if let Some(mut m) = self.metrics.take() {
            m.stop();
        }
        // hand the compute pool back the way we found it, so a stopped
        // server's `threads` setting does not leak into later training
        if let Some(prev) = self.prev_threads.take() {
            crate::util::pool::set_threads(prev);
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shared.request_shutdown();
        self.join_threads();
    }
}

/// Serve a single anonymous model (registered as `"default"`) — the
/// PR-1 entry point, now a thin wrapper over [`start_registry`].
pub fn start(artifact: ModelArtifact, cfg: &ServeConfig) -> anyhow::Result<ServerHandle> {
    start_registry(
        vec![ModelSpec { name: "default".to_string(), artifact, source: None }],
        cfg,
    )
}

/// Start serving a registry of named models with the given config.
/// Returns once the listener is bound and every worker pool is up.
pub fn start_registry(
    models: Vec<ModelSpec>,
    cfg: &ServeConfig,
) -> anyhow::Result<ServerHandle> {
    anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
    let prev_threads = (cfg.threads > 0).then(|| {
        // serve passes its compute budget to the shared pool so the
        // whole process runs one thread policy; snapshot the raw prior
        // setting (0 = "default") so shutdown can restore it exactly
        let prev = crate::util::pool::configured_threads();
        crate::util::pool::set_threads(cfg.threads);
        prev
    });
    let reg_cfg = RegistryConfig {
        cache_capacity: cfg.cache_capacity,
        cache_quant: cfg.cache_quant,
        max_queue: cfg.max_queue,
        breaker_threshold: cfg.breaker_threshold,
        breaker_cooldown: cfg.breaker_cooldown,
        ..RegistryConfig::default()
    };
    let registry = Registry::new(models, reg_cfg)?;
    // fold persisted counters/histograms back in before traffic starts,
    // so dashboards see one continuous run across restarts. A missing
    // file is first boot; a corrupt one fails the start loudly rather
    // than silently zeroing history.
    if let Some(path) = &cfg.stats_file {
        if path.exists() {
            crate::serve::stats_io::load(path, &registry)?;
        }
    }
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        registry,
        conn_errors: AtomicU64::new(0),
        shutdown: AtomicBool::new(false),
        addr,
        workers_per_model: cfg.workers.max(1),
        max_batch: cfg.max_batch,
        linger: cfg.linger,
        default_deadline: cfg.default_deadline,
        io_timeout: cfg.io_timeout,
        workers: Mutex::new(Vec::new()),
    });

    // bind the observability listener before spawning workers so a bad
    // metrics address fails the whole start cleanly
    let metrics = match &cfg.metrics_addr {
        Some(addr) => {
            let bridge = MetricsBridge { shared: Arc::clone(&shared) };
            Some(serve_http(addr, Arc::new(bridge))?)
        }
        None => None,
    };

    for entry in shared.registry.entries() {
        spawn_model_workers(&shared, &entry);
    }

    // periodic stats flusher: sleeps in short slices so shutdown is
    // never blocked behind a long interval, and each flush is an
    // atomic_write — a kill between flushes loses at most one interval
    let flusher = match (&cfg.stats_file, cfg.stats_flush) {
        (Some(path), Some(every)) => {
            let shared = Arc::clone(&shared);
            let path = path.clone();
            Some(std::thread::spawn(move || {
                let tick = every.min(Duration::from_millis(50));
                let mut since_flush = Duration::ZERO;
                while !shared.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    since_flush += tick;
                    if since_flush >= every {
                        since_flush = Duration::ZERO;
                        if let Err(e) = crate::serve::stats_io::save(&path, &shared.registry) {
                            eprintln!("warning: periodic stats flush failed: {e}");
                        }
                    }
                }
            }))
        }
        _ => None,
    };

    let accept_shared = Arc::clone(&shared);
    let accept = std::thread::spawn(move || accept_loop(listener, &accept_shared));
    Ok(ServerHandle {
        shared,
        accept: Some(accept),
        metrics,
        flusher,
        prev_threads,
        stats_file: cfg.stats_file.clone(),
    })
}

/// Spawn one model's engine worker pool and register the handles for
/// the eventual join — shared by boot and `admin add`.
fn spawn_model_workers(shared: &Shared, entry: &Arc<ModelEntry>) {
    let mut workers = psync::lock(&shared.workers);
    for _ in 0..shared.workers_per_model {
        let entry = Arc::clone(entry);
        let (max_batch, linger) = (shared.max_batch, shared.linger);
        workers.push(std::thread::spawn(move || {
            supervised_worker(&entry, max_batch, linger);
        }));
    }
}

/// The supervision loop a worker thread runs: each batch tick executes
/// under `catch_unwind`, so a panic (a model bug, a poisoned batch, or
/// the chaos harness's `worker.panic` point) is confined to the one
/// batch that hit it. The thread logs the panic against the model's
/// breaker and respawns its tick loop in place — the pool never
/// shrinks, and jobs caught mid-batch are answered by a drop guard.
fn supervised_worker(entry: &ModelEntry, max_batch: usize, linger: Duration) {
    loop {
        let tick = std::panic::catch_unwind(AssertUnwindSafe(|| {
            worker_tick(entry, max_batch, linger)
        }));
        match tick {
            Ok(true) => {}
            Ok(false) => return, // queue closed: graceful shutdown
            Err(_) => {
                entry.stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                entry.stats.worker_respawns.fetch_add(1, Ordering::Relaxed);
                entry.breaker.record_failure();
            }
        }
    }
}

/// Replies `Panicked` to every job still unanswered when dropped — the
/// worker's promise that a panic mid-batch never strands a client
/// blocked on `recv`. Jobs answered normally are drained out first.
struct PendingJobs(Vec<PredictJob>);

impl Drop for PendingJobs {
    fn drop(&mut self) {
        for job in self.0.drain(..) {
            let _ = job.reply.send(Err(JobError::Panicked));
        }
    }
}

/// One batch cycle; returns `false` when the queue has closed.
fn worker_tick(entry: &ModelEntry, max_batch: usize, linger: Duration) -> bool {
    let Some(batch) = entry.queue.pop_batch(max_batch, linger) else {
        return false;
    };
    if batch.is_empty() {
        return true;
    }
    // deadline enforcement happens here, at dequeue: a job that sat in
    // the queue past its deadline is answered without wasting a GEMM
    // slot on a result the client has already given up on
    let now = Instant::now();
    let (batch, expired): (Vec<_>, Vec<_>) =
        batch.into_iter().partition(|job| !job.expired(now));
    for job in expired {
        let _ = job.reply.send(Err(JobError::DeadlineExceeded));
    }
    // snapshot the predictor once per batch: a concurrent hot reload
    // swaps the entry's Arc but cannot invalidate this one
    let predictor = entry.predictor();
    let dim = predictor.dim();
    let (good, stale): (Vec<_>, Vec<_>) =
        batch.into_iter().partition(|job| job.x.len() == dim);
    for job in stale {
        // only possible when a reload changed the feature dimension
        // between enqueue-time validation and this batch
        let _ = job.reply.send(Err(JobError::Failed(
            "model was reloaded with a different dimension".to_string(),
        )));
    }
    if good.is_empty() {
        // every drained job was dropped before prediction; if one of
        // them held the half-open probe slot, no record_* call is
        // coming — hand the slot back so the breaker can't wedge
        entry.breaker.release_probe();
        return true;
    }
    entry.stats.batches.fetch_add(1, Ordering::Relaxed);
    entry.stats.batched.fetch_add(good.len() as u64, Ordering::Relaxed);
    if crate::obs::metrics::serve_recording() {
        entry.stats.batch_sizes.record(good.len() as u64);
    }
    // from here on a panic must answer the batch: move the jobs into
    // the drop guard before any engine work runs
    let mut pending = PendingJobs(good);
    if crate::faults::fire(crate::faults::FaultPoint::WorkerPanic) {
        panic!("injected worker.panic fault");
    }
    let q = Matrix::from_fn(pending.0.len(), dim, |i, j| pending.0[i].x[j]);
    let result = if crate::faults::fire(crate::faults::FaultPoint::EngineError) {
        Err(anyhow::anyhow!("injected engine.error fault"))
    } else {
        predictor.predict_batch(&q)
    };
    match result {
        // a short score vector would let zip silently drop the surplus
        // jobs (clients would see a misleading disconnect), so treat a
        // row/score count mismatch as an engine failure for the batch
        Ok(scores) if scores.len() == pending.0.len() => {
            for (job, &score) in pending.0.drain(..).zip(&scores) {
                // a disconnected client is not a worker error
                let _ = job.reply.send(Ok(score));
            }
            entry.breaker.record_success();
        }
        Ok(scores) => {
            let msg = format!(
                "engine returned {} scores for a batch of {} rows",
                scores.len(),
                pending.0.len()
            );
            for job in pending.0.drain(..) {
                let _ = job.reply.send(Err(JobError::Failed(msg.clone())));
            }
            entry.breaker.record_failure();
        }
        Err(e) => {
            let msg = e.to_string();
            for job in pending.0.drain(..) {
                let _ = job.reply.send(Err(JobError::Failed(msg.clone())));
            }
            entry.breaker.record_failure();
        }
    }
    true
}

fn accept_loop(listener: TcpListener, shared: &Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(stream) => {
                let shared = Arc::clone(shared);
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &shared);
                });
            }
            Err(_) => continue,
        }
    }
}

fn handle_conn(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    // slowloris defense: a peer that stalls mid-line (or never reads its
    // reply) times the socket out instead of pinning this thread forever
    stream.set_read_timeout(shared.io_timeout)?;
    stream.set_write_timeout(shared.io_timeout)?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // chaos-harness connection faults (no-ops unless armed): a
        // stalled peer, a peer that vanishes mid-request, and a reply
        // cut off mid-line — every client must survive all three
        if crate::faults::is_active() {
            if let Some(stall) = crate::faults::delay(crate::faults::FaultPoint::ConnDelay) {
                std::thread::sleep(stall);
            }
            if crate::faults::fire(crate::faults::FaultPoint::ConnDrop) {
                return Ok(());
            }
            if crate::faults::fire(crate::faults::FaultPoint::ConnTruncate) {
                let response = dispatch_line(&line, shared, &mut writer)?;
                if let Some(response) = response {
                    let cut = response.len() / 2;
                    writer.write_all(&response.as_bytes()[..cut])?;
                    writer.flush()?;
                }
                return Ok(());
            }
        }
        match dispatch_line(&line, shared, &mut writer)? {
            Some(response) => {
                writeln!(writer, "{response}")?;
                writer.flush()?;
            }
            None => return Ok(()), // shutdown acked inside dispatch
        }
    }
    Ok(())
}

/// Parse and execute one request line; returns the reply to write, or
/// `None` when the line was a shutdown (already acked, connection done).
fn dispatch_line(
    line: &str,
    shared: &Shared,
    writer: &mut BufWriter<TcpStream>,
) -> std::io::Result<Option<String>> {
    let response = match Request::parse(line) {
            Err(e) => {
                shared.conn_errors.fetch_add(1, Ordering::Relaxed);
                protocol::error_response(None, "bad_request", &e.to_string())
            }
            Ok(Request::Ping) => protocol::ok_response(),
            Ok(Request::Stats { model }) => handle_stats(shared, model.as_deref()),
            Ok(Request::Admin(admin)) => match admin {
                AdminRequest::List => admin_list_response(shared),
                AdminRequest::Reload { model, path } => {
                    handle_reload(shared, &model, path.as_deref())
                }
                AdminRequest::Add { model, path } => handle_add(shared, &model, &path),
                AdminRequest::Remove { model } => handle_remove(shared, &model),
                // stats sugar never parses as an admin op, but the typed
                // enum admits it — answer it the same as the stats verb
                AdminRequest::Stats { model } => handle_stats(shared, model.as_deref()),
            },
            Ok(Request::Shutdown) => {
                // flip the flag before acking so a client that saw the
                // ack observes is_shut_down() == true
                shared.request_shutdown();
                writeln!(writer, "{}", protocol::ok_response())?;
                writer.flush()?;
                return Ok(None);
            }
            Ok(Request::Predict { id, model, x, deadline_ms }) => {
                handle_predict(shared, id, model.as_deref(), x, deadline_ms)
            }
        };
    Ok(Some(response))
}

fn handle_stats(shared: &Shared, model: Option<&str>) -> String {
    match model {
        None => shared.aggregate_stats().to_line(),
        Some(name) => match shared.registry.get(name) {
            Some(entry) => entry.stats.snapshot().to_line(),
            None => {
                shared.conn_errors.fetch_add(1, Ordering::Relaxed);
                protocol::error_response(None, "unknown_model", &format!("unknown model {name:?}"))
            }
        },
    }
}

fn admin_list_response(shared: &Shared) -> String {
    let models: Vec<ModelInfo> = shared
        .registry
        .entries()
        .iter()
        .map(|entry| {
            let stats = entry.stats.snapshot();
            ModelInfo {
                name: entry.name().to_string(),
                m: entry.m(),
                d: entry.dim(),
                version: entry.version(),
                requests: stats.requests,
                shed: stats.shed,
            }
        })
        .collect();
    AdminResponse::Models(models).to_line()
}

fn handle_reload(shared: &Shared, model: &str, path: Option<&str>) -> String {
    let entry = match shared.registry.get(model) {
        Some(e) => e,
        None => {
            shared.conn_errors.fetch_add(1, Ordering::Relaxed);
            let loaded = shared.registry.names().join(", ");
            return protocol::error_response(
                None,
                "unknown_model",
                &format!("unknown model {model:?} (loaded: {loaded})"),
            );
        }
    };
    match entry.reload(path.map(std::path::Path::new)) {
        Ok((m, d, version)) => {
            AdminResponse::Swapped { model: model.to_string(), m, d, version }.to_line()
        }
        Err(e) => {
            shared.conn_errors.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(None, "reload_failed", &e.to_string())
        }
    }
}

fn handle_add(shared: &Shared, model: &str, path: &str) -> String {
    let artifact = match ModelArtifact::load(path) {
        Ok(a) => a,
        Err(e) => {
            shared.conn_errors.fetch_add(1, Ordering::Relaxed);
            return protocol::error_response(None, "add_failed", &e.to_string());
        }
    };
    let spec = ModelSpec {
        name: model.to_string(),
        artifact,
        source: Some(PathBuf::from(path)),
    };
    match shared.registry.add(spec) {
        Ok(entry) => {
            // a shutdown racing in between add and here closes the new
            // entry's queue via close_all, so these workers exit at once
            spawn_model_workers(shared, &entry);
            AdminResponse::Swapped {
                model: model.to_string(),
                m: entry.m(),
                d: entry.dim(),
                version: entry.version(),
            }
            .to_line()
        }
        Err(e) => {
            shared.conn_errors.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(None, "add_failed", &e.to_string())
        }
    }
}

fn handle_remove(shared: &Shared, model: &str) -> String {
    match shared.registry.remove(model) {
        Ok(_entry) => AdminResponse::Removed { model: model.to_string() }.to_line(),
        Err(e) => {
            shared.conn_errors.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(None, "unknown_model", &e.to_string())
        }
    }
}

fn handle_predict(
    shared: &Shared,
    id: u64,
    model: Option<&str>,
    x: Vec<f64>,
    deadline_ms: Option<u64>,
) -> String {
    let t0 = Instant::now();
    let entry = match shared.registry.resolve(model) {
        Ok(e) => e,
        Err(e) => {
            shared.conn_errors.fetch_add(1, Ordering::Relaxed);
            return protocol::error_response(Some(id), "unknown_model", &e.to_string());
        }
    };
    entry.stats.requests.fetch_add(1, Ordering::Relaxed);
    // the request's own deadline wins; otherwise the server default
    let budget = deadline_ms.map(Duration::from_millis).or(shared.default_deadline);
    let deadline = budget.map(|b| t0 + b);
    // breaker check up front: a quarantined model answers immediately
    // instead of queueing work its sick engine will only fail again.
    // A Probe admission carries an obligation: if this request exits
    // before a worker predicts it (cache hit, bad dims, shed, closed),
    // it must hand the slot back or the breaker wedges half-open.
    let is_probe = match entry.breaker.admit() {
        Admission::Allowed => false,
        Admission::Probe => true,
        Admission::Quarantined => {
            entry.stats.quarantined.fetch_add(1, Ordering::Relaxed);
            return protocol::error_response(
                Some(id),
                "quarantined",
                &format!(
                    "model {:?} is quarantined after repeated worker failures; retry later",
                    entry.name()
                ),
            );
        }
    };
    let dim = entry.dim();
    if x.len() != dim {
        if is_probe {
            entry.breaker.release_probe();
        }
        entry.stats.errors.fetch_add(1, Ordering::Relaxed);
        return protocol::error_response(
            Some(id),
            "bad_request",
            &format!("query dimension {} != model dimension {dim}", x.len()),
        );
    }

    let pending = match entry.cache_probe(&x) {
        CacheProbe::Hit(y) => {
            // a cached score says nothing about the engine's health, so
            // this is a release, not a success: the next miss probes
            if is_probe {
                entry.breaker.release_probe();
            }
            entry.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            bump_latency(entry, t0);
            return protocol::predict_response(id, y, true);
        }
        CacheProbe::Miss(pending) => pending,
    };

    let (tx, rx) = mpsc::channel();
    match entry.enqueue(PredictJob { x, reply: tx, deadline }) {
        Push::Accepted => {}
        Push::Full => {
            if is_probe {
                entry.breaker.release_probe();
            }
            entry.stats.shed.fetch_add(1, Ordering::Relaxed);
            return protocol::error_response(
                Some(id),
                "overloaded",
                &format!(
                    "model {:?} queue is full ({} pending); retry later",
                    entry.name(),
                    entry.max_queue()
                ),
            );
        }
        Push::Closed => {
            if is_probe {
                entry.breaker.release_probe();
            }
            entry.stats.errors.fetch_add(1, Ordering::Relaxed);
            return protocol::error_response(Some(id), "shutting_down", "server is shutting down");
        }
    }
    // with a deadline, don't out-wait it on the channel either: the
    // worker may be mid-GEMM on an earlier batch when time runs out
    let received = match deadline {
        None => rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected),
        Some(d) => rx.recv_timeout(d.saturating_duration_since(Instant::now())),
    };
    match received {
        Ok(Ok(y)) => {
            if let Some((key, version)) = pending {
                entry.cache_insert(key, version, y);
            }
            bump_latency(entry, t0);
            protocol::predict_response(id, y, false)
        }
        Ok(Err(err)) => {
            // deadline misses are their own counter (the request was
            // well-formed and the engine healthy — time just ran out);
            // everything else is a model error
            if matches!(err, JobError::DeadlineExceeded) {
                entry.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            } else {
                entry.stats.errors.fetch_add(1, Ordering::Relaxed);
            }
            protocol::error_response(Some(id), err.code(), &err.message())
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            entry.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(
                Some(id),
                "deadline_exceeded",
                &format!("deadline of {}ms elapsed before a result", budget.unwrap().as_millis()),
            )
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            entry.stats.errors.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(
                Some(id),
                "shutting_down",
                "prediction failed (server stopping?)",
            )
        }
    }
}

fn bump_latency(entry: &ModelEntry, t0: Instant) {
    // gated so `benches/obs_overhead.rs` can compare recording on/off;
    // the histogram's exact sum feeds the wire `latency_us` counter
    if crate::obs::metrics::serve_recording() {
        let us = t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
        entry.stats.latency.record(us);
    }
}

/// Backoff policy for [`Client::predict_with_retry`]: transient replies
/// are retried after a jittered exponential delay, so a fleet of
/// clients hitting a saturated queue spreads out instead of hammering
/// it in lockstep. Two backoff classes:
///
/// * **fast** (`overloaded`, `deadline_exceeded`) — momentary pressure;
///   the ladder starts at [`base`](Self::base).
/// * **slow** (`quarantined`) — the model's circuit breaker is open and
///   will not even probe until its cooldown elapses, so retrying on the
///   fast ladder only burns attempts. The ladder is floored at
///   [`quarantine_base`](Self::quarantine_base) instead.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = plain `predict`).
    pub max_retries: u32,
    /// Delay before the first retry; doubles every retry.
    pub base: Duration,
    /// Cap on any single delay.
    pub max_delay: Duration,
    /// Seed for the jitter stream; mixed with the request id so
    /// concurrent requests de-correlate while staying reproducible.
    pub seed: u64,
    /// Wall-clock cap across *all* attempts and backoff sleeps: once
    /// spent, retrying stops even with `max_retries` left. `None` (the
    /// default) bounds by attempt count alone.
    pub budget: Option<Duration>,
    /// Floor on the backoff delay after a `quarantined` reply — sized
    /// to the server's breaker cooldown (default 250ms), since nothing
    /// can succeed before the half-open probe is admitted.
    pub quarantine_base: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 8,
            base: Duration::from_millis(1),
            max_delay: Duration::from_millis(200),
            seed: 0x5eed,
            budget: None,
            quarantine_base: Duration::from_millis(250),
        }
    }
}

/// The typed error [`Client::predict_with_retry`] returns when every
/// attempt failed transiently: distinguishable (via `downcast_ref`)
/// from a hard server error, and it carries what the caller needs to
/// decide between escalating and giving up.
#[derive(Debug)]
pub struct RetryExhausted {
    /// Attempts made (the first try plus each retry).
    pub attempts: u32,
    /// Wall-clock spent across attempts and backoff sleeps.
    pub elapsed: Duration,
    /// The transient error from the final attempt.
    pub last_error: String,
    /// The wire error code that exhausted the budget (`overloaded`,
    /// `deadline_exceeded` or `quarantined`) — callers branch on this:
    /// an exhausted `quarantined` means the model is sick, not busy.
    pub code: String,
}

impl std::fmt::Display for RetryExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "retry budget exhausted by [{}] after {} attempts over {:?}: {}",
            self.code, self.attempts, self.elapsed, self.last_error
        )
    }
}

impl std::error::Error for RetryExhausted {}

/// Extract the bracketed wire code from a client-side error string
/// (`"server error [overloaded]: …"` → `"overloaded"`).
fn error_code(message: &str) -> &str {
    message
        .split_once('[')
        .and_then(|(_, rest)| rest.split_once(']'))
        .map(|(code, _)| code)
        .unwrap_or("unknown")
}

/// A minimal blocking client for the line protocol — used by the CLI,
/// the integration tests and the `serve_roundtrip` example.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect to a running server.
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    fn round_trip(&mut self, line: &str) -> anyhow::Result<String> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(buf.trim_end().to_string())
    }

    fn predict_req(&mut self, req: Request, id: u64) -> anyhow::Result<(f64, bool)> {
        let line = self.round_trip(&req.to_line())?;
        let (rid, y, cached) = protocol::parse_predict_response(&line)?;
        anyhow::ensure!(rid == id, "response id {rid} != request id {id}");
        Ok((y, cached))
    }

    /// Score one query point against the only loaded model; returns
    /// `(score, served_from_cache)`.
    pub fn predict(&mut self, id: u64, x: &[f64]) -> anyhow::Result<(f64, bool)> {
        self.predict_req(
            Request::Predict { id, model: None, x: x.to_vec(), deadline_ms: None },
            id,
        )
    }

    /// Like [`predict`](Self::predict) but carries a per-request
    /// deadline: the server answers `deadline_exceeded` instead of
    /// letting the request wait longer than `deadline_ms`.
    pub fn predict_within(
        &mut self,
        id: u64,
        x: &[f64],
        deadline_ms: u64,
    ) -> anyhow::Result<(f64, bool)> {
        self.predict_req(
            Request::Predict {
                id,
                model: None,
                x: x.to_vec(),
                deadline_ms: Some(deadline_ms),
            },
            id,
        )
    }

    /// Like [`predict`](Self::predict) but retries transient replies —
    /// `overloaded` sheds, `deadline_exceeded` misses and `quarantined`
    /// refusals — under `policy` (jittered exponential backoff, optional
    /// wall-clock budget). A `quarantined` reply switches to the slow
    /// backoff class ([`RetryPolicy::quarantine_base`]): the breaker
    /// will not admit anything before its cooldown, so fast retries
    /// would only burn the attempt budget. Hard errors return as-is;
    /// exhausting the retry budget returns a typed [`RetryExhausted`]
    /// (carrying the exhausting wire code) the caller can
    /// `downcast_ref`.
    pub fn predict_with_retry(
        &mut self,
        id: u64,
        x: &[f64],
        policy: &RetryPolicy,
    ) -> anyhow::Result<(f64, bool)> {
        fn transient(e: &anyhow::Error) -> bool {
            let s = e.to_string();
            s.contains("[overloaded]")
                || s.contains("[deadline_exceeded]")
                || s.contains("[quarantined]")
        }
        let t0 = Instant::now();
        let mut rng = crate::rng::Rng::seeded(policy.seed ^ id);
        let mut delay = policy.base;
        let mut attempts = 0u32;
        let mut last_error;
        loop {
            attempts += 1;
            match self.predict(id, x) {
                Err(e) if transient(&e) => last_error = e.to_string(),
                other => return other,
            }
            let quarantined = last_error.contains("[quarantined]");
            // the budget is a wall-clock ceiling on the whole call, so
            // the backoff sleep must fit inside what remains of it —
            // and a spent budget ends the loop before sleeping at all
            let remaining = policy.budget.map(|b| b.saturating_sub(t0.elapsed()));
            if attempts > policy.max_retries || remaining == Some(Duration::ZERO) {
                let code = error_code(&last_error).to_string();
                return Err(anyhow::Error::new(RetryExhausted {
                    attempts,
                    elapsed: t0.elapsed(),
                    last_error,
                    code,
                }));
            }
            // quarantine floors the ladder at the breaker-cooldown
            // scale — and lifts the cap to match, since max_delay is
            // usually tuned for the fast (overloaded) class
            let cap = if quarantined {
                delay = delay.max(policy.quarantine_base);
                policy.max_delay.max(policy.quarantine_base)
            } else {
                policy.max_delay
            };
            // "equal jitter": sleep a uniform fraction of
            // [delay/2, delay) so retry waves decohere
            let frac = 0.5 + 0.5 * (rng.below(1_000) as f64 / 1_000.0);
            let mut sleep = delay.mul_f64(frac).min(cap);
            if let Some(r) = remaining {
                sleep = sleep.min(r);
            }
            std::thread::sleep(sleep);
            delay = (delay * 2).min(cap);
        }
    }

    /// Score one query point against a named model.
    pub fn predict_on(
        &mut self,
        model: &str,
        id: u64,
        x: &[f64],
    ) -> anyhow::Result<(f64, bool)> {
        self.predict_req(
            Request::Predict {
                id,
                model: Some(model.to_string()),
                x: x.to_vec(),
                deadline_ms: None,
            },
            id,
        )
    }

    /// Send any typed [`AdminRequest`] and get the matching
    /// [`AdminResponse`] back — the single entry point every
    /// administrative convenience method below routes through.
    pub fn admin(&mut self, req: AdminRequest) -> anyhow::Result<AdminResponse> {
        let line = self.round_trip(&Request::from(req.clone()).to_line())?;
        AdminResponse::parse_for(&req, &line)
    }

    /// Fetch aggregate server counters.
    pub fn stats(&mut self) -> anyhow::Result<StatsSnapshot> {
        match self.admin(AdminRequest::Stats { model: None })? {
            AdminResponse::Stats(s) => Ok(s),
            other => anyhow::bail!("unexpected stats response: {other:?}"),
        }
    }

    /// Fetch one model's counters.
    pub fn stats_for(&mut self, model: &str) -> anyhow::Result<StatsSnapshot> {
        match self.admin(AdminRequest::Stats { model: Some(model.to_string()) })? {
            AdminResponse::Stats(s) => Ok(s),
            other => anyhow::bail!("unexpected stats response: {other:?}"),
        }
    }

    /// Hot-reload a model (optionally from a new artifact path); returns
    /// the model's new version counter.
    pub fn admin_reload(&mut self, model: &str, path: Option<&str>) -> anyhow::Result<u64> {
        let req = AdminRequest::Reload {
            model: model.to_string(),
            path: path.map(str::to_string),
        };
        match self.admin(req)? {
            AdminResponse::Swapped { version, .. } => Ok(version),
            other => anyhow::bail!("unexpected reload response: {other:?}"),
        }
    }

    /// List loaded model names (sorted). For shapes, versions and
    /// traffic counters, send [`AdminRequest::List`] through
    /// [`admin`](Self::admin) and read the [`ModelInfo`] rows.
    pub fn admin_list(&mut self) -> anyhow::Result<Vec<String>> {
        match self.admin(AdminRequest::List)? {
            AdminResponse::Models(infos) => Ok(infos.into_iter().map(|i| i.name).collect()),
            other => anyhow::bail!("unexpected list response: {other:?}"),
        }
    }

    /// Register a new model from an artifact on the server's disk;
    /// returns its `(centers, dimension)` shape.
    pub fn admin_add(&mut self, model: &str, path: &str) -> anyhow::Result<(usize, usize)> {
        let req = AdminRequest::Add { model: model.to_string(), path: path.to_string() };
        match self.admin(req)? {
            AdminResponse::Swapped { m, d, .. } => Ok((m, d)),
            other => anyhow::bail!("unexpected add response: {other:?}"),
        }
    }

    /// Unregister a model; in-flight work drains, new requests for the
    /// name get `unknown_model`.
    pub fn admin_remove(&mut self, model: &str) -> anyhow::Result<()> {
        match self.admin(AdminRequest::Remove { model: model.to_string() })? {
            AdminResponse::Removed { .. } => Ok(()),
            other => anyhow::bail!("unexpected remove response: {other:?}"),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> anyhow::Result<()> {
        let line = self.round_trip(&Request::Ping.to_line())?;
        anyhow::ensure!(line.contains("\"ok\""), "unexpected ping response: {line}");
        Ok(())
    }

    /// Ask the server to shut down gracefully.
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        let line = self.round_trip(&Request::Shutdown.to_line())?;
        anyhow::ensure!(line.contains("\"ok\""), "unexpected shutdown response: {line}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_artifact() -> ModelArtifact {
        ModelArtifact {
            sigma: 1.0,
            centers: Matrix::from_vec(3, 2, vec![0.0, 0.0, 1.0, 0.5, -0.5, 1.0]),
            alpha: vec![0.5, -0.25, 1.0],
            trained_n: 3,
            dataset: "tiny".to_string(),
        }
    }

    fn scaled_artifact(scale: f64) -> ModelArtifact {
        let mut art = tiny_artifact();
        for a in &mut art.alpha {
            *a *= scale;
        }
        art
    }

    fn test_config() -> ServeConfig {
        ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(1))
            .build()
            .unwrap()
    }

    use crate::serve::model_store::Predictor;

    #[test]
    fn serves_predictions_matching_direct_predictor() {
        let art = tiny_artifact();
        let direct = Predictor::new(&art);
        let handle = start(art, &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.ping().unwrap();
        for (i, q) in [[0.2, 0.1], [1.0, 0.5], [-3.0, 2.0]].iter().enumerate() {
            let (y, cached) = client.predict(i as u64, q).unwrap();
            assert!(!cached);
            let want = direct.predict_one(q).unwrap();
            assert!((y - want).abs() < 1e-12, "served {y} vs direct {want}");
        }
        let stats = handle.stats();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 0);
        handle.shutdown();
    }

    #[test]
    fn repeated_query_hits_the_cache() {
        let art = tiny_artifact();
        let handle = start(art, &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let q = [0.4, -0.6];
        let (y1, c1) = client.predict(1, &q).unwrap();
        let (y2, c2) = client.predict(2, &q).unwrap();
        assert!(!c1);
        assert!(c2, "second identical query should be served from cache");
        assert_eq!(y1.to_bits(), y2.to_bits());
        assert_eq!(handle.stats().cache_hits, 1);
        handle.shutdown();
    }

    #[test]
    fn bad_requests_get_error_lines_and_are_counted() {
        let handle = start(tiny_artifact(), &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        // wrong dimension
        assert!(client.predict(1, &[1.0, 2.0, 3.0]).is_err());
        // raw garbage line
        let resp = client.round_trip("this is not json").unwrap();
        assert!(resp.contains("\"error\""), "got {resp}");
        assert!(resp.contains("bad_request"), "got {resp}");
        // connection still usable afterwards
        client.ping().unwrap();
        assert_eq!(handle.stats().errors, 2);
        handle.shutdown();
    }

    #[test]
    fn wire_shutdown_unblocks_join() {
        let handle = start(tiny_artifact(), &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.shutdown().unwrap();
        assert!(handle.is_shut_down());
        handle.join(); // returns because the client stopped the server
    }

    #[test]
    fn two_models_route_by_name_and_admin_lists_them() {
        let specs = vec![
            ModelSpec { name: "one".to_string(), artifact: tiny_artifact(), source: None },
            ModelSpec { name: "two".to_string(), artifact: scaled_artifact(2.0), source: None },
        ];
        let handle = start_registry(specs, &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();

        let q = [0.3, -0.4];
        let (y1, _) = client.predict_on("one", 1, &q).unwrap();
        let (y2, _) = client.predict_on("two", 2, &q).unwrap();
        assert!((y2 - 2.0 * y1).abs() < 1e-12, "scaled model should double: {y1} vs {y2}");

        // nameless predict is ambiguous with two models
        let err = client.predict(3, &q).unwrap_err().to_string();
        assert!(err.contains("model"), "got {err}");
        // unknown name is a structured error
        let err = client.predict_on("nope", 4, &q).unwrap_err().to_string();
        assert!(err.contains("[unknown_model]"), "got {err}");

        assert_eq!(client.admin_list().unwrap(), vec!["one".to_string(), "two".to_string()]);
        // per-model stats counted the routed traffic
        assert_eq!(client.stats_for("one").unwrap().requests, 1);
        assert_eq!(client.stats_for("two").unwrap().requests, 1);
        handle.shutdown();
    }

    #[test]
    fn wire_reload_swaps_predictions_and_bumps_version() {
        let path = std::env::temp_dir()
            .join(format!("bless-server-reload-{}.bin", std::process::id()));
        scaled_artifact(4.0).save(&path).unwrap();

        let handle = start(tiny_artifact(), &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let q = [0.25, 0.75];
        let (before, _) = client.predict(1, &q).unwrap();
        let version = client.admin_reload("default", path.to_str()).unwrap();
        assert_eq!(version, 2);
        let (after, cached) = client.predict(2, &q).unwrap();
        assert!(!cached, "reload must clear the cache");
        assert!(
            (after - 4.0 * before).abs() < 1e-12,
            "reloaded α×4 should quadruple: {before} → {after}"
        );
        assert_eq!(client.stats().unwrap().reloads, 1);
        // reloading an unknown model fails cleanly
        let err = client.admin_reload("nope", None).unwrap_err().to_string();
        assert!(err.contains("unknown_model"), "got {err}");
        std::fs::remove_file(&path).ok();
        handle.shutdown();
    }

    #[test]
    fn queue_cap_sheds_with_overloaded_error() {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(800))
            .cache_capacity(0)
            .max_queue(1)
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        let addr = handle.addr();

        // first request sits in the queue through the worker's linger
        // window; the second arrives while the depth cap is reached
        let blocker = std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.predict(1, &[0.1, 0.2]).unwrap()
        });
        // deterministic sync: wait until the blocker's job is actually
        // queued (depth cap reached) instead of racing a sleep
        let queue_len =
            || handle.shared.registry.get("default").unwrap().queue.len();
        let t0 = Instant::now();
        while queue_len() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "blocker never enqueued");
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut client = Client::connect(addr).unwrap();
        let err = client.predict(2, &[0.3, 0.4]).unwrap_err().to_string();
        assert!(err.contains("[overloaded]"), "got {err}");

        // the in-flight request still completes successfully
        let (y, _) = blocker.join().unwrap();
        assert!(y.is_finite());
        let stats = handle.stats();
        assert_eq!(stats.shed, 1);
        assert_eq!(stats.errors, 0, "shed load is not an error");
        assert_eq!(stats.requests, 2);
        handle.shutdown();
    }

    #[test]
    fn shed_requests_eventually_succeed_with_retry() {
        // same saturation setup as queue_cap_sheds…, but the second
        // client retries with backoff instead of giving up
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(100))
            .cache_capacity(0)
            .max_queue(1)
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        let addr = handle.addr();

        let blocker = std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.predict(1, &[0.1, 0.2]).unwrap()
        });
        let queue_len = || handle.shared.registry.get("default").unwrap().queue.len();
        let t0 = Instant::now();
        while queue_len() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "blocker never enqueued");
            std::thread::sleep(Duration::from_millis(2));
        }

        let mut client = Client::connect(addr).unwrap();
        let policy = RetryPolicy {
            max_retries: 50,
            base: Duration::from_millis(5),
            ..RetryPolicy::default()
        };
        let (y, _) = client.predict_with_retry(2, &[0.3, 0.4], &policy).unwrap();
        assert!(y.is_finite());
        let (y1, _) = blocker.join().unwrap();
        assert!(y1.is_finite());

        let stats = handle.stats();
        assert!(stats.shed >= 1, "the retried request must actually have been shed first");
        assert_eq!(stats.errors, 0);
        handle.shutdown();
    }

    #[test]
    fn builder_validates_and_rejects_bad_combinations() {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(3)
            .max_batch(16)
            .linger(Duration::from_millis(5))
            .cache_capacity(64)
            .cache_quant(1e-6)
            .max_queue(32)
            .threads(2)
            .metrics_addr("127.0.0.1:0")
            .build()
            .unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.metrics_addr.as_deref(), Some("127.0.0.1:0"));

        assert!(ServeConfig::builder().addr("").build().is_err());
        assert!(ServeConfig::builder().workers(0).build().is_err());
        assert!(ServeConfig::builder().max_batch(0).build().is_err());
        assert!(ServeConfig::builder().cache_quant(0.0).build().is_err());
        assert!(ServeConfig::builder().cache_quant(f64::NAN).build().is_err());
        assert!(ServeConfig::builder().metrics_addr("").build().is_err());

        // robustness knobs: defaults are timeout-on/breaker-on, zeros
        // are rejected where they would mean "instantly expired"
        let cfg = ServeConfig::builder()
            .default_deadline(Some(Duration::from_millis(50)))
            .io_timeout(Some(Duration::from_secs(5)))
            .breaker_threshold(3)
            .breaker_cooldown(Duration::from_millis(100))
            .stats_file("/tmp/stats.json")
            .build()
            .unwrap();
        assert_eq!(cfg.default_deadline, Some(Duration::from_millis(50)));
        assert_eq!(cfg.breaker_threshold, 3);
        assert!(cfg.stats_file.is_some());
        let defaults = ServeConfig::default();
        assert_eq!(defaults.io_timeout, Some(Duration::from_secs(30)));
        assert_eq!(defaults.breaker_threshold, 8);
        assert!(ServeConfig::builder()
            .default_deadline(Some(Duration::ZERO))
            .build()
            .is_err());
        assert!(ServeConfig::builder().io_timeout(Some(Duration::ZERO)).build().is_err());
        assert!(ServeConfig::builder()
            .breaker_threshold(1)
            .breaker_cooldown(Duration::ZERO)
            .build()
            .is_err());
    }

    #[test]
    fn shutdown_restores_the_prior_pool_width() {
        let _g = crate::util::pool::CONFIG_TEST_LOCK.lock().unwrap();
        crate::util::pool::set_threads(11);
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(1))
            .threads(7)
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        assert_eq!(crate::util::pool::threads(), 7, "server applies its width while up");
        handle.shutdown();
        assert_eq!(
            crate::util::pool::configured_threads(),
            11,
            "shutdown must restore the pre-server pool width"
        );

        // threads == 0 leaves the global setting alone in both directions
        let handle = start(tiny_artifact(), &test_config()).unwrap();
        assert_eq!(crate::util::pool::threads(), 11);
        handle.shutdown();
        assert_eq!(crate::util::pool::configured_threads(), 11);
        crate::util::pool::set_threads(0);
    }

    #[test]
    fn admin_add_and_remove_grow_and_shrink_the_registry() {
        let path =
            std::env::temp_dir().join(format!("bless-server-add-{}.bin", std::process::id()));
        scaled_artifact(2.0).save(&path).unwrap();

        let handle = start(tiny_artifact(), &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let q = [0.2, 0.1];
        let (base, _) = client.predict_on("default", 1, &q).unwrap();

        let (m, d) = client.admin_add("doubled", path.to_str().unwrap()).unwrap();
        assert_eq!((m, d), (3, 2));
        assert_eq!(
            client.admin_list().unwrap(),
            vec!["default".to_string(), "doubled".to_string()]
        );
        // the added model serves through its own freshly spawned workers
        let (y, _) = client.predict_on("doubled", 2, &q).unwrap();
        assert!((y - 2.0 * base).abs() < 1e-12, "added α×2 model: {base} → {y}");

        // duplicate adds and bad paths are structured errors
        let err = client.admin_add("doubled", path.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("[add_failed]"), "got {err}");
        let err = client.admin_add("ghost", "/nonexistent.bin").unwrap_err();
        assert!(err.to_string().contains("[add_failed]"), "got {err}");

        client.admin_remove("doubled").unwrap();
        assert_eq!(client.admin_list().unwrap(), vec!["default".to_string()]);
        let err = client.predict_on("doubled", 3, &q).unwrap_err();
        assert!(err.to_string().contains("[unknown_model]"), "got {err}");
        let err = client.admin_remove("doubled").unwrap_err();
        assert!(err.to_string().contains("[unknown_model]"), "got {err}");

        std::fs::remove_file(&path).ok();
        handle.shutdown();
    }

    #[test]
    fn metrics_bridge_renders_per_model_series_and_tracks_health() {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(1))
            .metrics_addr("127.0.0.1:0")
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        assert!(handle.metrics_addr().is_some(), "listener must be up");
        let mut client = Client::connect(handle.addr()).unwrap();
        client.predict(1, &[0.2, 0.1]).unwrap();

        let bridge = MetricsBridge { shared: Arc::clone(&handle.shared) };
        let text = bridge.metrics_text();
        assert!(text.contains("bless_serve_requests_total{model=\"default\"} 1"), "{text}");
        assert!(text.contains("# TYPE bless_serve_latency_us histogram"), "{text}");
        assert!(text.contains("bless_serve_latency_us_count{model=\"default\"} 1"), "{text}");
        assert!(text.contains("bless_serve_queue_depth{model=\"default\"}"), "{text}");

        let varz = bridge.varz();
        let default = varz.get("models").and_then(|m| m.get("default")).unwrap();
        assert_eq!(default.get("requests").and_then(|v| v.as_f64()), Some(1.0));

        let (ready, body) = bridge.healthz();
        assert!(ready);
        assert!(body.to_string().contains("\"ok\":true"));
        assert!(text.contains("bless_serve_breaker_state{model=\"default\"} 0"), "{text}");
        handle.shutdown();
        let (ready, _) = bridge.healthz();
        assert!(!ready, "healthz must flip after shutdown");
    }

    #[test]
    fn per_request_deadline_replies_with_typed_code() {
        // the worker lingers far past the deadline, so the job expires
        // while queued and the client gets the structured code quickly
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(800))
            .cache_capacity(0)
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let t0 = Instant::now();
        let err = client.predict_within(1, &[0.1, 0.2], 20).unwrap_err().to_string();
        assert!(err.contains("[deadline_exceeded]"), "got {err}");
        assert!(
            t0.elapsed() < Duration::from_millis(700),
            "the reply must beat the linger window, took {:?}",
            t0.elapsed()
        );
        let stats = handle.stats();
        assert_eq!(stats.deadline_exceeded, 1);
        assert_eq!(stats.errors, 0, "a deadline miss is not a model error");
        handle.shutdown();
    }

    #[test]
    fn default_deadline_applies_when_the_request_has_none() {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(800))
            .cache_capacity(0)
            .default_deadline(Some(Duration::from_millis(20)))
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let err = client.predict(1, &[0.1, 0.2]).unwrap_err().to_string();
        assert!(err.contains("[deadline_exceeded]"), "got {err}");
        assert_eq!(handle.stats().deadline_exceeded, 1);
        handle.shutdown();
    }

    #[test]
    fn retry_exhaustion_returns_the_typed_error() {
        // saturate a depth-1 queue, then retry against it with a tiny
        // attempt budget — the typed RetryExhausted must surface
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(800))
            .cache_capacity(0)
            .max_queue(1)
            .build()
            .unwrap();
        let handle = start(tiny_artifact(), &cfg).unwrap();
        let addr = handle.addr();
        let blocker = std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.predict(1, &[0.1, 0.2]).unwrap()
        });
        let queue_len = || handle.shared.registry.get("default").unwrap().queue.len();
        let t0 = Instant::now();
        while queue_len() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "blocker never enqueued");
            std::thread::sleep(Duration::from_millis(2));
        }

        let mut client = Client::connect(addr).unwrap();
        let policy = RetryPolicy {
            max_retries: 2,
            base: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            ..RetryPolicy::default()
        };
        let err = client.predict_with_retry(2, &[0.3, 0.4], &policy).unwrap_err();
        let typed = err
            .downcast_ref::<RetryExhausted>()
            .expect("exhaustion must be the typed error");
        assert_eq!(typed.attempts, 3, "first try plus two retries");
        assert!(typed.last_error.contains("[overloaded]"), "got {}", typed.last_error);
        assert_eq!(typed.code, "overloaded", "the exhausting code must be reported");
        assert!(typed.to_string().contains("[overloaded]"), "got {typed}");
        blocker.join().unwrap();
        handle.shutdown();
    }

    #[test]
    fn error_code_extracts_the_bracketed_wire_code() {
        assert_eq!(error_code("server error [overloaded]: queue full"), "overloaded");
        assert_eq!(error_code("server error [quarantined]: retry later"), "quarantined");
        assert_eq!(error_code("no brackets here"), "unknown");
        assert_eq!(error_code("half [open"), "unknown");
    }

    #[test]
    fn periodic_flush_persists_stats_without_a_shutdown() {
        let path = std::env::temp_dir()
            .join(format!("bless-server-flush-{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(1))
            .stats_file(&path)
            .stats_flush(Some(Duration::from_millis(30)))
            .build()
            .unwrap();
        // flush without a file to flush to is a config error
        assert!(ServeConfig::builder()
            .stats_flush(Some(Duration::from_millis(10)))
            .build()
            .is_err());
        assert!(ServeConfig::builder()
            .stats_file(&path)
            .stats_flush(Some(Duration::ZERO))
            .build()
            .is_err());

        let handle = start(tiny_artifact(), &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.predict(1, &[0.2, 0.1]).unwrap();
        client.predict(2, &[0.4, -0.3]).unwrap();
        // the file must appear while the server is still running
        let t0 = Instant::now();
        loop {
            if path.exists() {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "flusher never wrote");
            std::thread::sleep(Duration::from_millis(5));
        }
        // a restarted server sees the flushed counters even though this
        // "previous" one never shut down gracefully (we drop it below
        // only after the assertion, mimicking a kill)
        handle.shutdown();
        let restarted = start(tiny_artifact(), &cfg).unwrap();
        assert!(
            restarted.model_stats("default").unwrap().requests >= 2,
            "flushed counters must survive"
        );
        restarted.shutdown();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stats_file_round_trips_across_a_server_restart() {
        let path = std::env::temp_dir()
            .join(format!("bless-server-stats-{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .linger(Duration::from_millis(1))
            .stats_file(&path)
            .build()
            .unwrap();

        let handle = start(tiny_artifact(), &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.predict(1, &[0.2, 0.1]).unwrap();
        client.predict(2, &[0.4, -0.3]).unwrap();
        handle.shutdown(); // persists {requests: 2, …} to the stats file
        assert!(path.exists(), "shutdown must write the stats file");

        // a "restarted" server folds the history back in before traffic
        let handle = start(tiny_artifact(), &cfg).unwrap();
        let restored = handle.model_stats("default").unwrap();
        assert_eq!(restored.requests, 2, "counters must survive the restart");
        let mut client = Client::connect(handle.addr()).unwrap();
        client.predict(3, &[0.5, 0.5]).unwrap();
        assert_eq!(handle.model_stats("default").unwrap().requests, 3);
        handle.shutdown();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_stats_file_fails_the_start_loudly() {
        let path = std::env::temp_dir()
            .join(format!("bless-server-badstats-{}.json", std::process::id()));
        std::fs::write(&path, b"{ this is not a stats file").unwrap();
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .stats_file(&path)
            .build()
            .unwrap();
        let err = start(tiny_artifact(), &cfg).unwrap_err().to_string();
        assert!(err.contains("stats file"), "got {err}");
        std::fs::remove_file(&path).ok();
    }
}
