//! The TCP prediction server: stdlib-only (`std::net` + threads).
//!
//! Topology:
//!
//! ```text
//! accept loop ──spawns──▶ connection threads (parse, cache, enqueue)
//!                              │ PredictJob
//!                              ▼
//!                        BatchQueue  ◀─ micro-batching (linger + max)
//!                              │ batch
//!                              ▼
//!                 engine workers (sharing one immutable Predictor —
//!                 one cross_block GEMM per batch)
//! ```
//!
//! Shutdown (`{"op":"shutdown"}` or [`ServerHandle::shutdown`]) closes
//! the queue (in-flight work drains, new work is refused), pokes the
//! accept loop and joins the worker pool. Idle keep-alive connections
//! are dropped when the process exits.

use crate::linalg::Matrix;
use crate::serve::batcher::{BatchQueue, PredictJob};
use crate::serve::cache::PredictionCache;
use crate::serve::model_store::{ModelArtifact, Predictor};
use crate::serve::protocol::{self, Request, StatsSnapshot};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Engine worker threads (all sharing one immutable [`Predictor`]).
    pub workers: usize,
    /// Largest coalesced batch per GEMM.
    pub max_batch: usize,
    /// How long a worker lingers for stragglers after the first request.
    pub linger: Duration,
    /// Prediction-cache capacity in entries (0 disables the cache).
    pub cache_capacity: usize,
    /// Cache quantization step for query coordinates.
    pub cache_quant: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 2,
            max_batch: 64,
            linger: Duration::from_millis(2),
            cache_capacity: 1024,
            cache_quant: 1e-9,
        }
    }
}

/// Monotone server counters (lock-free; read via [`StatsSnapshot`]).
#[derive(Default)]
struct ServerStats {
    requests: AtomicU64,
    batches: AtomicU64,
    batched: AtomicU64,
    cache_hits: AtomicU64,
    errors: AtomicU64,
    latency_us: AtomicU64,
}

impl ServerStats {
    fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            latency_us: self.latency_us.load(Ordering::Relaxed),
        }
    }
}

/// State shared by the accept loop, connection threads and workers.
struct Shared {
    queue: BatchQueue<PredictJob>,
    stats: ServerStats,
    cache: Option<Mutex<PredictionCache>>,
    shutdown: AtomicBool,
    dim: usize,
    addr: SocketAddr,
}

impl Shared {
    fn request_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return; // already shutting down
        }
        self.queue.close();
        // poke the accept loop so it re-checks the flag
        let _ = TcpStream::connect(self.addr);
    }
}

/// A running server; dropping (or calling [`shutdown`](Self::shutdown))
/// stops it and joins its threads.
pub struct ServerHandle {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Current counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Whether a shutdown has been requested (locally or over the wire).
    pub fn is_shut_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain in-flight work and join all threads.
    pub fn shutdown(mut self) {
        self.shared.request_shutdown();
        self.join_threads();
    }

    /// Block until the server shuts down (e.g. a client sends
    /// `{"op":"shutdown"}`) — the `repro serve` foreground mode.
    pub fn join(mut self) {
        self.join_threads();
    }

    fn join_threads(&mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shared.request_shutdown();
        self.join_threads();
    }
}

/// Start serving `artifact` with the given config. Returns once the
/// listener is bound and the worker pool is up.
pub fn start(artifact: ModelArtifact, cfg: &ServeConfig) -> anyhow::Result<ServerHandle> {
    anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        queue: BatchQueue::new(),
        stats: ServerStats::default(),
        cache: (cfg.cache_capacity > 0)
            .then(|| Mutex::new(PredictionCache::new(cfg.cache_capacity, cfg.cache_quant))),
        shutdown: AtomicBool::new(false),
        dim: artifact.d(),
        addr,
    });

    // the predictor is immutable after construction, so one engine
    // (centers matrix + row norms) serves every worker thread
    let predictor = Arc::new(Predictor::new(&artifact));
    let mut workers = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let predictor = Arc::clone(&predictor);
        let shared = Arc::clone(&shared);
        let (max_batch, linger) = (cfg.max_batch, cfg.linger);
        workers.push(std::thread::spawn(move || {
            worker_loop(&predictor, &shared, max_batch, linger);
        }));
    }

    let accept_shared = Arc::clone(&shared);
    let accept = std::thread::spawn(move || accept_loop(listener, &accept_shared));
    Ok(ServerHandle { shared, accept: Some(accept), workers })
}

fn worker_loop(predictor: &Predictor, shared: &Shared, max_batch: usize, linger: Duration) {
    while let Some(batch) = shared.queue.pop_batch(max_batch, linger) {
        if batch.is_empty() {
            continue;
        }
        shared.stats.batches.fetch_add(1, Ordering::Relaxed);
        shared.stats.batched.fetch_add(batch.len() as u64, Ordering::Relaxed);
        let q = Matrix::from_fn(batch.len(), predictor.dim(), |i, j| batch[i].x[j]);
        match predictor.predict_batch(&q) {
            Ok(scores) => {
                for (job, &score) in batch.iter().zip(&scores) {
                    // a disconnected client is not a worker error
                    let _ = job.reply.send(score);
                }
            }
            // dims are validated before enqueue; dropping the batch (and
            // its reply senders) surfaces an error on each waiting
            // connection
            Err(_) => {}
        }
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(stream) => {
                let shared = Arc::clone(shared);
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &shared);
                });
            }
            Err(_) => continue,
        }
    }
}

fn handle_conn(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                protocol::error_response(None, &e.to_string())
            }
            Ok(Request::Ping) => protocol::ok_response(),
            Ok(Request::Stats) => shared.stats.snapshot().to_line(),
            Ok(Request::Shutdown) => {
                // flip the flag before acking so a client that saw the
                // ack observes is_shut_down() == true
                shared.request_shutdown();
                writeln!(writer, "{}", protocol::ok_response())?;
                writer.flush()?;
                return Ok(());
            }
            Ok(Request::Predict { id, x }) => handle_predict(shared, id, x),
        };
        writeln!(writer, "{response}")?;
        writer.flush()?;
    }
    Ok(())
}

fn handle_predict(shared: &Shared, id: u64, x: Vec<f64>) -> String {
    let t0 = Instant::now();
    shared.stats.requests.fetch_add(1, Ordering::Relaxed);
    if x.len() != shared.dim {
        shared.stats.errors.fetch_add(1, Ordering::Relaxed);
        return protocol::error_response(
            Some(id),
            &format!("query dimension {} != model dimension {}", x.len(), shared.dim),
        );
    }

    // one lock acquisition covers both the key quantization and the
    // hit check; the key is kept for the post-predict insert
    let mut key = None;
    if let Some(cache) = &shared.cache {
        let mut c = cache.lock().unwrap();
        let k = c.key(&x);
        if let Some(y) = c.get(&k) {
            drop(c);
            shared.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            bump_latency(shared, t0);
            return protocol::predict_response(id, y, true);
        }
        key = Some(k);
    }

    let (tx, rx) = mpsc::channel();
    if !shared.queue.push(PredictJob { x, reply: tx }) {
        shared.stats.errors.fetch_add(1, Ordering::Relaxed);
        return protocol::error_response(Some(id), "server is shutting down");
    }
    match rx.recv() {
        Ok(y) => {
            if let (Some(cache), Some(key)) = (&shared.cache, key) {
                cache.lock().unwrap().insert(key, y);
            }
            bump_latency(shared, t0);
            protocol::predict_response(id, y, false)
        }
        Err(_) => {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(Some(id), "prediction failed (server stopping?)")
        }
    }
}

fn bump_latency(shared: &Shared, t0: Instant) {
    let us = t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
    shared.stats.latency_us.fetch_add(us, Ordering::Relaxed);
}

/// A minimal blocking client for the line protocol — used by the CLI,
/// the integration tests and the `serve_roundtrip` example.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect to a running server.
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    fn round_trip(&mut self, line: &str) -> anyhow::Result<String> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(buf.trim_end().to_string())
    }

    /// Score one query point; returns `(score, served_from_cache)`.
    pub fn predict(&mut self, id: u64, x: &[f64]) -> anyhow::Result<(f64, bool)> {
        let req = Request::Predict { id, x: x.to_vec() };
        let line = self.round_trip(&req.to_line())?;
        let (rid, y, cached) = protocol::parse_predict_response(&line)?;
        anyhow::ensure!(rid == id, "response id {rid} != request id {id}");
        Ok((y, cached))
    }

    /// Fetch server counters.
    pub fn stats(&mut self) -> anyhow::Result<StatsSnapshot> {
        let line = self.round_trip(&Request::Stats.to_line())?;
        StatsSnapshot::parse(&line)
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> anyhow::Result<()> {
        let line = self.round_trip(&Request::Ping.to_line())?;
        anyhow::ensure!(line.contains("\"ok\""), "unexpected ping response: {line}");
        Ok(())
    }

    /// Ask the server to shut down gracefully.
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        let line = self.round_trip(&Request::Shutdown.to_line())?;
        anyhow::ensure!(line.contains("\"ok\""), "unexpected shutdown response: {line}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_artifact() -> ModelArtifact {
        ModelArtifact {
            sigma: 1.0,
            centers: Matrix::from_vec(3, 2, vec![0.0, 0.0, 1.0, 0.5, -0.5, 1.0]),
            alpha: vec![0.5, -0.25, 1.0],
            trained_n: 3,
            dataset: "tiny".to_string(),
        }
    }

    fn test_config() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 1,
            linger: Duration::from_millis(1),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn serves_predictions_matching_direct_predictor() {
        let art = tiny_artifact();
        let direct = Predictor::new(&art);
        let handle = start(art, &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.ping().unwrap();
        for (i, q) in [[0.2, 0.1], [1.0, 0.5], [-3.0, 2.0]].iter().enumerate() {
            let (y, cached) = client.predict(i as u64, q).unwrap();
            assert!(!cached);
            let want = direct.predict_one(q).unwrap();
            assert!((y - want).abs() < 1e-12, "served {y} vs direct {want}");
        }
        let stats = handle.stats();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 0);
        handle.shutdown();
    }

    #[test]
    fn repeated_query_hits_the_cache() {
        let art = tiny_artifact();
        let handle = start(art, &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let q = [0.4, -0.6];
        let (y1, c1) = client.predict(1, &q).unwrap();
        let (y2, c2) = client.predict(2, &q).unwrap();
        assert!(!c1);
        assert!(c2, "second identical query should be served from cache");
        assert_eq!(y1.to_bits(), y2.to_bits());
        assert_eq!(handle.stats().cache_hits, 1);
        handle.shutdown();
    }

    #[test]
    fn bad_requests_get_error_lines_and_are_counted() {
        let handle = start(tiny_artifact(), &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        // wrong dimension
        assert!(client.predict(1, &[1.0, 2.0, 3.0]).is_err());
        // raw garbage line
        let resp = client.round_trip("this is not json").unwrap();
        assert!(resp.contains("\"error\""), "got {resp}");
        // connection still usable afterwards
        client.ping().unwrap();
        assert_eq!(handle.stats().errors, 2);
        handle.shutdown();
    }

    #[test]
    fn wire_shutdown_unblocks_join() {
        let handle = start(tiny_artifact(), &test_config()).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.shutdown().unwrap();
        assert!(handle.is_shut_down());
        handle.join(); // returns because the client stopped the server
    }
}
