//! Serving tier: model persistence + a batched, multi-threaded FALKON
//! prediction server.
//!
//! BLESS picks the Nyström centers and FALKON fits `α`; after that the
//! deployable model is just `(σ, centers, α)` and prediction is
//! `f(x) = Σ_j α_j K(x, x̃_j)` — cheap enough to serve at scale. This
//! module takes a fitted [`crate::falkon::FalkonModel`] from training to
//! traffic:
//!
//! * [`model_store`] — the self-contained, versioned + checksummed JSON
//!   artifact ([`ModelArtifact`]) with the center *rows* gathered out of
//!   the training set, and the inference-side [`Predictor`].
//! * [`batcher`] — the [`BatchQueue`] that coalesces concurrent
//!   single-point requests into one `cross_block` GEMM per tick.
//! * [`protocol`] — the line-delimited JSON wire format.
//! * [`server`] — the stdlib-only TCP server: accept loop, a worker
//!   pool over one shared engine, request/latency counters, graceful
//!   shutdown; plus the blocking [`Client`].
//! * [`cache`] — a bounded LRU over quantized query vectors for
//!   repeated-query traffic.
//!
//! ## Train → save → serve → predict
//!
//! ```no_run
//! use bless::serve::{self, ModelArtifact, ServeConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! # let (model, engine): (bless::falkon::FalkonModel, bless::kernels::NativeEngine) = todo!();
//! // training side (any KernelEngine):
//! let artifact = ModelArtifact::from_fitted(&model, &engine, "susy-like")?;
//! artifact.save("model.json")?;
//!
//! // inference side (no training data needed):
//! let loaded = ModelArtifact::load("model.json")?;
//! let handle = serve::start(loaded, &ServeConfig::default())?;
//! let mut client = serve::Client::connect(handle.addr())?;
//! let (score, _cached) = client.predict(1, &vec![0.0; 18])?;
//! # let _ = score;
//! # Ok(())
//! # }
//! ```
//!
//! Or from the CLI: `repro train --save model.json`, then
//! `repro serve --model model.json --port 7878`, then line-delimited
//! JSON requests over TCP (`repro predict --model model.json` for
//! offline scoring).

pub mod batcher;
pub mod cache;
pub mod model_store;
pub mod protocol;
pub mod server;

pub use batcher::{BatchQueue, PredictJob};
pub use cache::PredictionCache;
pub use model_store::{ModelArtifact, Predictor, FORMAT, VERSION};
pub use protocol::{Request, StatsSnapshot};
pub use server::{start, Client, ServeConfig, ServerHandle};
