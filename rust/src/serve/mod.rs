//! Serving tier: model persistence (JSON + binary), a multi-model
//! registry with hot reload and backpressure, and a batched,
//! multi-threaded FALKON prediction server.
//!
//! BLESS picks the Nyström centers and FALKON fits `α`; after that the
//! deployable model is just `(σ, centers, α)` and prediction is
//! `f(x) = Σ_j α_j K(x, x̃_j)` — cheap enough to serve at scale. This
//! module takes fitted [`crate::falkon::FalkonModel`]s from training to
//! traffic:
//!
//! * [`model_store`] — the self-contained, versioned + checksummed
//!   artifact ([`ModelArtifact`]) with the center *rows* gathered out of
//!   the training set, and the inference-side [`Predictor`].
//! * [`codec`] — the two on-disk encodings: human-readable JSON for
//!   small models, and a raw little-endian **binary** layout for large M
//!   (magic `BLESSBIN`, version, header, raw `f64` sections for `α` and
//!   the center rows, trailing FNV-1a checksum). `save` picks by
//!   extension (`.bin`/`.bless` → binary), `load` sniffs the magic, and
//!   both roundtrip every `f64` bit-exactly.
//! * [`registry`] — one process, N named models: per-model batching
//!   queue, LRU cache, counters and queue-depth cap around a
//!   hot-swappable predictor.
//! * [`batcher`] — the [`BatchQueue`] that coalesces concurrent
//!   single-point requests into one `cross_block` GEMM per tick, with a
//!   bounded-push mode for load shedding.
//! * [`protocol`] — the line-delimited JSON wire format.
//! * [`server`] — the stdlib-only TCP server: accept loop, per-model
//!   worker pools, request/latency counters, graceful shutdown; plus the
//!   blocking [`Client`].
//! * [`cache`] — a bounded LRU over quantized query vectors for
//!   repeated-query traffic.
//!
//! ## Routing, hot reload, backpressure
//!
//! Predict requests carry an optional `"model"` name
//! (`{"id":1,"model":"higgs-v2","x":[…]}`); with a single loaded model
//! the name may be omitted. The `admin` verb manages the registry at
//! run time — typed as [`AdminRequest`]/[`AdminResponse`] on the Rust
//! side ([`Client::admin`] plus per-verb sugar):
//!
//! ```text
//! → {"op":"admin","cmd":"list"}
//! → {"op":"admin","cmd":"reload","model":"higgs-v2","path":"v3.bin"}
//! → {"op":"admin","cmd":"add","model":"mnist","path":"mnist.bin"}
//! → {"op":"admin","cmd":"remove","model":"mnist"}
//! ```
//!
//! `add` loads the artifact, registers the model and spawns its batch
//! queue + worker pool; `remove` retires them (queued requests drain,
//! then the workers exit). Both serialize against shutdown.
//!
//! Reload loads the artifact (either encoding), builds the new predictor
//! off-lock, and swaps it atomically: engine workers snapshot the
//! predictor per batch, so every in-flight request completes against a
//! consistent model and none are dropped; the model's query cache is
//! cleared under the same swap. Each model's queue has a depth cap
//! (`ServeConfig::max_queue`); a request arriving at a full queue is
//! shed immediately with `{"error":…,"code":"overloaded"}` rather than
//! buffered without bound — clients should back off and retry.
//! [`Client::predict_with_retry`] packages that loop: jittered
//! exponential backoff under a [`RetryPolicy`], retrying the transient
//! codes (`overloaded` and `deadline_exceeded` on the fast ladder,
//! `quarantined` on a slower breaker-cooldown-aware one) and surfacing
//! a typed [`server::RetryExhausted`] — carrying the exhausting code —
//! when the budget runs out.
//!
//! ## Robustness
//!
//! The serving tier is hardened against its own failure modes, and a
//! seeded chaos harness ([`crate::faults`]) injects them on demand:
//!
//! * **Deadlines** — requests may carry `"deadline_ms"` (or inherit
//!   `ServeConfig::default_deadline`); a request that cannot be
//!   answered in time gets `{"code":"deadline_exceeded"}` instead of
//!   waiting forever, and expired jobs are discarded at dequeue.
//! * **Panic quarantine** — engine workers run each batch under
//!   `catch_unwind`; a panic answers its batch with structured errors
//!   and the worker respawns, so the pool never shrinks. Repeated
//!   failures trip a per-model circuit breaker
//!   ([`registry::Breaker`]): the model answers `quarantined`
//!   immediately, `/healthz` degrades, and a half-open probe re-admits
//!   it once healthy.
//! * **Crash-safe artifacts** — every artifact and stats write goes
//!   through temp-file + fsync + atomic rename
//!   ([`crate::util::fsio::atomic_write`]), so a crash mid-save never
//!   leaves a torn file; truncated or bit-flipped artifacts load as
//!   clean typed errors (the checksum catches them).
//! * **Stats continuity** — `ServeConfig::stats_file` persists
//!   per-model counters and histograms across restarts ([`stats_io`]);
//!   `ServeConfig::stats_flush` flushes the same snapshot periodically
//!   while serving, bounding what a hard kill can lose.
//!
//! ## Observability
//!
//! With `ServeConfig::metrics_addr` set (CLI: `repro serve
//! --metrics-port 9100`), a separate HTTP listener exposes `GET
//! /metrics` (Prometheus text format: per-model request counters,
//! latency and batch-size histograms, queue-depth gauges, plus the
//! training-side [`crate::obs`] registry), `GET /healthz` (readiness;
//! 503 once shutdown begins) and `GET /varz` (the same data as JSON).
//! The `stats` wire verb carries derived p50/p95/p99 fields alongside
//! the exact counters.
//!
//! ## Train → save → serve → predict
//!
//! ```no_run
//! use bless::serve::{self, ModelArtifact, ServeConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! # let (model, engine): (bless::falkon::FalkonModel, bless::kernels::NativeEngine) = todo!();
//! // training side (any KernelEngine):
//! let artifact = ModelArtifact::from_fitted(&model, &engine, "susy-like")?;
//! artifact.save("model.bin")?;              // .bin/.bless → binary codec
//!
//! // inference side (no training data needed):
//! let loaded = ModelArtifact::load("model.bin")?;   // format auto-detected
//! let handle = serve::start(loaded, &ServeConfig::default())?;
//! let mut client = serve::Client::connect(handle.addr())?;
//! let (score, _cached) = client.predict(1, &vec![0.0; 18])?;
//! # let _ = score;
//! # Ok(())
//! # }
//! ```
//!
//! Or from the CLI: `repro train --save model.bin`, then
//! `repro serve --models susy=model.bin,higgs=other.bin --max-queue 512`,
//! then line-delimited JSON requests over TCP (`repro predict` for
//! offline scoring, `repro convert` to move artifacts between JSON and
//! binary).

pub mod batcher;
pub mod cache;
pub mod codec;
pub mod model_store;
pub mod protocol;
pub mod registry;
pub mod server;
pub mod stats_io;

pub use batcher::{BatchQueue, JobError, PredictJob, Push};
pub use cache::PredictionCache;
pub use codec::Format;
pub use model_store::{ModelArtifact, Predictor, FORMAT, VERSION};
pub use protocol::{AdminRequest, AdminResponse, ModelInfo, Request, StatsSnapshot};
pub use registry::{
    Admission, Breaker, ModelEntry, ModelSpec, ModelStats, Registry, RegistryConfig,
};
pub use server::{
    start, start_registry, Client, RetryExhausted, RetryPolicy, ServeConfig,
    ServeConfigBuilder, ServerHandle,
};
