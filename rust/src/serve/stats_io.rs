//! Stats snapshot/restore across server restarts (`serve --stats-file`).
//!
//! A restart normally zeroes every per-model counter and histogram,
//! which breaks long-horizon dashboards (request totals, cumulative
//! p99) every deploy. With `--stats-file PATH` the server persists each
//! model's counters *and* full latency/batch-size histograms on
//! graceful shutdown and folds them back in at the next start:
//! counters add on, histograms merge bucket-exactly
//! ([`Histogram::merge_snapshot`]), so percentiles after a restart are
//! what one uninterrupted run would have reported.
//!
//! The file is JSON (written crash-safely via
//! [`crate::util::fsio::atomic_write`]):
//!
//! ```text
//! {"format":"bless-serve-stats","version":1,
//!  "models":{"susy":{"requests":128,…,
//!                    "latency":{"buckets":[[17,40],[18,88]],"count":128,"sum":…},
//!                    "batch_sizes":{…}}}}
//! ```
//!
//! Histogram buckets are stored sparsely as `[index,count]` pairs.
//! Restore is name-keyed and forgiving: models in the file but not in
//! the registry are skipped (the fleet changed), models not in the file
//! start cold, and a missing file is simply "no history yet".

use crate::obs::{HistSnapshot, Histogram};
use crate::serve::protocol::StatsSnapshot;
use crate::serve::registry::Registry;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

const FORMAT: &str = "bless-serve-stats";
const VERSION: u64 = 1;

fn hist_to_json(s: &HistSnapshot) -> Json {
    let mut obj = BTreeMap::new();
    let pairs: Vec<Json> = s
        .buckets
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, &c)| Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)]))
        .collect();
    obj.insert("buckets".to_string(), Json::Arr(pairs));
    obj.insert("count".to_string(), Json::Num(s.count as f64));
    obj.insert("sum".to_string(), Json::Num(s.sum as f64));
    Json::Obj(obj)
}

fn hist_from_json(j: &Json) -> anyhow::Result<HistSnapshot> {
    let mut s = HistSnapshot::default();
    if let Some(pairs) = j.get("buckets").and_then(|v| v.as_arr()) {
        for pair in pairs {
            let p = pair
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow::anyhow!("bad histogram bucket entry"))?;
            let idx = p[0]
                .as_usize()
                .filter(|&i| i < s.buckets.len())
                .ok_or_else(|| anyhow::anyhow!("histogram bucket index out of range"))?;
            let count = p[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric histogram bucket count"))?;
            s.buckets[idx] += count as u64;
        }
    }
    s.count = j.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    s.sum = j.get("sum").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    Ok(s)
}

fn model_to_json(snap: &StatsSnapshot, lat: &HistSnapshot, batch: &HistSnapshot) -> Json {
    // reuse the wire serialization for the counters, then attach the
    // exact histograms (to_line's derived percentiles are redundant on
    // disk but harmless — parse ignores unknown keys)
    let mut obj = match Json::parse(&snap.to_line()) {
        Ok(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    obj.insert("latency".to_string(), hist_to_json(lat));
    obj.insert("batch_sizes".to_string(), hist_to_json(batch));
    Json::Obj(obj)
}

/// Persist every registered model's counters and histograms to `path`
/// (crash-safe: temp file + fsync + atomic rename). Returns the number
/// of models written.
pub fn save(path: impl AsRef<Path>, registry: &Registry) -> anyhow::Result<usize> {
    let mut models = BTreeMap::new();
    for entry in registry.entries() {
        models.insert(
            entry.name().to_string(),
            model_to_json(
                &entry.stats.snapshot(),
                &entry.stats.latency.snapshot(),
                &entry.stats.batch_sizes.snapshot(),
            ),
        );
    }
    let n = models.len();
    let mut root = BTreeMap::new();
    root.insert("format".to_string(), Json::Str(FORMAT.to_string()));
    root.insert("version".to_string(), Json::Num(VERSION as f64));
    root.insert("models".to_string(), Json::Obj(models));
    let path = path.as_ref();
    crate::util::fsio::atomic_write(path, Json::Obj(root).to_string().as_bytes())
        .map_err(|e| anyhow::anyhow!("writing stats file {}: {e}", path.display()))?;
    Ok(n)
}

/// Fold a persisted stats file back into the registry: counters add on,
/// histograms merge bucket-exactly. Models named in the file but absent
/// from the registry are skipped. Returns the number of models restored.
pub fn load(path: impl AsRef<Path>, registry: &Registry) -> anyhow::Result<usize> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading stats file {}: {e}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing stats file {}: {e}", path.display()))?;
    anyhow::ensure!(
        j.get("format").and_then(|v| v.as_str()) == Some(FORMAT),
        "{} is not a {FORMAT} file",
        path.display()
    );
    let version = j.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    anyhow::ensure!(
        version == VERSION,
        "stats file {} has version {version}, this server reads {VERSION}",
        path.display()
    );
    let models = j
        .get("models")
        .and_then(|v| v.as_obj())
        .ok_or_else(|| anyhow::anyhow!("stats file {} has no models map", path.display()))?;
    let mut restored = 0;
    for (name, model_j) in models {
        let Some(entry) = registry.get(name) else { continue };
        let counters = StatsSnapshot::parse(&model_j.to_string())?;
        entry.stats.restore(&counters);
        if let Some(lat) = model_j.get("latency") {
            entry.stats.latency.merge_snapshot(&hist_from_json(lat)?);
        }
        if let Some(batch) = model_j.get("batch_sizes") {
            entry.stats.batch_sizes.merge_snapshot(&hist_from_json(batch)?);
        }
        restored += 1;
    }
    Ok(restored)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::serve::registry::{ModelSpec, RegistryConfig};
    use crate::serve::ModelArtifact;
    use std::sync::atomic::Ordering;

    fn spec(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            artifact: ModelArtifact {
                sigma: 1.5,
                centers: Matrix::from_fn(4, 3, |i, j| ((i * 3 + j) as f64 * 0.31).cos()),
                alpha: vec![0.4, -0.2, 0.9, 0.1],
                trained_n: 4,
                dataset: "unit".to_string(),
            },
            source: None,
        }
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bless-stats-io-{}-{tag}.json", std::process::id()))
    }

    #[test]
    fn round_trip_restores_counters_and_percentiles() {
        let reg =
            Registry::new(vec![spec("a"), spec("b")], RegistryConfig::default()).unwrap();
        let a = reg.get("a").unwrap();
        a.stats.requests.fetch_add(120, Ordering::Relaxed);
        a.stats.deadline_exceeded.fetch_add(4, Ordering::Relaxed);
        a.stats.worker_respawns.fetch_add(2, Ordering::Relaxed);
        for i in 0..100u64 {
            a.stats.latency.record(100 + i * 7);
            a.stats.batch_sizes.record(1 + i % 8);
        }
        let before = a.stats.snapshot();

        let path = tmp_path("roundtrip");
        assert_eq!(save(&path, &reg).unwrap(), 2);

        // a fresh registry (same models, cold counters) restores exactly
        let reg2 =
            Registry::new(vec![spec("a"), spec("b")], RegistryConfig::default()).unwrap();
        assert_eq!(load(&path, &reg2).unwrap(), 2);
        let after = reg2.get("a").unwrap().stats.snapshot();
        assert_eq!(after, before, "snapshot must survive the restart byte-exactly");
        assert_eq!(reg2.get("b").unwrap().stats.snapshot().requests, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn restore_skips_models_the_registry_no_longer_has() {
        let reg = Registry::new(vec![spec("a"), spec("gone")], RegistryConfig::default())
            .unwrap();
        reg.get("gone").unwrap().stats.requests.fetch_add(9, Ordering::Relaxed);
        let path = tmp_path("skips");
        save(&path, &reg).unwrap();

        let reg2 = Registry::new(vec![spec("a")], RegistryConfig::default()).unwrap();
        assert_eq!(load(&path, &reg2).unwrap(), 1, "only the surviving model restores");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_stats_files_error_cleanly() {
        let reg = Registry::new(vec![spec("a")], RegistryConfig::default()).unwrap();
        let path = tmp_path("bad");
        assert!(load(&path, &reg).is_err(), "missing file is an error the caller gates on");
        std::fs::write(&path, b"not json").unwrap();
        assert!(load(&path, &reg).is_err());
        std::fs::write(&path, b"{\"format\":\"other\",\"version\":1,\"models\":{}}").unwrap();
        assert!(load(&path, &reg).is_err());
        std::fs::write(
            &path,
            format!("{{\"format\":\"{FORMAT}\",\"version\":99,\"models\":{{}}}}"),
        )
        .unwrap();
        assert!(load(&path, &reg).is_err());
        std::fs::remove_file(&path).ok();
    }
}
