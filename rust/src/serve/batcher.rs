//! Micro-batching: coalesce concurrent single-point requests into one
//! `cross_block` GEMM per tick.
//!
//! The blocked row-norm kernel path makes a batch of 64 queries far
//! cheaper than 64 singles (one gather of the center rows, one GEMM), so
//! the server funnels every in-flight predict request through a
//! [`BatchQueue`]. Engine workers block for the first request, *linger*
//! a short window for stragglers, then drain up to `max_batch` items and
//! answer them with a single batched predict.
//!
//! The queue is a plain `Mutex<VecDeque> + Condvar` pair: `std::sync::
//! mpsc` receivers cannot be shared across workers without holding a lock
//! through the blocking `recv`, which would serialize the worker pool.
//! Locking goes through [`crate::util::sync`], so a worker that panics
//! mid-batch (isolated by the server's `catch_unwind` supervisor) never
//! wedges the queue for its peers.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync as psync;

/// Why a queued job failed — carried back over the job's reply channel
/// so the connection handler can answer with the right wire `code`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The job's deadline had already passed when a worker dequeued it
    /// (or the handler timed out waiting); wire code `deadline_exceeded`.
    DeadlineExceeded,
    /// The worker servicing the batch panicked; the supervisor respawned
    /// it and the job is answered with wire code `internal`.
    Panicked,
    /// The predict itself failed (engine error, stale dimension after a
    /// hot reload, …); wire code `internal` with this message.
    Failed(String),
}

impl JobError {
    /// The machine-readable wire code for this failure.
    pub fn code(&self) -> &'static str {
        match self {
            JobError::DeadlineExceeded => "deadline_exceeded",
            JobError::Panicked | JobError::Failed(_) => "internal",
        }
    }

    /// Human-readable message for the wire reply.
    pub fn message(&self) -> String {
        match self {
            JobError::DeadlineExceeded => "deadline exceeded before completion".to_string(),
            JobError::Panicked => "worker panicked servicing the batch".to_string(),
            JobError::Failed(msg) => msg.clone(),
        }
    }
}

/// One queued prediction request: the query row plus the channel the
/// connection handler is blocked on.
pub struct PredictJob {
    /// Query point (length = model feature dimension; validated upstream).
    pub x: Vec<f64>,
    /// Where the batched score — or a structured failure (deadline blown,
    /// worker panicked, model hot-reloaded to a different dimension
    /// mid-flight) — is delivered.
    pub reply: mpsc::Sender<Result<f64, JobError>>,
    /// Absolute completion deadline, if the request (or the server
    /// default) set one; workers discard already-expired jobs at dequeue
    /// instead of spending a batch slot on an answer nobody is waiting
    /// for.
    pub deadline: Option<Instant>,
}

impl PredictJob {
    /// Whether the job's deadline (if any) has already passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Outcome of a bounded enqueue attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Push {
    /// The item was queued.
    Accepted,
    /// The queue was closed (server shutting down); the item was dropped.
    Closed,
    /// The queue was at its depth cap (backpressure); the item was
    /// dropped so the caller can shed load with a structured error
    /// instead of buffering without bound.
    Full,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A closable MPMC queue with batched, lingering pops.
pub struct BatchQueue<T> {
    state: Mutex<QueueState<T>>,
    cv: Condvar,
}

impl<T> Default for BatchQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> BatchQueue<T> {
    /// Empty open queue.
    pub fn new() -> Self {
        BatchQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue an item; returns `false` (dropping the item) if the queue
    /// has been closed.
    pub fn push(&self, item: T) -> bool {
        self.push_bounded(item, 0) == Push::Accepted
    }

    /// Enqueue with a depth cap: `cap == 0` means unbounded, otherwise
    /// an item arriving while `cap` items are already queued is dropped
    /// and [`Push::Full`] returned — the server's backpressure signal.
    pub fn push_bounded(&self, item: T, cap: usize) -> Push {
        let mut g = psync::lock(&self.state);
        if g.closed {
            return Push::Closed;
        }
        if cap > 0 && g.items.len() >= cap {
            return Push::Full;
        }
        g.items.push_back(item);
        drop(g);
        self.cv.notify_all();
        Push::Accepted
    }

    /// Close the queue: no further pushes succeed; blocked poppers drain
    /// the remaining items and then observe `None`.
    pub fn close(&self) {
        psync::lock(&self.state).closed = true;
        self.cv.notify_all();
    }

    /// Number of currently queued items.
    pub fn len(&self) -> usize {
        psync::lock(&self.state).items.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until at least one item is available (or the queue is closed
    /// and drained — then `None`). Once the first item arrives, wait up
    /// to `linger` for the batch to fill to `max`, then drain up to `max`
    /// items. `max` must be ≥ 1.
    pub fn pop_batch(&self, max: usize, linger: Duration) -> Option<Vec<T>> {
        assert!(max >= 1);
        let mut g = psync::lock(&self.state);
        // phase 1: wait for the first item
        while g.items.is_empty() {
            if g.closed {
                return None;
            }
            g = psync::wait(&self.cv, g);
        }
        // phase 2: linger for stragglers to coalesce a batch
        if linger > Duration::ZERO && g.items.len() < max && !g.closed {
            let deadline = Instant::now() + linger;
            loop {
                let now = Instant::now();
                if now >= deadline || g.items.len() >= max || g.closed {
                    break;
                }
                let (g2, timeout) = psync::wait_timeout(&self.cv, g, deadline - now);
                g = g2;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.items.len().min(max);
        Some(g.items.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::falkon::nystrom_krr;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::linalg::Matrix;
    use crate::rng::Rng;
    use crate::serve::{ModelArtifact, Predictor};
    use std::sync::Arc;

    #[test]
    fn pre_queued_items_come_out_as_one_batch() {
        let q: BatchQueue<usize> = BatchQueue::new();
        for i in 0..10 {
            assert!(q.push(i));
        }
        let batch = q.pop_batch(64, Duration::ZERO).unwrap();
        assert_eq!(batch, (0..10).collect::<Vec<_>>());
        assert!(q.is_empty());
    }

    #[test]
    fn max_batch_is_respected() {
        let q: BatchQueue<usize> = BatchQueue::new();
        for i in 0..10 {
            q.push(i);
        }
        assert_eq!(q.pop_batch(4, Duration::ZERO).unwrap().len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn close_wakes_blocked_popper_and_drains() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new());
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some(batch) = q2.pop_batch(8, Duration::from_millis(1)) {
                seen.extend(batch);
            }
            seen
        });
        for i in 0..5 {
            q.push(i);
        }
        q.close();
        assert!(!q.push(99)); // closed queue refuses new work
        let seen = popper.join().unwrap();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bounded_push_sheds_at_the_cap_and_recovers() {
        let q: BatchQueue<usize> = BatchQueue::new();
        assert_eq!(q.push_bounded(0, 2), Push::Accepted);
        assert_eq!(q.push_bounded(1, 2), Push::Accepted);
        // at the cap: the third item is shed, not buffered
        assert_eq!(q.push_bounded(2, 2), Push::Full);
        assert_eq!(q.len(), 2);
        // draining frees capacity again
        assert_eq!(q.pop_batch(8, Duration::ZERO).unwrap(), vec![0, 1]);
        assert_eq!(q.push_bounded(3, 2), Push::Accepted);
        // cap 0 = unbounded
        for i in 0..100 {
            assert_eq!(q.push_bounded(i, 0), Push::Accepted);
        }
        q.close();
        assert_eq!(q.push_bounded(9, 2), Push::Closed);
    }

    #[test]
    fn lingering_pop_collects_late_arrivals() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new());
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || q2.pop_batch(16, Duration::from_millis(200)));
        // stagger a few pushes well inside the linger window
        for i in 0..3 {
            std::thread::sleep(Duration::from_millis(10));
            q.push(i);
        }
        let batch = popper.join().unwrap().unwrap();
        assert!(batch.len() >= 2, "linger failed to coalesce: got {batch:?}");
    }

    #[test]
    fn job_error_wire_codes() {
        assert_eq!(JobError::DeadlineExceeded.code(), "deadline_exceeded");
        assert_eq!(JobError::Panicked.code(), "internal");
        assert_eq!(JobError::Failed("dim".into()).code(), "internal");
        assert_eq!(JobError::Failed("dim".into()).message(), "dim");
    }

    #[test]
    fn expiry_is_judged_against_the_deadline() {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let job = PredictJob { x: vec![0.0], reply: tx.clone(), deadline: None };
        assert!(!job.expired(now), "no deadline never expires");
        let job = PredictJob {
            x: vec![0.0],
            reply: tx,
            deadline: Some(now + Duration::from_secs(5)),
        };
        assert!(!job.expired(now));
        assert!(job.expired(now + Duration::from_secs(6)));
    }

    #[test]
    fn queue_survives_a_popper_panicking_mid_hold() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new());
        q.push(1);
        let q2 = Arc::clone(&q);
        // poison the internal mutex the way a crashed worker would
        let _ = std::thread::spawn(move || {
            let _g = q2.state.lock().unwrap();
            panic!("worker crash while holding the queue lock");
        })
        .join();
        // every operation still works for the surviving threads
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_batch(8, Duration::ZERO).unwrap(), vec![1, 2]);
        q.close();
    }

    /// The ISSUE-mandated agreement check: answering jobs through the
    /// batched path gives the same scores as one-at-a-time prediction.
    #[test]
    fn batched_predictions_match_sequential() {
        let mut rng = Rng::seeded(33);
        let ds = susy_like(250, &mut rng);
        let eng = NativeEngine::new(ds.x.clone(), Gaussian::new(3.5));
        let centers = rng.sample_without_replacement(250, 30);
        let model = nystrom_krr(&eng, &centers, 1e-3, &ds.y).unwrap();
        let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();
        let p = Predictor::new(&art);

        let queue: BatchQueue<PredictJob> = BatchQueue::new();
        let queries: Vec<Vec<f64>> = (0..10).map(|i| ds.x.row(i * 7).to_vec()).collect();
        let mut receivers = Vec::new();
        for x in &queries {
            let (tx, rx) = mpsc::channel();
            queue.push(PredictJob { x: x.clone(), reply: tx, deadline: None });
            receivers.push(rx);
        }

        // one worker tick: drain the whole batch, answer with one GEMM
        let batch = queue.pop_batch(64, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), queries.len());
        let q = Matrix::from_fn(batch.len(), p.dim(), |i, j| batch[i].x[j]);
        let scores = p.predict_batch(&q).unwrap();
        for (job, &s) in batch.iter().zip(&scores) {
            job.reply.send(Ok(s)).unwrap();
        }

        for (rx, x) in receivers.iter().zip(&queries) {
            let batched = rx.recv().unwrap().unwrap();
            let sequential = p.predict_one(x).unwrap();
            assert!(
                (batched - sequential).abs() < 1e-12,
                "batched {batched} vs sequential {sequential}"
            );
        }
    }
}
