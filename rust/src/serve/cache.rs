//! A bounded LRU cache over quantized query vectors.
//!
//! Repeated-query traffic (hot items, retries, dashboards polling the
//! same point) shouldn't pay a kernel evaluation each time. Queries are
//! quantized onto a grid of step `quant` (default 1e-9 — far below any
//! meaningful feature resolution, so collisions only merge queries whose
//! predictions agree to ~1e-9 anyway) and the grid coordinates are the
//! hash key.
//!
//! Eviction is exact LRU via a monotone use-tick per entry; the evictee
//! scan is `O(capacity)` but only runs on insert-after-full and costs
//! microseconds against the milliseconds of the GEMM it saves.

use std::collections::HashMap;

/// Quantized query key: `round(x_i / quant)` per coordinate.
pub type QueryKey = Vec<i64>;

/// Bounded LRU of `query → score`.
pub struct PredictionCache {
    map: HashMap<QueryKey, (f64, u64)>,
    capacity: usize,
    quant: f64,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PredictionCache {
    /// Cache holding at most `capacity` entries, keys quantized with step
    /// `quant` (`quant <= 0` falls back to the default 1e-9).
    pub fn new(capacity: usize, quant: f64) -> Self {
        PredictionCache {
            map: HashMap::with_capacity(capacity.min(4096)),
            capacity,
            quant: if quant > 0.0 { quant } else { 1e-9 },
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Quantize a query vector into a cache key. Each coordinate
    /// contributes a `(tag, value)` pair: tag 0 carries the grid cell
    /// for in-range values; tag 1 carries the raw bit pattern for
    /// coordinates whose quantized magnitude leaves the `i64` grid (or
    /// are non-finite). The tag keeps the two value spaces disjoint —
    /// without it a bit pattern could collide with a legitimate grid
    /// cell and serve one query another query's cached score.
    pub fn key(&self, x: &[f64]) -> QueryKey {
        let inv = 1.0 / self.quant;
        let mut key = Vec::with_capacity(2 * x.len());
        for &v in x {
            let q = (v * inv).round();
            if q.abs() < 9.0e18 {
                key.push(0); // comfortably inside i64's exact cast range
                key.push(q as i64);
            } else {
                key.push(1);
                key.push(v.to_bits() as i64);
            }
        }
        key
    }

    /// Look up a key, refreshing its recency on hit.
    pub fn get(&mut self, key: &[i64]) -> Option<f64> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some((v, last)) => {
                *last = tick;
                self.hits += 1;
                Some(*v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a key, evicting the least-recently-used entry
    /// when at capacity.
    pub fn insert(&mut self, key: QueryKey, value: f64) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            if let Some(oldest) =
                self.map.iter().min_by_key(|(_, (_, t))| *t).map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key, (value, self.tick));
    }

    /// Drop every entry (hit/miss counters survive). Called when a model
    /// is hot-reloaded: cached scores belong to the replaced predictor.
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_insert_and_miss_before() {
        let mut c = PredictionCache::new(8, 1e-9);
        let k = c.key(&[1.0, -2.5]);
        assert_eq!(c.get(&k), None);
        c.insert(k.clone(), 0.75);
        assert_eq!(c.get(&k), Some(0.75));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn nearby_queries_share_a_key_distant_do_not() {
        let c = PredictionCache::new(8, 1e-9);
        // within half a quantum → same cell
        assert_eq!(c.key(&[1.0, 2.0]), c.key(&[1.0 + 4e-10, 2.0 - 4e-10]));
        // two quanta away → different cell
        assert_ne!(c.key(&[1.0, 2.0]), c.key(&[1.0 + 2e-9, 2.0]));
        // and real-world-distinct points are far apart on the grid
        assert_ne!(c.key(&[1.0, 2.0]), c.key(&[1.001, 2.0]));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = PredictionCache::new(2, 1.0);
        let (ka, kb, kc) = (vec![1], vec![2], vec![3]);
        c.insert(ka.clone(), 1.0);
        c.insert(kb.clone(), 2.0);
        assert_eq!(c.get(&ka), Some(1.0)); // refresh a → b is now LRU
        c.insert(kc.clone(), 3.0); // evicts b
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&kb), None);
        assert_eq!(c.get(&ka), Some(1.0));
        assert_eq!(c.get(&kc), Some(3.0));
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = PredictionCache::new(2, 1.0);
        c.insert(vec![1], 1.0);
        c.insert(vec![2], 2.0);
        c.insert(vec![1], 1.5); // same key: refresh, no eviction
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&[1]), Some(1.5));
        assert_eq!(c.get(&[2]), Some(2.0));
    }

    #[test]
    fn clear_empties_but_keeps_counters() {
        let mut c = PredictionCache::new(4, 1.0);
        c.insert(vec![1], 1.0);
        assert_eq!(c.get(&[1]), Some(1.0));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&[1]), None);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn zero_capacity_disables_storage() {
        let mut c = PredictionCache::new(0, 1.0);
        c.insert(vec![1], 1.0);
        assert!(c.is_empty());
        assert_eq!(c.get(&[1]), None);
    }

    #[test]
    fn extreme_inputs_stay_distinguishable() {
        let c = PredictionCache::new(4, 1e-9);
        // off-grid magnitudes must NOT collapse onto a shared key
        assert_ne!(c.key(&[1e10]), c.key(&[2e10]));
        assert_ne!(c.key(&[f64::MAX]), c.key(&[f64::MAX / 2.0]));
        assert_ne!(c.key(&[1e300]), c.key(&[-1e300]));
        assert_eq!(c.key(&[0.0]), vec![0, 0]);
        // and a huge value still equals itself
        assert_eq!(c.key(&[1e10]), c.key(&[1e10]));
        // the off-grid bit-pattern space is tagged apart from the grid
        // space, so it cannot alias a legitimately quantized coordinate
        let off_grid = c.key(&[1e10]);
        assert_eq!(off_grid[0], 1);
        let bits_as_grid_value = off_grid[1] as f64 * 1e-9;
        assert_ne!(off_grid, c.key(&[bits_as_grid_value]));
    }
}
