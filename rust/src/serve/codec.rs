//! Binary model artifact codec: the large-M companion to the JSON format.
//!
//! The JSON artifact ([`crate::serve::model_store`]) prints every `f64`
//! of the `M × d` center matrix and the `α` vector as shortest
//! round-trip decimal text (~20 bytes per value) and re-parses it on
//! load — exactly the wrong trade once BLESS makes large-M models cheap
//! to fit. This module defines a versioned, checksummed little-endian
//! binary layout that stores each `f64` as its raw 8 bit-pattern bytes:
//! load is a bounds-checked `memcpy`, the roundtrip is bit-exact by
//! construction (NaN payloads, −0.0 and subnormals included), and the
//! artifact is a fraction of the JSON size.
//!
//! ## Layout (all integers little-endian)
//!
//! ```text
//! offset        size  field
//! 0             8     magic  b"BLESSBIN"
//! 8             4     format version (u32, currently 1)
//! 12            4     reserved flags (u32, written 0, ignored on read)
//! 16            8     sigma (f64 bit pattern)
//! 24            8     m  — number of centers (u64)
//! 32            8     d  — feature dimension (u64)
//! 40            8     trained_n (u64)
//! 48            4     dataset tag length L (u32)
//! 52            L     dataset tag (UTF-8)
//! 52+L          8·m   α section        (f64 bit patterns)
//! 52+L+8m       8·m·d center rows, row-major (f64 bit patterns)
//! end−8         8     FNV-1a 64 checksum over every preceding byte
//! ```
//!
//! [`Format::detect`] sniffs the magic so `ModelArtifact::load` reads
//! either encoding from any path; [`Format::from_path`] picks the
//! encoding `save` writes (`.bin` / `.bless` → binary, anything else →
//! JSON, so small models stay human-readable).
//!
//! Truncated files, flipped bits, a wrong magic and an unknown version
//! all fail with a clean error — never a panic, never a partial model.

use crate::linalg::Matrix;
use crate::serve::model_store::ModelArtifact;
use std::path::Path;

/// Leading magic bytes of a binary artifact.
pub const MAGIC: [u8; 8] = *b"BLESSBIN";
/// Current binary layout version. Bump on incompatible changes.
pub const BINARY_VERSION: u32 = 1;

/// Fixed-size part of the header (through the dataset-length field).
const HEADER_LEN: usize = 52;
/// Smallest syntactically possible artifact: header + checksum.
const MIN_LEN: usize = HEADER_LEN + 8;

/// On-disk artifact encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Human-readable JSON (the PR-1 format; good for small M).
    Json,
    /// Raw little-endian binary (this module; good for large M).
    Binary,
}

impl Format {
    /// Encoding chosen by file extension — what `save` writes.
    pub fn from_path(path: &Path) -> Format {
        match path.extension().and_then(|e| e.to_str()) {
            Some("bin") | Some("bless") => Format::Binary,
            _ => Format::Json,
        }
    }

    /// Encoding sniffed from leading file bytes — what `load` reads.
    /// Anything that does not start with the binary magic is treated as
    /// JSON (whose parser then reports its own errors).
    pub fn detect(bytes: &[u8]) -> Format {
        if bytes.starts_with(&MAGIC) {
            Format::Binary
        } else {
            Format::Json
        }
    }
}

/// FNV-1a 64-bit over a byte slice.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Serialize an artifact into the binary layout (header + raw f64
/// sections + trailing checksum). Infallible: any in-memory artifact
/// has a representation, including non-finite values — finiteness
/// policy lives in `ModelArtifact::validate`, not in the codec.
pub fn encode(art: &ModelArtifact) -> Vec<u8> {
    let name = art.dataset.as_bytes();
    let values = art.alpha.len() + art.centers.as_slice().len();
    let mut out = Vec::with_capacity(HEADER_LEN + name.len() + 8 * values + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&BINARY_VERSION.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // reserved flags
    out.extend_from_slice(&art.sigma.to_bits().to_le_bytes());
    out.extend_from_slice(&(art.m() as u64).to_le_bytes());
    out.extend_from_slice(&(art.d() as u64).to_le_bytes());
    out.extend_from_slice(&(art.trained_n as u64).to_le_bytes());
    out.extend_from_slice(&(name.len() as u32).to_le_bytes());
    out.extend_from_slice(name);
    for &v in &art.alpha {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    for &v in art.centers.as_slice() {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Bounds-checked cursor over the payload bytes.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow::anyhow!("truncated binary artifact (at byte {})", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_section(&mut self, count: usize) -> anyhow::Result<Vec<f64>> {
        let bytes = count
            .checked_mul(8)
            .ok_or_else(|| anyhow::anyhow!("binary artifact section overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

/// Decode a binary artifact. Checks, in order: magic, minimum length,
/// checksum over the full payload, layout version, header/section
/// shape consistency against the actual byte count. Does **not** apply
/// the finiteness policy — `ModelArtifact::load` does that — so the
/// codec itself roundtrips NaN, −0.0 and subnormal payloads bit-exactly.
pub fn decode(bytes: &[u8]) -> anyhow::Result<ModelArtifact> {
    anyhow::ensure!(
        bytes.starts_with(&MAGIC),
        "not a binary model artifact (bad magic; want {:?})",
        std::str::from_utf8(&MAGIC).unwrap()
    );
    anyhow::ensure!(
        bytes.len() >= MIN_LEN,
        "truncated binary artifact: {} bytes, header alone needs {MIN_LEN}",
        bytes.len()
    );
    let (payload, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    let computed = fnv1a(payload);
    anyhow::ensure!(
        stored == computed,
        "checksum mismatch (stored {stored:016x}, computed {computed:016x}) — artifact corrupted"
    );

    let mut r = Reader { b: payload, i: MAGIC.len() };
    let version = r.u32()?;
    anyhow::ensure!(
        version == BINARY_VERSION,
        "unsupported binary artifact version {version} (this build reads version {BINARY_VERSION})"
    );
    let _flags = r.u32()?;
    let sigma = f64::from_bits(r.u64()?);
    let m = r.u64()? as usize;
    let d = r.u64()? as usize;
    let trained_n = r.u64()? as usize;
    let name_len = r.u32()? as usize;
    let dataset = String::from_utf8(r.take(name_len)?.to_vec())
        .map_err(|_| anyhow::anyhow!("dataset tag is not valid UTF-8"))?;

    let cells = m
        .checked_mul(d)
        .ok_or_else(|| anyhow::anyhow!("binary artifact header overflow: m={m} d={d}"))?;
    let body = m
        .checked_add(cells)
        .and_then(|v| v.checked_mul(8))
        .ok_or_else(|| anyhow::anyhow!("binary artifact header overflow: m={m} d={d}"))?;
    anyhow::ensure!(
        payload.len() - r.i == body,
        "binary artifact length mismatch: {} section bytes for m={m} d={d} (want {body})",
        payload.len() - r.i
    );
    let alpha = r.f64_section(m)?;
    let data = r.f64_section(cells)?;
    Ok(ModelArtifact { sigma, centers: Matrix::from_vec(m, d, data), alpha, trained_n, dataset })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model_store::Predictor;

    /// Deterministic artifact with full-mantissa (trained-weight-like)
    /// values: every value is an irrational-ish expression so its decimal
    /// form needs the whole 17 significant digits.
    fn dense_artifact(m: usize, d: usize) -> ModelArtifact {
        ModelArtifact {
            sigma: std::f64::consts::PI,
            centers: Matrix::from_fn(m, d, |i, j| {
                ((i * d + j) as f64 * 0.618_033_988_749_894_9).sin() * 2.5
            }),
            alpha: (0..m).map(|i| (i as f64 * 1.414_213_562_373_095_1).cos() * 1e-3).collect(),
            trained_n: 12_345,
            dataset: "dense-test".to_string(),
        }
    }

    #[test]
    fn binary_roundtrip_is_bit_exact() {
        let art = dense_artifact(37, 5);
        let back = decode(&encode(&art)).unwrap();
        assert_eq!(back.m(), 37);
        assert_eq!(back.d(), 5);
        assert_eq!(back.trained_n, 12_345);
        assert_eq!(back.dataset, "dense-test");
        assert_eq!(back.sigma.to_bits(), art.sigma.to_bits());
        for (a, b) in art.alpha.iter().zip(&back.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in art.centers.as_slice().iter().zip(back.centers.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn binary_matches_json_predictions_bit_exactly() {
        let art = dense_artifact(23, 4);
        let via_bin = decode(&encode(&art)).unwrap();
        let via_json = ModelArtifact::from_json(&art.to_json()).unwrap();
        let q = Matrix::from_fn(9, 4, |i, j| ((i * 4 + j) as f64 * 0.37).cos());
        let a = Predictor::new(&via_bin).predict_batch(&q).unwrap();
        let b = Predictor::new(&via_json).predict_batch(&q).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "codec paths disagree: {x} vs {y}");
        }
    }

    #[test]
    fn nan_negative_zero_and_subnormals_survive_the_codec() {
        let mut art = dense_artifact(4, 3);
        // a NaN with a distinctive payload, −0.0 and a subnormal: the
        // codec must carry all three bit patterns through untouched
        let weird_nan = f64::from_bits(0x7ff8_dead_beef_0001);
        art.alpha[0] = weird_nan;
        art.alpha[1] = -0.0;
        art.alpha[2] = f64::from_bits(1); // smallest positive subnormal
        art.centers.set(0, 0, f64::NEG_INFINITY);
        art.centers.set(1, 1, -4.9e-324_f64);
        let back = decode(&encode(&art)).unwrap();
        assert_eq!(back.alpha[0].to_bits(), weird_nan.to_bits());
        assert_eq!(back.alpha[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(back.alpha[2].to_bits(), 1);
        assert_eq!(back.centers.get(0, 0).to_bits(), f64::NEG_INFINITY.to_bits());
        assert_eq!(back.centers.get(1, 1).to_bits(), (-4.9e-324_f64).to_bits());
    }

    #[test]
    fn truncated_artifact_errors_cleanly() {
        let full = encode(&dense_artifact(6, 3));
        for cut in [0, 4, MIN_LEN - 1, full.len() / 2, full.len() - 1] {
            let err = decode(&full[..cut]).unwrap_err().to_string();
            assert!(
                err.contains("truncated")
                    || err.contains("checksum")
                    || err.contains("bad magic")
                    || err.contains("length mismatch"),
                "cut at {cut}: unexpected error {err}"
            );
        }
    }

    #[test]
    fn bit_flip_fails_checksum() {
        let mut bytes = encode(&dense_artifact(6, 3));
        let mid = HEADER_LEN + 20; // inside the α section
        bytes[mid] ^= 0x40;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode(&dense_artifact(4, 2));
        bytes[0] = b'X';
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "unexpected error: {err}");
        // and a JSON artifact fed to the binary decoder is a magic error
        let json = dense_artifact(4, 2).to_json().to_string();
        assert!(decode(json.as_bytes()).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut bytes = encode(&dense_artifact(4, 2));
        bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
        // keep the checksum honest so the *version* check is what fires
        let n = bytes.len();
        let sum = fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 9"), "unexpected error: {err}");
    }

    #[test]
    fn header_section_mismatch_rejected() {
        let mut bytes = encode(&dense_artifact(4, 2));
        // claim m=5 while the sections still hold m=4 worth of values
        bytes[24..32].copy_from_slice(&5u64.to_le_bytes());
        let n = bytes.len();
        let sum = fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("length mismatch"), "unexpected error: {err}");
    }

    #[test]
    fn format_detection_by_path_and_magic() {
        assert_eq!(Format::from_path(Path::new("m.bin")), Format::Binary);
        assert_eq!(Format::from_path(Path::new("m.bless")), Format::Binary);
        assert_eq!(Format::from_path(Path::new("m.json")), Format::Json);
        assert_eq!(Format::from_path(Path::new("model")), Format::Json);
        assert_eq!(Format::detect(&encode(&dense_artifact(2, 2))), Format::Binary);
        assert_eq!(Format::detect(b"{\"format\":\"bless-falkon-model\"}"), Format::Json);
        assert_eq!(Format::detect(b""), Format::Json);
    }

    #[test]
    fn binary_is_smaller_than_json_on_dense_values() {
        let art = dense_artifact(64, 8);
        let bin = encode(&art).len();
        let json = art.to_json().to_string().len();
        assert!(
            json >= 2 * bin,
            "binary not smaller: {bin} bytes binary vs {json} bytes JSON"
        );
    }
}
