//! Wire protocol: line-delimited JSON over TCP.
//!
//! One request per line, one response line per request, in order. The
//! serialized forms never contain raw newlines ([`Json`]'s `Display`
//! escapes them inside strings), so framing is a plain `\n` split.
//!
//! ```text
//! → {"id":1,"x":[0.12,-1.4,…]}        predict one point
//! ← {"id":1,"y":0.8315,"cached":false}
//! → {"op":"stats"}                    server counters
//! ← {"requests":128,"batches":19,"mean_batch":6.7,…}
//! → {"op":"ping"}                     liveness
//! ← {"ok":true}
//! → {"op":"shutdown"}                 graceful stop
//! ← {"ok":true}
//! ```
//!
//! Malformed lines get `{"error":"…"}` and the connection stays open.
//!
//! Numbers ride JSON's `f64` lane, so correlation `id`s (and counters)
//! are exact only up to 2⁵³ — the standard JSON interop bound. Clients
//! should use sequential or bounded ids, not random full-range `u64`s.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Score one query point.
    Predict {
        /// Client-chosen correlation id, echoed back in the response.
        id: u64,
        /// The query row.
        x: Vec<f64>,
    },
    /// Report server counters.
    Stats,
    /// Liveness probe.
    Ping,
    /// Graceful server stop.
    Shutdown,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> anyhow::Result<Request> {
        let j = Json::parse(line)?;
        anyhow::ensure!(j.as_obj().is_some(), "request must be a JSON object");
        if let Some(op) = j.get("op").and_then(|v| v.as_str()) {
            return match op {
                "stats" => Ok(Request::Stats),
                "ping" => Ok(Request::Ping),
                "shutdown" => Ok(Request::Shutdown),
                other => anyhow::bail!("unknown op {other:?}"),
            };
        }
        let x_j = j
            .get("x")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("predict request needs an \"x\" array"))?;
        anyhow::ensure!(!x_j.is_empty(), "empty query vector");
        let mut x = Vec::with_capacity(x_j.len());
        for v in x_j {
            let f = v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric query entry"))?;
            anyhow::ensure!(f.is_finite(), "non-finite query entry");
            x.push(f);
        }
        let id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        Ok(Request::Predict { id, x })
    }

    /// Serialize a request to its wire line (no trailing newline) —
    /// used by clients and tests.
    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        match self {
            Request::Predict { id, x } => {
                obj.insert("id".to_string(), Json::Num(*id as f64));
                obj.insert(
                    "x".to_string(),
                    Json::Arr(x.iter().map(|&v| Json::Num(v)).collect()),
                );
            }
            Request::Stats => {
                obj.insert("op".to_string(), Json::Str("stats".to_string()));
            }
            Request::Ping => {
                obj.insert("op".to_string(), Json::Str("ping".to_string()));
            }
            Request::Shutdown => {
                obj.insert("op".to_string(), Json::Str("shutdown".to_string()));
            }
        }
        Json::Obj(obj).to_string()
    }
}

/// Point-in-time server counters, as reported over the wire.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Predict requests accepted.
    pub requests: u64,
    /// Batches executed by the engine workers.
    pub batches: u64,
    /// Total requests answered through batches (`batched / batches` =
    /// mean batch size).
    pub batched: u64,
    /// Requests answered from the prediction cache.
    pub cache_hits: u64,
    /// Requests rejected with an error response.
    pub errors: u64,
    /// Total predict latency in microseconds (enqueue → reply).
    pub latency_us: u64,
}

impl StatsSnapshot {
    /// Mean coalesced batch size (0 when no batch has run).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched as f64 / self.batches as f64
        }
    }

    /// Mean enqueue→reply latency in microseconds (0 when idle).
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.latency_us as f64 / self.requests as f64
        }
    }

    /// Serialize to the wire line. The exact `latency_us` total goes on
    /// the wire (the derived `mean_*` fields are for humans) so a parsed
    /// snapshot reproduces the server's counters without drift.
    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("requests".to_string(), Json::Num(self.requests as f64));
        obj.insert("batches".to_string(), Json::Num(self.batches as f64));
        obj.insert("batched".to_string(), Json::Num(self.batched as f64));
        obj.insert("mean_batch".to_string(), Json::Num(self.mean_batch()));
        obj.insert("cache_hits".to_string(), Json::Num(self.cache_hits as f64));
        obj.insert("errors".to_string(), Json::Num(self.errors as f64));
        obj.insert("latency_us".to_string(), Json::Num(self.latency_us as f64));
        obj.insert("mean_latency_us".to_string(), Json::Num(self.mean_latency_us()));
        Json::Obj(obj).to_string()
    }

    /// Parse a stats response line (client side).
    pub fn parse(line: &str) -> anyhow::Result<StatsSnapshot> {
        let j = Json::parse(line)?;
        let field = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        Ok(StatsSnapshot {
            requests: field("requests"),
            batches: field("batches"),
            batched: field("batched"),
            cache_hits: field("cache_hits"),
            errors: field("errors"),
            latency_us: field("latency_us"),
        })
    }
}

/// Serialize a successful prediction response.
pub fn predict_response(id: u64, y: f64, cached: bool) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert("y".to_string(), Json::Num(y));
    obj.insert("cached".to_string(), Json::Bool(cached));
    Json::Obj(obj).to_string()
}

/// Serialize an error response (with the correlation id when known).
pub fn error_response(id: Option<u64>, message: &str) -> String {
    let mut obj = BTreeMap::new();
    if let Some(id) = id {
        obj.insert("id".to_string(), Json::Num(id as f64));
    }
    obj.insert("error".to_string(), Json::Str(message.to_string()));
    Json::Obj(obj).to_string()
}

/// Serialize the bare-acknowledgement response (ping/shutdown).
pub fn ok_response() -> String {
    let mut obj = BTreeMap::new();
    obj.insert("ok".to_string(), Json::Bool(true));
    Json::Obj(obj).to_string()
}

/// Parse a prediction response line (client side): `(id, score, cached)`.
pub fn parse_predict_response(line: &str) -> anyhow::Result<(u64, f64, bool)> {
    let j = Json::parse(line)?;
    if let Some(err) = j.get("error").and_then(|v| v.as_str()) {
        anyhow::bail!("server error: {err}");
    }
    let id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let y = j
        .get("y")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("response missing \"y\": {line}"))?;
    let cached = matches!(j.get("cached"), Some(Json::Bool(true)));
    Ok((id, y, cached))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_request_round_trips() {
        let req = Request::Predict { id: 42, x: vec![0.5, -1.25, 3.0] };
        let line = req.to_line();
        assert!(!line.contains('\n'));
        assert_eq!(Request::parse(&line).unwrap(), req);
    }

    #[test]
    fn ops_round_trip() {
        for req in [Request::Stats, Request::Ping, Request::Shutdown] {
            assert_eq!(Request::parse(&req.to_line()).unwrap(), req);
        }
    }

    #[test]
    fn malformed_requests_are_errors() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("[1,2]").is_err());
        assert!(Request::parse("{\"op\":\"nope\"}").is_err());
        assert!(Request::parse("{\"x\":[]}").is_err());
        assert!(Request::parse("{\"x\":[1,\"two\"]}").is_err());
        assert!(Request::parse("{\"id\":1}").is_err());
    }

    #[test]
    fn responses_parse_back() {
        let (id, y, cached) = parse_predict_response(&predict_response(7, 0.125, true)).unwrap();
        assert_eq!(id, 7);
        assert_eq!(y, 0.125);
        assert!(cached);
        assert!(parse_predict_response(&error_response(Some(7), "boom")).is_err());
        assert!(parse_predict_response(&ok_response()).is_err());
    }

    #[test]
    fn stats_line_round_trips_counts() {
        let s = StatsSnapshot {
            requests: 100,
            batches: 20,
            batched: 100,
            cache_hits: 3,
            errors: 1,
            latency_us: 12_000,
        };
        let line = s.to_line();
        let back = StatsSnapshot::parse(&line).unwrap();
        assert_eq!(back.requests, 100);
        assert_eq!(back.batches, 20);
        assert_eq!(back.batched, 100);
        assert_eq!(back.cache_hits, 3);
        assert_eq!(back.errors, 1);
        assert_eq!(back.latency_us, 12_000, "exact total must survive the wire");
        assert!((back.mean_batch() - 5.0).abs() < 1e-12);
        assert!((back.mean_latency_us() - 120.0).abs() < 1e-12);
    }
}
