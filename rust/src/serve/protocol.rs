//! Wire protocol: line-delimited JSON over TCP.
//!
//! One request per line, one response line per request, in order. The
//! serialized forms never contain raw newlines ([`Json`]'s `Display`
//! escapes them inside strings), so framing is a plain `\n` split.
//!
//! ```text
//! → {"id":1,"x":[0.12,-1.4,…]}              predict (single model, or
//! → {"id":1,"model":"higgs-v2","x":[…]}      routed by name)
//! ← {"id":1,"y":0.8315,"cached":false}
//! → {"op":"stats"}                           aggregate counters
//! → {"op":"stats","model":"higgs-v2"}        one model's counters
//! ← {"requests":128,"batches":19,"mean_batch":6.7,"shed":0,…}
//! → {"op":"admin","cmd":"list"}              loaded models
//! ← {"models":[{"name":"higgs-v2","m":2000,"d":28,"version":1},…]}
//! → {"op":"admin","cmd":"reload","model":"higgs-v2","path":"new.bin"}
//! ← {"ok":true,"model":"higgs-v2","m":2500,"d":28,"version":2}
//! → {"op":"admin","cmd":"add","model":"new","path":"new.bin"}
//! ← {"ok":true,"model":"new","m":2500,"d":28,"version":1}
//! → {"op":"admin","cmd":"remove","model":"old"}
//! ← {"ok":true,"model":"old","removed":true}
//! → {"op":"ping"}                            liveness
//! ← {"ok":true}
//! → {"op":"shutdown"}                        graceful stop
//! ← {"ok":true}
//! ```
//!
//! Client-side, the administrative surface is typed: build an
//! [`AdminRequest`], get an [`AdminResponse`] back — the JSON above is
//! the wire encoding those enums serialize to and parse from.
//!
//! Malformed lines get `{"error":"…","code":"…"}` and the connection
//! stays open. The `code` field is machine-readable: `bad_request`,
//! `unknown_model`, `overloaded` (queue-depth backpressure — retry
//! later), `deadline_exceeded` (the request's `deadline_ms` — or the
//! server's `--default-deadline` — elapsed before a score was ready;
//! retryable), `quarantined` (the model's circuit breaker is open after
//! repeated worker failures; retry after its cooldown), `reload_failed`,
//! `internal`, `shutting_down`.
//!
//! A predict request may carry `"deadline_ms":N` — a per-request
//! completion budget in milliseconds, measured from the moment the
//! server parses the line. Expired requests are answered
//! `deadline_exceeded` instead of occupying a batch slot.
//!
//! Numbers ride JSON's `f64` lane, so correlation `id`s (and counters)
//! are exact only up to 2⁵³ — the standard JSON interop bound. Clients
//! should use sequential or bounded ids, not random full-range `u64`s.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Score one query point.
    Predict {
        /// Client-chosen correlation id, echoed back in the response.
        id: u64,
        /// Target model name; omitted when exactly one model is loaded.
        model: Option<String>,
        /// The query row.
        x: Vec<f64>,
        /// Per-request completion budget in milliseconds; `None` falls
        /// back to the server's default deadline (which may be none).
        deadline_ms: Option<u64>,
    },
    /// Report counters — aggregate, or one model's when `model` is set.
    Stats {
        /// Restrict to one model.
        model: Option<String>,
    },
    /// Liveness probe.
    Ping,
    /// Graceful server stop.
    Shutdown,
    /// Registry administration — see [`AdminRequest`] for the verbs.
    Admin(AdminRequest),
}

/// A typed administrative request. One enum covers every verb that
/// manages or inspects the registry; [`Client::admin`] sends any of
/// them and returns the matching [`AdminResponse`] variant.
///
/// [`Client::admin`]: crate::serve::Client::admin
#[derive(Clone, Debug, PartialEq)]
pub enum AdminRequest {
    /// Hot-reload a model's artifact, atomically swapping its predictor
    /// (from `path` when given, else from the model's recorded source).
    Reload {
        /// Which registry entry to swap.
        model: String,
        /// Optional new artifact path (JSON or binary, auto-detected).
        path: Option<String>,
    },
    /// List the loaded models with shape, version and traffic counters.
    List,
    /// Load an artifact from disk and register it under a new name,
    /// spawning its worker pool — the registry grows at run time.
    Add {
        /// New registry name (must not collide with a loaded model).
        model: String,
        /// Artifact path (JSON or binary, auto-detected).
        path: String,
    },
    /// Unregister a model: its queue is closed (in-flight work drains),
    /// new requests for the name get `unknown_model`.
    Remove {
        /// Which registry entry to drop.
        model: String,
    },
    /// Fetch counters — aggregate, or one model's when `model` is set.
    /// (Rides the `stats` wire op, not the `admin` one.)
    Stats {
        /// Restrict to one model.
        model: Option<String>,
    },
}

impl From<AdminRequest> for Request {
    fn from(req: AdminRequest) -> Request {
        match req {
            // stats predates the admin verb family and keeps its own
            // wire op for compatibility
            AdminRequest::Stats { model } => Request::Stats { model },
            other => Request::Admin(other),
        }
    }
}

/// One model's row in the [`AdminResponse::Models`] listing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelInfo {
    /// Registry name.
    pub name: String,
    /// Number of centers M.
    pub m: usize,
    /// Feature dimension d.
    pub d: usize,
    /// Monotone model version (1 at load, +1 per reload).
    pub version: u64,
    /// Predict requests routed to this model.
    pub requests: u64,
    /// Requests shed by its queue-depth cap.
    pub shed: u64,
}

impl ModelInfo {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        obj.insert("m".to_string(), Json::Num(self.m as f64));
        obj.insert("d".to_string(), Json::Num(self.d as f64));
        obj.insert("version".to_string(), Json::Num(self.version as f64));
        obj.insert("requests".to_string(), Json::Num(self.requests as f64));
        obj.insert("shed".to_string(), Json::Num(self.shed as f64));
        Json::Obj(obj)
    }

    fn from_json(j: &Json) -> anyhow::Result<ModelInfo> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("model entry missing \"name\""))?
            .to_string();
        let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        Ok(ModelInfo {
            name,
            m: num("m") as usize,
            d: num("d") as usize,
            version: num("version"),
            requests: num("requests"),
            shed: num("shed"),
        })
    }
}

/// The typed reply to an [`AdminRequest`]. The server serializes these
/// with [`to_line`](Self::to_line); the client recovers them with
/// [`parse_for`](Self::parse_for) (the expected variant depends on the
/// request sent, and error lines surface as `Err` carrying the wire
/// `code` in square brackets).
#[derive(Clone, Debug, PartialEq)]
pub enum AdminResponse {
    /// A predictor was (re)loaded: the model's shape and new version
    /// (`1` for a fresh [`AdminRequest::Add`]).
    Swapped {
        /// The affected model.
        model: String,
        /// Number of centers M.
        m: usize,
        /// Feature dimension d.
        d: usize,
        /// Version after the swap.
        version: u64,
    },
    /// The registry listing, sorted by name.
    Models(Vec<ModelInfo>),
    /// A model was unregistered.
    Removed {
        /// The dropped model.
        model: String,
    },
    /// Counters, for [`AdminRequest::Stats`].
    Stats(StatsSnapshot),
}

impl AdminResponse {
    /// Serialize to the wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            AdminResponse::Swapped { model, m, d, version } => {
                let mut obj = BTreeMap::new();
                obj.insert("ok".to_string(), Json::Bool(true));
                obj.insert("model".to_string(), Json::Str(model.clone()));
                obj.insert("m".to_string(), Json::Num(*m as f64));
                obj.insert("d".to_string(), Json::Num(*d as f64));
                obj.insert("version".to_string(), Json::Num(*version as f64));
                Json::Obj(obj).to_string()
            }
            AdminResponse::Models(infos) => {
                let mut obj = BTreeMap::new();
                obj.insert(
                    "models".to_string(),
                    Json::Arr(infos.iter().map(ModelInfo::to_json).collect()),
                );
                Json::Obj(obj).to_string()
            }
            AdminResponse::Removed { model } => {
                let mut obj = BTreeMap::new();
                obj.insert("ok".to_string(), Json::Bool(true));
                obj.insert("model".to_string(), Json::Str(model.clone()));
                obj.insert("removed".to_string(), Json::Bool(true));
                Json::Obj(obj).to_string()
            }
            AdminResponse::Stats(s) => s.to_line(),
        }
    }

    /// Parse the reply to `req` (client side). Structured error lines
    /// become `Err("admin request failed [code]: …")`.
    pub fn parse_for(req: &AdminRequest, line: &str) -> anyhow::Result<AdminResponse> {
        let j = Json::parse(line)?;
        if let Some(err) = j.get("error").and_then(|v| v.as_str()) {
            let code = j.get("code").and_then(|v| v.as_str()).unwrap_or("unknown");
            anyhow::bail!("admin request failed [{code}]: {err}");
        }
        match req {
            AdminRequest::Reload { .. } | AdminRequest::Add { .. } => {
                let model = j
                    .get("model")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("admin response missing model: {line}"))?
                    .to_string();
                let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let version = j
                    .get("version")
                    .and_then(|v| v.as_f64())
                    .map(|v| v as u64)
                    .ok_or_else(|| anyhow::anyhow!("admin response missing version: {line}"))?;
                Ok(AdminResponse::Swapped {
                    model,
                    m: num("m") as usize,
                    d: num("d") as usize,
                    version,
                })
            }
            AdminRequest::List => {
                let arr = j
                    .get("models")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("bad admin list response: {line}"))?;
                let infos = arr.iter().map(ModelInfo::from_json).collect::<Result<_, _>>()?;
                Ok(AdminResponse::Models(infos))
            }
            AdminRequest::Remove { .. } => {
                let model = j
                    .get("model")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("admin response missing model: {line}"))?
                    .to_string();
                Ok(AdminResponse::Removed { model })
            }
            AdminRequest::Stats { .. } => Ok(AdminResponse::Stats(StatsSnapshot::parse(line)?)),
        }
    }
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> anyhow::Result<Request> {
        let j = Json::parse(line)?;
        anyhow::ensure!(j.as_obj().is_some(), "request must be a JSON object");
        if let Some(op) = j.get("op").and_then(|v| v.as_str()) {
            return match op {
                "stats" => Ok(Request::Stats {
                    model: j.get("model").and_then(|v| v.as_str()).map(str::to_string),
                }),
                "ping" => Ok(Request::Ping),
                "shutdown" => Ok(Request::Shutdown),
                "admin" => {
                    let cmd = j
                        .get("cmd")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow::anyhow!("admin request needs a \"cmd\""))?;
                    let model = |verb: &str| {
                        j.get("model")
                            .and_then(|v| v.as_str())
                            .map(str::to_string)
                            .ok_or_else(|| {
                                anyhow::anyhow!("admin {verb} needs a \"model\" name")
                            })
                    };
                    let admin = match cmd {
                        "reload" => AdminRequest::Reload {
                            model: model("reload")?,
                            path: j.get("path").and_then(|v| v.as_str()).map(str::to_string),
                        },
                        "list" => AdminRequest::List,
                        "add" => AdminRequest::Add {
                            model: model("add")?,
                            path: j
                                .get("path")
                                .and_then(|v| v.as_str())
                                .map(str::to_string)
                                .ok_or_else(|| {
                                    anyhow::anyhow!("admin add needs an artifact \"path\"")
                                })?,
                        },
                        "remove" => AdminRequest::Remove { model: model("remove")? },
                        other => anyhow::bail!("unknown admin cmd {other:?}"),
                    };
                    Ok(Request::Admin(admin))
                }
                other => anyhow::bail!("unknown op {other:?}"),
            };
        }
        let x_j = j
            .get("x")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("predict request needs an \"x\" array"))?;
        anyhow::ensure!(!x_j.is_empty(), "empty query vector");
        let mut x = Vec::with_capacity(x_j.len());
        for v in x_j {
            let f = v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric query entry"))?;
            anyhow::ensure!(f.is_finite(), "non-finite query entry");
            x.push(f);
        }
        let id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let model = j.get("model").and_then(|v| v.as_str()).map(str::to_string);
        let deadline_ms = match j.get("deadline_ms").and_then(|v| v.as_f64()) {
            Some(ms) => {
                anyhow::ensure!(
                    ms.is_finite() && ms >= 1.0,
                    "deadline_ms must be a positive number of milliseconds"
                );
                Some(ms as u64)
            }
            None => None,
        };
        Ok(Request::Predict { id, model, x, deadline_ms })
    }

    /// Serialize a request to its wire line (no trailing newline) —
    /// used by clients and tests.
    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        match self {
            Request::Predict { id, model, x, deadline_ms } => {
                obj.insert("id".to_string(), Json::Num(*id as f64));
                if let Some(m) = model {
                    obj.insert("model".to_string(), Json::Str(m.clone()));
                }
                if let Some(ms) = deadline_ms {
                    obj.insert("deadline_ms".to_string(), Json::Num(*ms as f64));
                }
                obj.insert(
                    "x".to_string(),
                    Json::Arr(x.iter().map(|&v| Json::Num(v)).collect()),
                );
            }
            Request::Stats { model } => {
                obj.insert("op".to_string(), Json::Str("stats".to_string()));
                if let Some(m) = model {
                    obj.insert("model".to_string(), Json::Str(m.clone()));
                }
            }
            Request::Ping => {
                obj.insert("op".to_string(), Json::Str("ping".to_string()));
            }
            Request::Shutdown => {
                obj.insert("op".to_string(), Json::Str("shutdown".to_string()));
            }
            Request::Admin(admin) => {
                match admin {
                    // stats sugar keeps its historical wire op
                    AdminRequest::Stats { model } => {
                        obj.insert("op".to_string(), Json::Str("stats".to_string()));
                        if let Some(m) = model {
                            obj.insert("model".to_string(), Json::Str(m.clone()));
                        }
                        return Json::Obj(obj).to_string();
                    }
                    AdminRequest::Reload { model, path } => {
                        obj.insert("cmd".to_string(), Json::Str("reload".to_string()));
                        obj.insert("model".to_string(), Json::Str(model.clone()));
                        if let Some(p) = path {
                            obj.insert("path".to_string(), Json::Str(p.clone()));
                        }
                    }
                    AdminRequest::List => {
                        obj.insert("cmd".to_string(), Json::Str("list".to_string()));
                    }
                    AdminRequest::Add { model, path } => {
                        obj.insert("cmd".to_string(), Json::Str("add".to_string()));
                        obj.insert("model".to_string(), Json::Str(model.clone()));
                        obj.insert("path".to_string(), Json::Str(path.clone()));
                    }
                    AdminRequest::Remove { model } => {
                        obj.insert("cmd".to_string(), Json::Str("remove".to_string()));
                        obj.insert("model".to_string(), Json::Str(model.clone()));
                    }
                }
                obj.insert("op".to_string(), Json::Str("admin".to_string()));
            }
        }
        Json::Obj(obj).to_string()
    }
}

/// Point-in-time server counters, as reported over the wire — either one
/// model's, or the sum across the registry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Predict requests accepted.
    pub requests: u64,
    /// Batches executed by the engine workers.
    pub batches: u64,
    /// Total requests answered through batches (`batched / batches` =
    /// mean batch size).
    pub batched: u64,
    /// Requests answered from the prediction cache.
    pub cache_hits: u64,
    /// Requests rejected with an error response.
    pub errors: u64,
    /// Requests shed by queue-depth backpressure (`overloaded` replies;
    /// counted separately from `errors`).
    pub shed: u64,
    /// Hot reloads applied (per model; summed in the aggregate view).
    pub reloads: u64,
    /// Requests answered `deadline_exceeded` (expired in queue or timed
    /// out waiting for the batch result).
    pub deadline_exceeded: u64,
    /// Requests refused `quarantined` (circuit breaker open).
    pub quarantined: u64,
    /// Worker panics caught and isolated by the supervisor.
    pub worker_panics: u64,
    /// Supervised worker respawns after a panic.
    pub worker_respawns: u64,
    /// Retrained candidates promoted into this entry after passing the
    /// holdout gate (lifecycle tier; summed in the aggregate view).
    pub promotions: u64,
    /// Promotions undone because the breaker tripped inside the
    /// probation window — the previous artifact was swapped back.
    pub rollbacks: u64,
    /// Total predict latency in microseconds (enqueue → reply).
    pub latency_us: u64,
    /// Median predict latency in microseconds, from the server-side
    /// histogram (0 when idle or talking to a pre-histogram server).
    pub latency_p50_us: f64,
    /// 95th-percentile predict latency in microseconds.
    pub latency_p95_us: f64,
    /// 99th-percentile predict latency in microseconds.
    pub latency_p99_us: f64,
    /// Median coalesced batch size.
    pub batch_p50: f64,
    /// 95th-percentile coalesced batch size.
    pub batch_p95: f64,
    /// 99th-percentile coalesced batch size.
    pub batch_p99: f64,
}

impl StatsSnapshot {
    /// Mean coalesced batch size (0 when no batch has run).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched as f64 / self.batches as f64
        }
    }

    /// Mean enqueue→reply latency in microseconds (0 when idle).
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.latency_us as f64 / self.requests as f64
        }
    }

    /// Accumulate another snapshot (registry aggregation). Sums only the
    /// `u64` counters — percentiles don't add, so the aggregation path in
    /// the registry recomputes them from merged histograms instead.
    pub fn add(&mut self, other: &StatsSnapshot) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.batched += other.batched;
        self.cache_hits += other.cache_hits;
        self.errors += other.errors;
        self.shed += other.shed;
        self.reloads += other.reloads;
        self.deadline_exceeded += other.deadline_exceeded;
        self.quarantined += other.quarantined;
        self.worker_panics += other.worker_panics;
        self.worker_respawns += other.worker_respawns;
        self.promotions += other.promotions;
        self.rollbacks += other.rollbacks;
        self.latency_us += other.latency_us;
    }

    /// Serialize to the wire line. The exact `latency_us` total goes on
    /// the wire (the derived `mean_*` fields are for humans) so a parsed
    /// snapshot reproduces the server's counters without drift.
    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("requests".to_string(), Json::Num(self.requests as f64));
        obj.insert("batches".to_string(), Json::Num(self.batches as f64));
        obj.insert("batched".to_string(), Json::Num(self.batched as f64));
        obj.insert("mean_batch".to_string(), Json::Num(self.mean_batch()));
        obj.insert("cache_hits".to_string(), Json::Num(self.cache_hits as f64));
        obj.insert("errors".to_string(), Json::Num(self.errors as f64));
        obj.insert("shed".to_string(), Json::Num(self.shed as f64));
        obj.insert("reloads".to_string(), Json::Num(self.reloads as f64));
        obj.insert(
            "deadline_exceeded".to_string(),
            Json::Num(self.deadline_exceeded as f64),
        );
        obj.insert("quarantined".to_string(), Json::Num(self.quarantined as f64));
        obj.insert("worker_panics".to_string(), Json::Num(self.worker_panics as f64));
        obj.insert("worker_respawns".to_string(), Json::Num(self.worker_respawns as f64));
        obj.insert("promotions".to_string(), Json::Num(self.promotions as f64));
        obj.insert("rollbacks".to_string(), Json::Num(self.rollbacks as f64));
        obj.insert("latency_us".to_string(), Json::Num(self.latency_us as f64));
        obj.insert("mean_latency_us".to_string(), Json::Num(self.mean_latency_us()));
        obj.insert("latency_p50_us".to_string(), Json::Num(self.latency_p50_us));
        obj.insert("latency_p95_us".to_string(), Json::Num(self.latency_p95_us));
        obj.insert("latency_p99_us".to_string(), Json::Num(self.latency_p99_us));
        obj.insert("batch_p50".to_string(), Json::Num(self.batch_p50));
        obj.insert("batch_p95".to_string(), Json::Num(self.batch_p95));
        obj.insert("batch_p99".to_string(), Json::Num(self.batch_p99));
        Json::Obj(obj).to_string()
    }

    /// Parse a stats response line (client side). Fields absent on the
    /// wire (older servers) read as 0.
    pub fn parse(line: &str) -> anyhow::Result<StatsSnapshot> {
        let j = Json::parse(line)?;
        let field = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let ffield = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        Ok(StatsSnapshot {
            requests: field("requests"),
            batches: field("batches"),
            batched: field("batched"),
            cache_hits: field("cache_hits"),
            errors: field("errors"),
            shed: field("shed"),
            reloads: field("reloads"),
            deadline_exceeded: field("deadline_exceeded"),
            quarantined: field("quarantined"),
            worker_panics: field("worker_panics"),
            worker_respawns: field("worker_respawns"),
            promotions: field("promotions"),
            rollbacks: field("rollbacks"),
            latency_us: field("latency_us"),
            latency_p50_us: ffield("latency_p50_us"),
            latency_p95_us: ffield("latency_p95_us"),
            latency_p99_us: ffield("latency_p99_us"),
            batch_p50: ffield("batch_p50"),
            batch_p95: ffield("batch_p95"),
            batch_p99: ffield("batch_p99"),
        })
    }
}

/// Serialize a successful prediction response.
pub fn predict_response(id: u64, y: f64, cached: bool) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert("y".to_string(), Json::Num(y));
    obj.insert("cached".to_string(), Json::Bool(cached));
    Json::Obj(obj).to_string()
}

/// Serialize an error response: a human-readable `error` message, a
/// machine-readable `code`, and the correlation id when known.
pub fn error_response(id: Option<u64>, code: &str, message: &str) -> String {
    let mut obj = BTreeMap::new();
    if let Some(id) = id {
        obj.insert("id".to_string(), Json::Num(id as f64));
    }
    obj.insert("code".to_string(), Json::Str(code.to_string()));
    obj.insert("error".to_string(), Json::Str(message.to_string()));
    Json::Obj(obj).to_string()
}

/// Serialize the bare-acknowledgement response (ping/shutdown).
pub fn ok_response() -> String {
    let mut obj = BTreeMap::new();
    obj.insert("ok".to_string(), Json::Bool(true));
    Json::Obj(obj).to_string()
}

/// Parse a prediction response line (client side): `(id, score, cached)`.
/// Error replies surface as `Err` whose message carries the wire `code`
/// in square brackets (e.g. `server error [overloaded]: …`).
pub fn parse_predict_response(line: &str) -> anyhow::Result<(u64, f64, bool)> {
    let j = Json::parse(line)?;
    if let Some(err) = j.get("error").and_then(|v| v.as_str()) {
        let code = j.get("code").and_then(|v| v.as_str()).unwrap_or("unknown");
        anyhow::bail!("server error [{code}]: {err}");
    }
    let id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let y = j
        .get("y")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("response missing \"y\": {line}"))?;
    let cached = matches!(j.get("cached"), Some(Json::Bool(true)));
    Ok((id, y, cached))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_request_round_trips() {
        let req = Request::Predict {
            id: 42,
            model: None,
            x: vec![0.5, -1.25, 3.0],
            deadline_ms: None,
        };
        let line = req.to_line();
        assert!(!line.contains('\n'));
        assert!(!line.contains("deadline_ms"), "absent deadline stays off the wire");
        assert_eq!(Request::parse(&line).unwrap(), req);

        let routed = Request::Predict {
            id: 7,
            model: Some("higgs-v2".to_string()),
            x: vec![1.0, 2.0],
            deadline_ms: Some(250),
        };
        let line = routed.to_line();
        assert!(line.contains("\"model\":\"higgs-v2\""));
        assert!(line.contains("\"deadline_ms\":250"));
        assert_eq!(Request::parse(&line).unwrap(), routed);
    }

    #[test]
    fn bad_deadlines_are_rejected() {
        assert!(Request::parse("{\"x\":[1],\"deadline_ms\":0}").is_err());
        assert!(Request::parse("{\"x\":[1],\"deadline_ms\":-5}").is_err());
    }

    #[test]
    fn ops_round_trip() {
        for req in [
            Request::Stats { model: None },
            Request::Stats { model: Some("a".to_string()) },
            Request::Ping,
            Request::Shutdown,
            Request::Admin(AdminRequest::Reload { model: "a".to_string(), path: None }),
            Request::Admin(AdminRequest::Reload {
                model: "a".to_string(),
                path: Some("m.bin".to_string()),
            }),
            Request::Admin(AdminRequest::List),
            Request::Admin(AdminRequest::Add {
                model: "b".to_string(),
                path: "b.bin".to_string(),
            }),
            Request::Admin(AdminRequest::Remove { model: "a".to_string() }),
        ] {
            assert_eq!(Request::parse(&req.to_line()).unwrap(), req);
        }
    }

    #[test]
    fn admin_stats_sugar_rides_the_stats_op() {
        // the typed stats verb serializes to the historical wire op, so
        // it parses back as Request::Stats — not Request::Admin
        let typed: Request = AdminRequest::Stats { model: Some("a".to_string()) }.into();
        assert_eq!(typed, Request::Stats { model: Some("a".to_string()) });
        let line = Request::Admin(AdminRequest::Stats { model: None }).to_line();
        assert_eq!(Request::parse(&line).unwrap(), Request::Stats { model: None });
    }

    #[test]
    fn admin_responses_round_trip() {
        let swapped = AdminResponse::Swapped {
            model: "a".to_string(),
            m: 2000,
            d: 28,
            version: 3,
        };
        let req = AdminRequest::Reload { model: "a".to_string(), path: None };
        assert_eq!(AdminResponse::parse_for(&req, &swapped.to_line()).unwrap(), swapped);

        let listing = AdminResponse::Models(vec![ModelInfo {
            name: "a".to_string(),
            m: 5,
            d: 3,
            version: 1,
            requests: 7,
            shed: 2,
        }]);
        assert_eq!(
            AdminResponse::parse_for(&AdminRequest::List, &listing.to_line()).unwrap(),
            listing
        );

        let removed = AdminResponse::Removed { model: "a".to_string() };
        let req = AdminRequest::Remove { model: "a".to_string() };
        assert_eq!(AdminResponse::parse_for(&req, &removed.to_line()).unwrap(), removed);

        let stats = AdminResponse::Stats(StatsSnapshot { requests: 9, ..Default::default() });
        let req = AdminRequest::Stats { model: None };
        assert_eq!(AdminResponse::parse_for(&req, &stats.to_line()).unwrap(), stats);

        // structured error lines surface the code in brackets
        let err = AdminResponse::parse_for(&req, &error_response(None, "unknown_model", "nope"))
            .unwrap_err();
        assert!(err.to_string().contains("[unknown_model]"), "got {err}");
    }

    #[test]
    fn malformed_requests_are_errors() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("[1,2]").is_err());
        assert!(Request::parse("{\"op\":\"nope\"}").is_err());
        assert!(Request::parse("{\"x\":[]}").is_err());
        assert!(Request::parse("{\"x\":[1,\"two\"]}").is_err());
        assert!(Request::parse("{\"id\":1}").is_err());
        assert!(Request::parse("{\"op\":\"admin\"}").is_err());
        assert!(Request::parse("{\"op\":\"admin\",\"cmd\":\"nope\"}").is_err());
        assert!(Request::parse("{\"op\":\"admin\",\"cmd\":\"reload\"}").is_err());
        assert!(Request::parse("{\"op\":\"admin\",\"cmd\":\"add\",\"model\":\"a\"}").is_err());
        assert!(Request::parse("{\"op\":\"admin\",\"cmd\":\"remove\"}").is_err());
    }

    #[test]
    fn responses_parse_back() {
        let (id, y, cached) = parse_predict_response(&predict_response(7, 0.125, true)).unwrap();
        assert_eq!(id, 7);
        assert_eq!(y, 0.125);
        assert!(cached);
        let err = parse_predict_response(&error_response(Some(7), "overloaded", "queue full"))
            .unwrap_err();
        assert!(err.to_string().contains("[overloaded]"), "got {err}");
        assert!(parse_predict_response(&ok_response()).is_err());
    }

    #[test]
    fn stats_line_round_trips_counts() {
        let s = StatsSnapshot {
            requests: 100,
            batches: 20,
            batched: 100,
            cache_hits: 3,
            errors: 1,
            shed: 2,
            reloads: 4,
            deadline_exceeded: 6,
            quarantined: 5,
            worker_panics: 2,
            worker_respawns: 2,
            promotions: 3,
            rollbacks: 1,
            latency_us: 12_000,
            latency_p50_us: 104.0,
            latency_p95_us: 240.5,
            latency_p99_us: 512.0,
            batch_p50: 5.0,
            batch_p95: 8.0,
            batch_p99: 8.0,
        };
        let line = s.to_line();
        let back = StatsSnapshot::parse(&line).unwrap();
        assert_eq!(back, s, "exact counters must survive the wire");
        assert!((back.mean_batch() - 5.0).abs() < 1e-12);
        assert!((back.mean_latency_us() - 120.0).abs() < 1e-12);
    }

    #[test]
    fn stats_aggregation_sums_fields() {
        let mut a = StatsSnapshot { requests: 3, shed: 1, latency_us: 10, ..Default::default() };
        let b = StatsSnapshot {
            requests: 2,
            errors: 4,
            reloads: 1,
            deadline_exceeded: 3,
            quarantined: 2,
            worker_panics: 1,
            worker_respawns: 1,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.requests, 5);
        assert_eq!(a.errors, 4);
        assert_eq!(a.shed, 1);
        assert_eq!(a.reloads, 1);
        assert_eq!(a.deadline_exceeded, 3);
        assert_eq!(a.quarantined, 2);
        assert_eq!(a.worker_panics, 1);
        assert_eq!(a.worker_respawns, 1);
        assert_eq!(a.latency_us, 10);
    }
}
