//! Evaluation metrics: AUC, classification error, RMSE.

/// Area under the ROC curve of `scores` against ±1 `labels`.
///
/// Computed by the rank statistic (Mann–Whitney U) with midrank handling
/// of tied scores — O(n log n).
pub fn auc(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // midranks
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l > 0.0).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum_pos: f64 = (0..n).filter(|&i| labels[i] > 0.0).map(|i| ranks[i]).sum();
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Fraction of sign mismatches between `scores` and ±1 `labels`.
pub fn classification_error(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let wrong = scores
        .iter()
        .zip(labels)
        .filter(|(s, l)| (s.is_sign_positive() as i8 * 2 - 1) as f64 * **l <= 0.0)
        .count();
    wrong as f64 / scores.len() as f64
}

/// Confusion counts `(tp, fp, tn, fn)` at threshold 0.
pub fn confusion(scores: &[f64], labels: &[f64]) -> (usize, usize, usize, usize) {
    let (mut tp, mut fp, mut tn, mut fnn) = (0, 0, 0, 0);
    for (s, l) in scores.iter().zip(labels) {
        match (*s > 0.0, *l > 0.0) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, false) => tn += 1,
            (false, true) => fnn += 1,
        }
    }
    (tp, fp, tn, fnn)
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    let s: f64 = pred.iter().zip(target).map(|(p, t)| (p - t) * (p - t)).sum();
    (s / pred.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_auc() {
        let scores = vec![-2.0, -1.0, 1.0, 2.0];
        let labels = vec![-1.0, -1.0, 1.0, 1.0];
        assert!((auc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_auc_is_zero() {
        let scores = vec![2.0, 1.0, -1.0, -2.0];
        let labels = vec![-1.0, -1.0, 1.0, 1.0];
        assert!(auc(&scores, &labels).abs() < 1e-12);
    }

    #[test]
    fn random_auc_near_half() {
        let mut r = crate::rng::Rng::seeded(0);
        let n = 10_000;
        let scores: Vec<f64> = (0..n).map(|_| r.next_f64()).collect();
        let labels: Vec<f64> =
            (0..n).map(|_| if r.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.02, "auc {a}");
    }

    #[test]
    fn ties_get_midranks() {
        // all scores equal → AUC 0.5 exactly
        let scores = vec![1.0; 6];
        let labels = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn classification_error_counts() {
        let scores = vec![1.0, -1.0, 1.0, -1.0];
        let labels = vec![1.0, 1.0, -1.0, -1.0];
        assert!((classification_error(&scores, &labels) - 0.5).abs() < 1e-12);
        let (tp, fp, tn, fnn) = confusion(&scores, &labels);
        assert_eq!((tp, fp, tn, fnn), (1, 1, 1, 1));
    }

    #[test]
    fn rmse_basic() {
        assert!((rmse(&[1.0, 2.0], &[1.0, 4.0]) - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn degenerate_single_class() {
        assert_eq!(auc(&[0.3, 0.5], &[1.0, 1.0]), 0.5);
    }
}
