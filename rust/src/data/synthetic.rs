//! Synthetic physics-like dataset generators (SUSY / HIGGS substitutes).
//!
//! Both real datasets are Monte-Carlo event records: a block of *low-level*
//! detector features (momenta, angles) followed by *derived* high-level
//! features (invariant masses, products). The generators below mirror that
//! structure: class-conditional correlated Gaussian low-level blocks, plus
//! deterministic nonlinear derived features, plus detector-style noise.
//! See DESIGN.md §5 for why this preserves the paper's experimental
//! behaviour.

use super::Dataset;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Parameters for the generic physics-like generator.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Number of low-level (raw) features.
    pub raw_dim: usize,
    /// Number of derived (nonlinear) features appended after the raw block.
    pub derived_dim: usize,
    /// Class separation of the signal mean shift.
    pub separation: f64,
    /// Strength of the intra-event feature correlation (0 = independent).
    pub correlation: f64,
    /// Observation noise added to every feature.
    pub noise: f64,
    /// Dataset name.
    pub name: &'static str,
}

impl SyntheticSpec {
    /// SUSY-like: 18 features (8 raw + 10 derived), moderate separation.
    /// The real SUSY task saturates around AUC ≈ 0.87.
    pub fn susy() -> Self {
        SyntheticSpec {
            raw_dim: 8,
            derived_dim: 10,
            separation: 1.0,
            correlation: 0.6,
            noise: 0.8,
            name: "susy-like",
        }
    }

    /// HIGGS-like: 28 features (21 raw + 7 derived), weaker separation
    /// (the real HIGGS task is harder, AUC ≈ 0.80 for kernel methods).
    pub fn higgs() -> Self {
        SyntheticSpec {
            raw_dim: 21,
            derived_dim: 7,
            separation: 0.6,
            correlation: 0.5,
            noise: 1.0,
            name: "higgs-like",
        }
    }

    /// Total feature dimension.
    pub fn dim(&self) -> usize {
        self.raw_dim + self.derived_dim
    }

    /// Generate `n` labeled events.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Dataset {
        let d = self.dim();
        let mut x = Matrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        let mut raw = vec![0.0; self.raw_dim];
        for i in 0..n {
            let label = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            y.push(label);
            // Low-level block: correlated Gaussians. A single shared latent
            // factor per event induces an approximately rank-1-dominated
            // covariance — this is what gives the kernel matrix its fast
            // spectral decay (d_eff(λ) ≪ 1/λ).
            let latent = rng.gaussian();
            // signal events get a mean shift along an oscillating direction
            for (j, r) in raw.iter_mut().enumerate() {
                let dir = ((j as f64 + 1.0) * 0.7).sin();
                let shift = if label > 0.0 { self.separation * dir } else { 0.0 };
                *r = shift
                    + self.correlation * latent
                    + (1.0 - self.correlation * self.correlation).sqrt() * rng.gaussian();
            }
            let row = x.row_mut(i);
            row[..self.raw_dim].copy_from_slice(&raw);
            // Derived block: smooth nonlinear combinations of raw features
            // (pairwise products, norms, angle-like ratios) — analogous to
            // invariant masses / MET in the real datasets.
            for k in 0..self.derived_dim {
                let a = k % self.raw_dim;
                let b = (k * 3 + 1) % self.raw_dim;
                let c = (k * 5 + 2) % self.raw_dim;
                let v = match k % 3 {
                    0 => raw[a] * raw[b],
                    1 => (raw[a] * raw[a] + raw[b] * raw[b]).sqrt(),
                    _ => (raw[a] + raw[b]) * raw[c].tanh(),
                };
                row[self.raw_dim + k] = v;
            }
            // detector noise on everything
            for v in row.iter_mut() {
                *v += self.noise * 0.1 * rng.gaussian();
            }
        }
        let mut ds = Dataset { x, y, name: self.name.to_string() };
        ds.standardize();
        ds
    }
}

/// SUSY-like dataset with `n` events (18 standardized features).
pub fn susy_like(n: usize, rng: &mut Rng) -> Dataset {
    SyntheticSpec::susy().generate(n, rng)
}

/// HIGGS-like dataset with `n` events (28 standardized features).
pub fn higgs_like(n: usize, rng: &mut Rng) -> Dataset {
    SyntheticSpec::higgs().generate(n, rng)
}

/// Classic two-moons toy problem (2-D), for quickstart examples and tests
/// where a visually obvious nonlinear decision boundary helps.
pub fn two_moons(n: usize, noise: f64, rng: &mut Rng) -> Dataset {
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = if i % 2 == 0 { 1.0 } else { -1.0 };
        let t = std::f64::consts::PI * rng.next_f64();
        let (cx, cy) = if label > 0.0 {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        x.set(i, 0, cx + noise * rng.gaussian());
        x.set(i, 1, cy + noise * rng.gaussian());
        y.push(label);
    }
    Dataset { x, y, name: "two-moons".to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let mut r = Rng::seeded(0);
        let ds = susy_like(300, &mut r);
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d(), 18);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        let dh = higgs_like(100, &mut r);
        assert_eq!(dh.d(), 28);
    }

    #[test]
    fn classes_roughly_balanced() {
        let ds = susy_like(2_000, &mut Rng::seeded(1));
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!((pos as f64 / 2_000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn classes_are_separable_better_than_chance() {
        // a trivial linear score along the mean-difference direction must
        // achieve AUC > 0.6: the labels carry real signal.
        let ds = susy_like(2_000, &mut Rng::seeded(2));
        let d = ds.d();
        let mut mean_pos = vec![0.0; d];
        let mut mean_neg = vec![0.0; d];
        let (mut np, mut nn) = (0.0, 0.0);
        for i in 0..ds.n() {
            let row = ds.x.row(i);
            if ds.y[i] > 0.0 {
                np += 1.0;
                for j in 0..d {
                    mean_pos[j] += row[j];
                }
            } else {
                nn += 1.0;
                for j in 0..d {
                    mean_neg[j] += row[j];
                }
            }
        }
        let w: Vec<f64> =
            (0..d).map(|j| mean_pos[j] / np - mean_neg[j] / nn).collect();
        let scores: Vec<f64> =
            (0..ds.n()).map(|i| crate::linalg::dot(ds.x.row(i), &w)).collect();
        let auc = super::super::auc(&scores, &ds.y);
        assert!(auc > 0.6, "linear AUC {auc} too low — no class signal");
    }

    #[test]
    fn two_moons_shape() {
        let ds = two_moons(100, 0.05, &mut Rng::seeded(3));
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.d(), 2);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = susy_like(50, &mut Rng::seeded(9));
        let b = susy_like(50, &mut Rng::seeded(9));
        assert!(a.x.max_abs_diff(&b.x) == 0.0);
        assert_eq!(a.y, b.y);
    }
}
