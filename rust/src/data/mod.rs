//! Datasets and evaluation metrics.
//!
//! The paper evaluates on the UCI **SUSY** (5M × 18) and **HIGGS**
//! (11M × 28) binary-classification datasets, which are not available in
//! this offline environment. Per the substitution policy (DESIGN.md §5)
//! we build class-conditional *physics-like* generators that preserve the
//! two properties the experiments actually exercise:
//!
//! 1. a **fast-decaying kernel spectrum** so `d_eff(λ) ≪ 1/λ` — both
//!    generators produce strongly correlated low-level features plus
//!    nonlinear derived features (pairwise products, norms, angles),
//!    mimicking the raw + derived structure of the real datasets;
//! 2. a **learnable binary target** with AUC well above chance but below
//!    1.0 (the classes overlap), so the FALKON AUC-per-iteration curves
//!    are meaningful.

mod metrics;
mod synthetic;

pub use metrics::{auc, classification_error, confusion, rmse};
pub use synthetic::{higgs_like, susy_like, two_moons, SyntheticSpec};

use crate::linalg::Matrix;
use crate::rng::Rng;

/// A supervised dataset: row-major features and ±1 labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n × d` feature matrix.
    pub x: Matrix,
    /// Labels in `{-1, +1}` (regression targets also allowed).
    pub y: Vec<f64>,
    /// Human-readable name for logs and result tables.
    pub name: String,
}

impl Dataset {
    /// Number of examples.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Split into `(train, test)` with `test_frac` of points held out,
    /// shuffled with `rng`.
    pub fn split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let n = self.n();
        let n_test = ((n as f64) * test_frac).round() as usize;
        let perm = rng.permutation(n);
        let take = |idx: &[usize], tag: &str| -> Dataset {
            let x = Matrix::from_fn(idx.len(), self.d(), |i, j| self.x.get(idx[i], j));
            let y = idx.iter().map(|&i| self.y[i]).collect();
            Dataset { x, y, name: format!("{}-{}", self.name, tag) }
        };
        (take(&perm[n_test..], "train"), take(&perm[..n_test], "test"))
    }

    /// Subset by row indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let x = Matrix::from_fn(idx.len(), self.d(), |i, j| self.x.get(idx[i], j));
        let y = idx.iter().map(|&i| self.y[i]).collect();
        Dataset { x, y, name: self.name.clone() }
    }

    /// Standardize features to zero mean / unit variance in place
    /// (matches the preprocessing used for SUSY/HIGGS in [14]).
    pub fn standardize(&mut self) {
        let (n, d) = (self.n(), self.d());
        for j in 0..d {
            let mut mean = 0.0;
            for i in 0..n {
                mean += self.x.get(i, j);
            }
            mean /= n as f64;
            let mut var = 0.0;
            for i in 0..n {
                let c = self.x.get(i, j) - mean;
                var += c * c;
            }
            var /= n as f64;
            let std = var.sqrt().max(1e-12);
            for i in 0..n {
                let v = (self.x.get(i, j) - mean) / std;
                self.x.set(i, j, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_partitions() {
        let ds = susy_like(200, &mut Rng::seeded(0));
        let (tr, te) = ds.split(0.25, &mut Rng::seeded(1));
        assert_eq!(tr.n() + te.n(), 200);
        assert_eq!(te.n(), 50);
        assert_eq!(tr.d(), ds.d());
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = higgs_like(500, &mut Rng::seeded(2));
        ds.standardize();
        for j in 0..ds.d() {
            let mean: f64 = (0..ds.n()).map(|i| ds.x.get(i, j)).sum::<f64>() / ds.n() as f64;
            let var: f64 =
                (0..ds.n()).map(|i| (ds.x.get(i, j) - mean).powi(2)).sum::<f64>() / ds.n() as f64;
            assert!(mean.abs() < 1e-9, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-6, "col {j} var {var}");
        }
    }

    #[test]
    fn subset_selects_rows() {
        let ds = susy_like(50, &mut Rng::seeded(3));
        let s = ds.subset(&[3, 7, 11]);
        assert_eq!(s.n(), 3);
        assert_eq!(s.y[1], ds.y[7]);
        assert_eq!(s.x.get(2, 0), ds.x.get(11, 0));
    }
}
