//! Walker's alias method for O(1) sampling from a discrete distribution.
//!
//! BLESS draws `M_h` multinomial samples from `R_h` categories each
//! iteration (Alg. 1 line 9); the alias table makes the whole draw
//! `O(R_h + M_h)` rather than `O(R_h · M_h)` for naive inverse-CDF.

use super::Rng;

/// Precomputed alias table over `n` categories.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights.
    ///
    /// Panics if all weights are zero or any weight is negative/non-finite.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "empty weight vector");
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "weights must sum to a positive finite value"
        );
        for &w in weights {
            assert!(w >= 0.0 && w.is_finite(), "negative or non-finite weight");
        }
        // scaled probabilities, mean 1
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers are numerically 1
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
            alias[i] = i;
        }
        AliasTable { prob, alias }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True when the table has no categories (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one category index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_category() {
        let t = AliasTable::new(&[3.5]);
        let mut r = Rng::seeded(0);
        for _ in 0..10 {
            assert_eq!(t.sample(&mut r), 0);
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let t = AliasTable::new(&[0.0, 1.0, 0.0, 2.0]);
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            let s = t.sample(&mut r);
            assert!(s == 1 || s == 3, "sampled zero-weight category {s}");
        }
    }

    #[test]
    fn skewed_distribution_frequencies() {
        let w = [0.01, 0.09, 0.4, 0.5];
        let t = AliasTable::new(&w);
        let mut r = Rng::seeded(2);
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[t.sample(&mut r)] += 1;
        }
        for i in 0..4 {
            let got = counts[i] as f64 / n as f64;
            assert!((got - w[i]).abs() < 0.005, "cat {i}: {got} vs {}", w[i]);
        }
    }

    #[test]
    #[should_panic(expected = "positive finite")]
    fn all_zero_weights_panic() {
        AliasTable::new(&[0.0, 0.0]);
    }
}
