//! Pseudo-random number generation and sampling substrate.
//!
//! The `rand` crate is not in the offline registry, so the crate ships its
//! own generator: **xoshiro256\*\*** seeded through SplitMix64 — fast,
//! high-quality, and reproducible across runs (every experiment takes an
//! explicit seed).
//!
//! On top of the raw generator live the sampling primitives the paper's
//! algorithms need: uniform subsets, Bernoulli thinning (BLESS-R),
//! multinomial sampling with replacement via **Walker's alias method**
//! (BLESS step 9: `J_h ~ Multinomial(P_h, U_h)` with `M_h` draws from
//! `R_h` categories in `O(R_h + M_h)`), and Gaussian variates for the
//! synthetic datasets.

mod alias;

pub use alias::AliasTable;

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn seeded(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut z = seed;
        let mut next = move || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s = [next(), next(), next(), next()];
        // avoid the all-zero state (probability ~0 but cheap to guard)
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal variate (Box–Muller, one value per call; the spare
    /// is discarded for simplicity — generation is not a hot path).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// `k` i.i.d. uniform draws from `[0, n)` **with** replacement.
    pub fn uniform_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }

    /// `k` distinct uniform draws from `[0, n)` **without** replacement
    /// (partial Fisher–Yates over an index array; O(n) memory, O(k) swaps —
    /// used for dataset splits and the SQUEAK partition).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random permutation of `[0, n)`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        self.sample_without_replacement(n, n)
    }

    /// `k` multinomial draws (with replacement) from unnormalized weights.
    ///
    /// Uses the alias method: `O(len + k)` instead of `O(len·k)`.
    pub fn multinomial(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        let table = AliasTable::new(weights);
        (0..k).map(|_| table.sample(self)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(43);
        assert_ne!(Rng::seeded(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seeded(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seeded(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts {counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seeded(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn without_replacement_distinct_and_complete() {
        let mut r = Rng::seeded(4);
        let s = r.sample_without_replacement(100, 40);
        assert_eq!(s.len(), 40);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40, "duplicates found");
        assert!(s.iter().all(|&i| i < 100));
        // full permutation covers everything
        let p = r.permutation(50);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::seeded(5);
        let hits = (0..50_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn multinomial_follows_weights() {
        let mut r = Rng::seeded(6);
        let w = [1.0, 2.0, 3.0, 4.0];
        let draws = r.multinomial(&w, 100_000);
        let mut counts = [0usize; 4];
        for d in draws {
            counts[d] += 1;
        }
        let total: f64 = w.iter().sum();
        for i in 0..4 {
            let expect = w[i] / total;
            let got = counts[i] as f64 / 100_000.0;
            assert!((got - expect).abs() < 0.01, "cat {i}: {got} vs {expect}");
        }
    }
}
