//! The fault-plan spec: which injection points fire, how often, and the
//! seed that makes a chaos run replayable.
//!
//! Grammar (CLI `serve --faults "…"` / `BLESS_FAULTS` env):
//!
//! ```text
//! seed=42;conn.delay:p=0.05,ms=200;worker.panic:p=0.01
//! ```
//!
//! Semicolon-separated entries; one optional `seed=N` entry (default 0)
//! plus any number of `point:key=value,key=value` rules. Every point
//! takes `p` (per-draw probability, in `[0,1]`); `conn.delay`
//! additionally takes `ms` (injected delay). [`FaultPlan`] round-trips
//! through `Display`, so a logged plan replays verbatim.
//!
//! The lifecycle tier adds three points to the original serve six:
//! `train.panic` (kill the candidate trainer mid-fit), `ckpt.corrupt`
//! (mutilate checkpoint bytes on load) and `gate.fail` (force the
//! holdout gate to reject the candidate) — the retrain chaos soak in
//! `tests/lifecycle_soak.rs` storms all three.

use std::fmt;

/// A named injection point at one of the serve or lifecycle tier's IO
/// or compute boundaries. The set is closed — every point has exactly
/// one firing site in `serve/`, `falkon/` or `lifecycle/`, so a plan can
/// be reasoned about exhaustively.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultPoint {
    /// Stall a connection after reading a request line (`ms` applies).
    ConnDelay,
    /// Drop the connection before answering (client sees EOF).
    ConnDrop,
    /// Write a truncated response line, then drop the connection.
    ConnTruncate,
    /// Corrupt artifact bytes between disk read and decode.
    ArtifactCorrupt,
    /// Panic inside an engine worker mid-batch.
    WorkerPanic,
    /// Substitute a predict error for a batch's real result.
    EngineError,
    /// Panic inside the candidate trainer mid-fit (lifecycle retrain).
    TrainPanic,
    /// Corrupt checkpoint bytes between disk read and decode.
    CkptCorrupt,
    /// Force the holdout promotion gate to reject the candidate.
    GateFail,
}

impl FaultPoint {
    /// Every injection point, in spec order.
    pub const ALL: [FaultPoint; 9] = [
        FaultPoint::ConnDelay,
        FaultPoint::ConnDrop,
        FaultPoint::ConnTruncate,
        FaultPoint::ArtifactCorrupt,
        FaultPoint::WorkerPanic,
        FaultPoint::EngineError,
        FaultPoint::TrainPanic,
        FaultPoint::CkptCorrupt,
        FaultPoint::GateFail,
    ];

    /// The spec name (`conn.delay`, `worker.panic`, …).
    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::ConnDelay => "conn.delay",
            FaultPoint::ConnDrop => "conn.drop",
            FaultPoint::ConnTruncate => "conn.truncate",
            FaultPoint::ArtifactCorrupt => "artifact.corrupt",
            FaultPoint::WorkerPanic => "worker.panic",
            FaultPoint::EngineError => "engine.error",
            FaultPoint::TrainPanic => "train.panic",
            FaultPoint::CkptCorrupt => "ckpt.corrupt",
            FaultPoint::GateFail => "gate.fail",
        }
    }

    /// Parse a spec name back to the point.
    pub fn parse(s: &str) -> Option<FaultPoint> {
        FaultPoint::ALL.iter().copied().find(|p| p.name() == s)
    }

    /// Dense index, for per-point state arrays.
    pub(crate) fn index(self) -> usize {
        self as usize
    }
}

/// One point's firing rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRule {
    /// Per-draw firing probability in `[0, 1]`.
    pub p: f64,
    /// Injected delay in milliseconds (only `conn.delay` reads it).
    pub ms: u64,
}

/// A complete, replayable fault plan: the seed plus zero or more rules.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    /// Base seed for the per-point draw streams; two runs of the same
    /// plan see the same per-point draw sequences.
    pub seed: u64,
    rules: [Option<FaultRule>; 9],
}

impl FaultPlan {
    /// An empty plan (no rules) with the given seed.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: [None; 9] }
    }

    /// Set (or replace) one point's rule; builder-style.
    pub fn with(mut self, point: FaultPoint, rule: FaultRule) -> FaultPlan {
        self.rules[point.index()] = Some(rule);
        self
    }

    /// The rule for a point, if the plan carries one.
    pub fn rule(&self, point: FaultPoint) -> Option<FaultRule> {
        self.rules[point.index()]
    }

    /// Whether the plan has any rule at all.
    pub fn is_empty(&self) -> bool {
        self.rules.iter().all(Option::is_none)
    }

    /// Parse a spec string (see the module docs for the grammar).
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            if let Some(seed) = entry.strip_prefix("seed=") {
                plan.seed = seed
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad fault seed {seed:?}: {e}"))?;
                continue;
            }
            let (name, kvs) = entry
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad fault entry {entry:?} (want point:p=…)"))?;
            let point = FaultPoint::parse(name.trim()).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown fault point {:?} (known: {})",
                    name.trim(),
                    FaultPoint::ALL.map(FaultPoint::name).join(", ")
                )
            })?;
            let mut rule = FaultRule { p: 0.0, ms: 0 };
            let mut saw_p = false;
            for kv in kvs.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("bad fault param {kv:?} (want key=value)"))?;
                match k.trim() {
                    "p" => {
                        rule.p = v
                            .trim()
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad probability {v:?}: {e}"))?;
                        saw_p = true;
                    }
                    "ms" => {
                        rule.ms = v
                            .trim()
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad ms value {v:?}: {e}"))?;
                    }
                    other => anyhow::bail!("unknown fault param {other:?} (want p or ms)"),
                }
            }
            anyhow::ensure!(saw_p, "fault entry {entry:?} needs a probability (p=…)");
            anyhow::ensure!(
                rule.p.is_finite() && (0.0..=1.0).contains(&rule.p),
                "fault probability {} out of [0,1]",
                rule.p
            );
            plan.rules[point.index()] = Some(rule);
        }
        Ok(plan)
    }
}

impl fmt::Display for FaultPlan {
    /// The canonical spec string; `FaultPlan::parse(&plan.to_string())`
    /// reproduces the plan exactly.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for point in FaultPoint::ALL {
            if let Some(rule) = self.rule(point) {
                write!(f, ";{}:p={}", point.name(), rule.p)?;
                if rule.ms > 0 {
                    write!(f, ",ms={}", rule.ms)?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let plan = FaultPlan::parse("conn.delay:p=0.05,ms=200;worker.panic:p=0.01").unwrap();
        assert_eq!(plan.seed, 0);
        assert_eq!(
            plan.rule(FaultPoint::ConnDelay),
            Some(FaultRule { p: 0.05, ms: 200 })
        );
        assert_eq!(plan.rule(FaultPoint::WorkerPanic), Some(FaultRule { p: 0.01, ms: 0 }));
        assert_eq!(plan.rule(FaultPoint::ConnDrop), None);
    }

    #[test]
    fn seed_entry_and_whitespace_are_accepted() {
        let plan = FaultPlan::parse(" seed=42 ; engine.error : p = 1 ").unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.rule(FaultPoint::EngineError), Some(FaultRule { p: 1.0, ms: 0 }));
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn display_round_trips() {
        let plan = FaultPlan::seeded(7)
            .with(FaultPoint::ConnDelay, FaultRule { p: 0.25, ms: 50 })
            .with(FaultPoint::ArtifactCorrupt, FaultRule { p: 0.5, ms: 0 });
        let spec = plan.to_string();
        assert_eq!(FaultPlan::parse(&spec).unwrap(), plan, "spec was {spec}");
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(FaultPlan::parse("nope.point:p=0.5").is_err());
        assert!(FaultPlan::parse("conn.delay").is_err());
        assert!(FaultPlan::parse("conn.delay:ms=5").is_err(), "p is mandatory");
        assert!(FaultPlan::parse("conn.delay:p=1.5").is_err());
        assert!(FaultPlan::parse("conn.delay:p=-0.1").is_err());
        assert!(FaultPlan::parse("conn.delay:p=abc").is_err());
        assert!(FaultPlan::parse("conn.delay:p=0.1,volume=11").is_err());
        assert!(FaultPlan::parse("seed=notanumber").is_err());
    }

    #[test]
    fn lifecycle_points_parse_and_round_trip() {
        let plan =
            FaultPlan::parse("seed=9;train.panic:p=0.2;ckpt.corrupt:p=1;gate.fail:p=0.5").unwrap();
        assert_eq!(plan.rule(FaultPoint::TrainPanic), Some(FaultRule { p: 0.2, ms: 0 }));
        assert_eq!(plan.rule(FaultPoint::CkptCorrupt), Some(FaultRule { p: 1.0, ms: 0 }));
        assert_eq!(plan.rule(FaultPoint::GateFail), Some(FaultRule { p: 0.5, ms: 0 }));
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
    }

    #[test]
    fn every_point_name_parses_back() {
        for point in FaultPoint::ALL {
            assert_eq!(FaultPoint::parse(point.name()), Some(point));
        }
        assert_eq!(FaultPoint::parse("conn"), None);
    }
}
