//! Seeded, deterministic fault injection for the serve tier.
//!
//! The ROADMAP's production north star means the server must survive the
//! failure modes production actually throws: stalled and dropped
//! connections, torn artifact reads, panicking workers, failing engines.
//! This module is the controlled way to *cause* those, so
//! `tests/chaos_soak.rs` can assert the hardening in `serve/` holds —
//! the same observation-never-perturbs discipline as [`crate::obs`]:
//!
//! * **Zero cost when disabled.** Every check starts with one relaxed
//!   atomic load ([`is_active`]); with no plan armed, no lock is taken,
//!   no RNG advanced, no counter touched, and serve output is
//!   bit-identical to a build without the module.
//! * **Deterministic when enabled.** Each [`FaultPoint`] draws from its
//!   own RNG stream, seeded from the plan seed and the point's index —
//!   the *k*-th draw at a given point is the same in every run of the
//!   same plan. (Which request consumes which draw still depends on
//!   thread scheduling; the per-point draw sequences, and hence
//!   aggregate fault counts for a fixed request count, replay exactly.)
//! * **Observable.** Every injected fault increments a per-point counter
//!   ([`injected_counts`]) and the process-wide
//!   `faults_injected_total` counter in [`crate::obs::metrics::global`],
//!   so `/metrics` shows chaos as it happens.
//!
//! Plans come from `serve --faults "…"` or the `BLESS_FAULTS` env var —
//! see [`FaultPlan::parse`] for the spec grammar.
//!
//! The firing sites live in `serve/`, `falkon/` and `lifecycle/`:
//! connection read/write ([`FaultPoint::ConnDelay`],
//! [`ConnDrop`](FaultPoint::ConnDrop),
//! [`ConnTruncate`](FaultPoint::ConnTruncate)), artifact load
//! ([`ArtifactCorrupt`](FaultPoint::ArtifactCorrupt)), the engine
//! workers ([`WorkerPanic`](FaultPoint::WorkerPanic),
//! [`EngineError`](FaultPoint::EngineError)), checkpoint load
//! ([`CkptCorrupt`](FaultPoint::CkptCorrupt)), the lifecycle candidate
//! trainer ([`TrainPanic`](FaultPoint::TrainPanic)) and the holdout
//! promotion gate ([`GateFail`](FaultPoint::GateFail)).

mod plan;

pub use plan::{FaultPlan, FaultPoint, FaultRule};

use crate::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Duration;

/// Fast-path gate: a single relaxed load decides "faults off".
static ACTIVE: AtomicBool = AtomicBool::new(false);

/// The armed plan plus its per-point draw streams and counters.
struct Armed {
    plan: FaultPlan,
    /// One seeded stream per point: draws at one point never perturb
    /// another point's sequence.
    streams: [Mutex<Rng>; 9],
    injected: [AtomicU64; 9],
}

fn slot() -> &'static RwLock<Option<Arc<Armed>>> {
    static SLOT: OnceLock<RwLock<Option<Arc<Armed>>>> = OnceLock::new();
    SLOT.get_or_init(|| RwLock::new(None))
}

fn armed() -> Option<Arc<Armed>> {
    crate::util::sync::read(slot()).clone()
}

/// Arm a plan (or disarm with `None` / an empty plan). Re-arming resets
/// the draw streams and injection counters, so two soaks of the same
/// plan replay identically.
pub fn configure(plan: Option<FaultPlan>) {
    let armed = plan.filter(|p| !p.is_empty()).map(|plan| {
        let streams = std::array::from_fn(|i| {
            // distinct golden-ratio offsets per point: streams stay
            // decorrelated even for adjacent seeds
            Mutex::new(Rng::seeded(
                plan.seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(i as u64 + 1)),
            ))
        });
        Arc::new(Armed { plan, streams, injected: std::array::from_fn(|_| AtomicU64::new(0)) })
    });
    let mut guard = crate::util::sync::write(slot());
    ACTIVE.store(armed.is_some(), Ordering::Relaxed);
    *guard = armed;
}

/// Whether any fault plan is armed — one relaxed atomic load, the whole
/// cost of the module on the disabled path.
#[inline]
pub fn is_active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

fn record(armed: &Armed, point: FaultPoint) {
    armed.injected[point.index()].fetch_add(1, Ordering::Relaxed);
    crate::obs::metrics::global().counter("faults_injected_total").inc();
}

/// Draw once at `point`: `true` means the fault fires now. Always
/// `false` when disarmed or the plan has no rule for the point.
pub fn fire(point: FaultPoint) -> bool {
    if !is_active() {
        return false;
    }
    let Some(armed) = armed() else { return false };
    let Some(rule) = armed.plan.rule(point) else { return false };
    if rule.p <= 0.0 {
        return false;
    }
    let hit = crate::util::sync::lock(&armed.streams[point.index()]).bernoulli(rule.p);
    if hit {
        record(&armed, point);
    }
    hit
}

/// Draw at a delay-style point; `Some(d)` means "stall for `d` now".
pub fn delay(point: FaultPoint) -> Option<Duration> {
    if !is_active() {
        return None;
    }
    let armed = armed()?;
    let rule = armed.plan.rule(point)?;
    if rule.p <= 0.0 {
        return None;
    }
    let hit = crate::util::sync::lock(&armed.streams[point.index()]).bernoulli(rule.p);
    if !hit {
        return None;
    }
    record(&armed, point);
    Some(Duration::from_millis(rule.ms))
}

/// Draw at a byte-corruption point; when it fires, deterministically
/// mutilate `bytes` (truncate to a seeded prefix, or flip one seeded bit)
/// and return `true`. The loader downstream must turn the damage into a
/// clean typed error — that contract is what `tests/chaos_soak.rs` and
/// the artifact-recovery tests assert.
fn corrupt_bytes(point: FaultPoint, bytes: &mut Vec<u8>) -> bool {
    if !is_active() {
        return false;
    }
    let Some(armed) = armed() else { return false };
    let Some(rule) = armed.plan.rule(point) else { return false };
    if rule.p <= 0.0 {
        return false;
    }
    let mut rng = crate::util::sync::lock(&armed.streams[point.index()]);
    if !rng.bernoulli(rule.p) {
        return false;
    }
    if bytes.is_empty() {
        record(&armed, point);
        return true;
    }
    if rng.bernoulli(0.5) {
        // short read: keep a strict prefix (possibly empty)
        let keep = rng.below(bytes.len());
        bytes.truncate(keep);
    } else {
        // bit rot: flip one bit somewhere in the payload
        let idx = rng.below(bytes.len());
        let bit = rng.below(8) as u32;
        bytes[idx] ^= 1u8 << bit;
    }
    drop(rng);
    record(&armed, point);
    true
}

/// Draw at [`FaultPoint::ArtifactCorrupt`] against model-artifact bytes.
pub fn corrupt_artifact(bytes: &mut Vec<u8>) -> bool {
    corrupt_bytes(FaultPoint::ArtifactCorrupt, bytes)
}

/// Draw at [`FaultPoint::CkptCorrupt`] against `BLESSCKPT` checkpoint
/// bytes; the checkpoint loader must degrade to a cold start (with a
/// loud warning), never a panic.
pub fn corrupt_checkpoint(bytes: &mut Vec<u8>) -> bool {
    corrupt_bytes(FaultPoint::CkptCorrupt, bytes)
}

/// Injected-fault counts per point since the last [`configure`], in
/// [`FaultPoint::ALL`] order. Empty when disarmed.
pub fn injected_counts() -> Vec<(&'static str, u64)> {
    match armed() {
        None => Vec::new(),
        Some(armed) => FaultPoint::ALL
            .iter()
            .map(|p| (p.name(), armed.injected[p.index()].load(Ordering::Relaxed)))
            .collect(),
    }
}

/// Total faults injected since the last [`configure`].
pub fn total_injected() -> u64 {
    injected_counts().iter().map(|(_, n)| n).sum()
}

#[cfg(test)]
static TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize tests that touch the process-global armed plan.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        crate::util::sync::lock(&TEST_LOCK)
    }

    #[test]
    fn disabled_by_default_and_after_disarm() {
        let _g = guard();
        configure(None);
        assert!(!is_active());
        assert!(!fire(FaultPoint::WorkerPanic));
        assert!(delay(FaultPoint::ConnDelay).is_none());
        let mut bytes = vec![1, 2, 3];
        assert!(!corrupt_artifact(&mut bytes));
        assert_eq!(bytes, vec![1, 2, 3]);
        assert_eq!(total_injected(), 0);
        // an empty plan arms nothing
        configure(Some(FaultPlan::seeded(9)));
        assert!(!is_active());
    }

    #[test]
    fn p1_always_fires_and_counts() {
        let _g = guard();
        configure(Some(
            FaultPlan::seeded(1).with(FaultPoint::EngineError, FaultRule { p: 1.0, ms: 0 }),
        ));
        for _ in 0..10 {
            assert!(fire(FaultPoint::EngineError));
        }
        // points without a rule never fire even while armed
        assert!(!fire(FaultPoint::ConnDrop));
        assert_eq!(total_injected(), 10);
        let counts = injected_counts();
        assert!(counts.contains(&("engine.error", 10)), "got {counts:?}");
        configure(None);
    }

    #[test]
    fn same_seed_replays_the_same_draw_sequence() {
        let _g = guard();
        let plan =
            FaultPlan::seeded(33).with(FaultPoint::ConnDrop, FaultRule { p: 0.3, ms: 0 });
        configure(Some(plan.clone()));
        let a: Vec<bool> = (0..200).map(|_| fire(FaultPoint::ConnDrop)).collect();
        configure(Some(plan));
        let b: Vec<bool> = (0..200).map(|_| fire(FaultPoint::ConnDrop)).collect();
        assert_eq!(a, b, "re-arming the same plan must replay bit-identically");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f), "p=0.3 should mix");
        configure(None);
    }

    #[test]
    fn delay_returns_the_configured_stall() {
        let _g = guard();
        configure(Some(
            FaultPlan::seeded(5).with(FaultPoint::ConnDelay, FaultRule { p: 1.0, ms: 40 }),
        ));
        assert_eq!(delay(FaultPoint::ConnDelay), Some(Duration::from_millis(40)));
        configure(None);
    }

    #[test]
    fn corruption_damages_bytes_deterministically() {
        let _g = guard();
        let plan = FaultPlan::seeded(77)
            .with(FaultPoint::ArtifactCorrupt, FaultRule { p: 1.0, ms: 0 });
        let original: Vec<u8> = (0..=255).collect();

        configure(Some(plan.clone()));
        let mut first = original.clone();
        assert!(corrupt_artifact(&mut first));
        assert_ne!(first, original, "corruption must change the bytes");

        configure(Some(plan));
        let mut second = original.clone();
        assert!(corrupt_artifact(&mut second));
        assert_eq!(first, second, "same seed must produce the same damage");
        configure(None);
    }

    #[test]
    fn checkpoint_corruption_replays_and_is_independent() {
        let _g = guard();
        let plan = FaultPlan::seeded(123)
            .with(FaultPoint::ArtifactCorrupt, FaultRule { p: 1.0, ms: 0 })
            .with(FaultPoint::CkptCorrupt, FaultRule { p: 1.0, ms: 0 });
        let original: Vec<u8> = (0..=255).collect();

        configure(Some(plan.clone()));
        let mut first = original.clone();
        assert!(corrupt_checkpoint(&mut first));
        assert_ne!(first, original, "corruption must change the bytes");

        configure(Some(plan));
        let mut second = original.clone();
        assert!(corrupt_checkpoint(&mut second));
        assert_eq!(first, second, "same seed must produce the same damage");
        // draws at ckpt.corrupt never advanced the artifact stream
        let counts = injected_counts();
        assert!(counts.contains(&("ckpt.corrupt", 1)), "got {counts:?}");
        assert!(counts.contains(&("artifact.corrupt", 0)), "got {counts:?}");
        configure(None);
    }
}
