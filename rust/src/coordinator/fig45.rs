//! Figures 4 & 5 — FALKON-BLESS vs FALKON-UNI: test AUC after every CG
//! iteration (SUSY: σ=4, λ_falkon=1e-6, λ_bless=1e-4; HIGGS: σ=22,
//! λ_falkon=1e-8, λ_bless=1e-6). The claim: BLESS centers give the same
//! final accuracy in ~¼ of the iterations/wallclock and much earlier
//! AUC lift-off.
//!
//! Our substitution: SUSY-like / HIGGS-like generators, n scaled to the
//! one-core budget, λs rescaled to keep M = |J_H| in a comparable ratio
//! to n. FALKON-UNI gets the *same number* of uniform centers as BLESS
//! returned (the paper's protocol).

use crate::bless::{bless, BlessConfig};
use crate::data::{auc, Dataset};
use crate::falkon::Falkon;
use crate::kernels::KernelEngine;
use crate::leverage::WeightedSet;
use crate::rng::Rng;
use crate::util::table::{fnum, Table};
use crate::util::timed;

/// Configuration of the FALKON comparison.
#[derive(Clone, Debug)]
pub struct Fig45Config {
    pub sigma: f64,
    pub lambda_bless: f64,
    pub lambda_falkon: f64,
    pub iterations: usize,
    pub seed: u64,
    /// Dataset label for the table title.
    pub dataset: String,
}

impl Fig45Config {
    /// Paper Figure-4 setup (SUSY), rescaled.
    pub fn susy() -> Self {
        Fig45Config {
            sigma: 4.0,
            lambda_bless: 1e-4,
            lambda_falkon: 1e-6,
            iterations: 20,
            seed: 0,
            dataset: "susy-like".into(),
        }
    }

    /// Paper Figure-5 setup (HIGGS), rescaled.
    pub fn higgs() -> Self {
        Fig45Config {
            sigma: 5.0,
            lambda_bless: 1e-4,
            lambda_falkon: 1e-7,
            iterations: 20,
            seed: 0,
            dataset: "higgs-like".into(),
        }
    }
}

/// One method's AUC-per-iteration curve.
#[derive(Clone, Debug)]
pub struct FalkonCurve {
    pub label: String,
    pub centers: usize,
    pub sampling_secs: f64,
    /// `(iteration, cumulative seconds, test AUC)`.
    pub points: Vec<(usize, f64, f64)>,
}

impl FalkonCurve {
    /// First iteration reaching `frac` of the final AUC gain over 0.5.
    pub fn iters_to_reach(&self, target_auc: f64) -> Option<usize> {
        self.points.iter().find(|(_, _, a)| *a >= target_auc).map(|(i, _, _)| *i)
    }

    /// Final AUC.
    pub fn final_auc(&self) -> f64 {
        self.points.last().map(|p| p.2).unwrap_or(0.5)
    }
}

/// Run FALKON-BLESS and FALKON-UNI on a train/test split, capturing the
/// per-iteration test AUC for both.
pub fn fig45_falkon(
    engine: &dyn KernelEngine,
    train_y: &[f64],
    test: &Dataset,
    cfg: &Fig45Config,
) -> anyhow::Result<(FalkonCurve, FalkonCurve, Table)> {
    // --- BLESS centers (λ_bless ≫ λ_falkon keeps M small, §4 of paper)
    let mut rng = Rng::seeded(cfg.seed.wrapping_add(1));
    let (path, bless_secs) =
        timed(|| bless(engine, cfg.lambda_bless, &BlessConfig::default(), &mut rng));
    let bless_set = path.final_set().clone();
    // FALKON dedupes with-replacement picks; match UNI to the *distinct*
    // center count for a fair comparison (the paper's protocol).
    let m = {
        let mut idx = bless_set.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        idx.len()
    };

    let bless_curve = run_one(
        engine,
        train_y,
        test,
        &bless_set,
        cfg,
        "FALKON-BLESS",
        bless_secs,
    )?;

    // --- uniform centers, same count (paper's comparison protocol)
    let mut rng = Rng::seeded(cfg.seed.wrapping_add(2));
    let uni_idx = rng.sample_without_replacement(engine.n(), m.min(engine.n()));
    let uni_set = WeightedSet::uniform(uni_idx, cfg.lambda_falkon);
    let uni_curve = run_one(engine, train_y, test, &uni_set, cfg, "FALKON-UNI", 0.0)?;

    // --- result table
    let mut table = Table::new(
        &format!(
            "Figure 4/5 ({}): AUC per iteration, M={}, λ_bless={:.0e}, λ_falkon={:.0e}",
            cfg.dataset, m, cfg.lambda_bless, cfg.lambda_falkon
        ),
        &["iter", "BLESS_auc", "BLESS_s", "UNI_auc", "UNI_s"],
    );
    for i in 0..cfg.iterations {
        let b = bless_curve.points.get(i);
        let u = uni_curve.points.get(i);
        table.row(&[
            (i + 1).to_string(),
            b.map(|p| fnum(p.2)).unwrap_or_default(),
            b.map(|p| fnum(p.1)).unwrap_or_default(),
            u.map(|p| fnum(p.2)).unwrap_or_default(),
            u.map(|p| fnum(p.1)).unwrap_or_default(),
        ]);
    }
    Ok((bless_curve, uni_curve, table))
}

fn run_one(
    engine: &dyn KernelEngine,
    train_y: &[f64],
    test: &Dataset,
    set: &WeightedSet,
    cfg: &Fig45Config,
    label: &str,
    sampling_secs: f64,
) -> anyhow::Result<FalkonCurve> {
    let solver = Falkon::new(engine, set, cfg.lambda_falkon)?;
    let mut points = Vec::with_capacity(cfg.iterations);
    let t0 = std::time::Instant::now();
    let mut cb = |it: usize, model: &crate::falkon::FalkonModel| -> Option<f64> {
        let scores = model.predict(engine, &test.x);
        let a = auc(&scores, &test.y);
        points.push((it, sampling_secs + t0.elapsed().as_secs_f64(), a));
        Some(a)
    };
    let _ = solver.fit(train_y, cfg.iterations, Some(&mut cb))?;
    Ok(FalkonCurve {
        label: label.to_string(),
        centers: solver.m(),
        sampling_secs,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};

    #[test]
    fn bless_centers_competitive_on_small_problem() {
        let mut rng = Rng::seeded(5);
        let ds = susy_like(900, &mut rng);
        let (train, test) = ds.split(0.3, &mut rng);
        let eng = NativeEngine::new(train.x.clone(), Gaussian::new(4.0));
        let cfg = Fig45Config {
            iterations: 10,
            lambda_bless: 1e-3,
            lambda_falkon: 1e-5,
            ..Fig45Config::susy()
        };
        let (b, u, table) = fig45_falkon(&eng, &train.y, &test, &cfg).unwrap();
        assert_eq!(table.rows.len(), 10);
        assert!(b.final_auc() > 0.7, "BLESS final AUC {}", b.final_auc());
        assert!(u.final_auc() > 0.6, "UNI final AUC {}", u.final_auc());
        // comparable center counts by construction
        assert!(
            (b.centers as f64 - u.centers as f64).abs() / b.centers as f64 <= 0.35,
            "center counts {} vs {}",
            b.centers,
            u.centers
        );
    }
}
