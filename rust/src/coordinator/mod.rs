//! Experiment coordinator: the harness that regenerates every table and
//! figure of the paper's evaluation section (see DESIGN.md §4 for the
//! experiment index). Each submodule returns [`crate::util::table::Table`]s
//! so the CLI, the examples and the benches share one implementation.
//!
//! All experiments inherit the process-wide thread policy
//! ([`crate::util::pool`], CLI `--threads`): timings scale with cores
//! while every reported number stays bit-identical to the serial run, so
//! figures regenerated on different machines remain comparable.

mod engines;
mod fig1;
mod fig2;
mod fig3;
mod fig45;
mod table1;

pub use engines::{build_engine, Engine, EngineKind};
pub use fig1::{fig1_accuracy, fig1_estimator_shootout, Fig1Config, ShootoutConfig};
pub use fig2::{
    fig2_estimator_scaling, fig2_scaling, scaling_exponent, scaling_exponent_for, Fig2Config,
};
pub use fig3::{fig3_stability, Fig3Config};
pub use fig45::{fig45_falkon, Fig45Config, FalkonCurve};
pub use table1::{table1_complexity, Table1Config};

/// The sampling methods compared throughout the evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Bless,
    BlessR,
    Squeak,
    Rrls,
    TwoPass,
    Uniform,
    ExactRls,
}

impl Method {
    /// All methods, in the paper's Figure-1 ordering.
    pub fn all() -> &'static [Method] {
        &[
            Method::Bless,
            Method::BlessR,
            Method::Squeak,
            Method::Uniform,
            Method::Rrls,
            Method::TwoPass,
            Method::ExactRls,
        ]
    }

    /// Fast methods only (feasible in the Figure-2 n-sweep).
    pub fn scalable() -> &'static [Method] {
        &[Method::Bless, Method::BlessR, Method::Squeak, Method::Rrls, Method::TwoPass]
    }

    /// Display name matching the paper.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Bless => "BLESS",
            Method::BlessR => "BLESS-R",
            Method::Squeak => "SQUEAK",
            Method::Rrls => "RRLS",
            Method::TwoPass => "Two-Pass",
            Method::Uniform => "Uniform",
            Method::ExactRls => "Exact-RLS",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_lowercase().as_str() {
            "bless" => Some(Method::Bless),
            "bless-r" | "blessr" => Some(Method::BlessR),
            "squeak" => Some(Method::Squeak),
            "rrls" => Some(Method::Rrls),
            "two-pass" | "twopass" => Some(Method::TwoPass),
            "uniform" => Some(Method::Uniform),
            "exact" | "exact-rls" => Some(Method::ExactRls),
            _ => None,
        }
    }
}

/// Run one sampling method, returning `(set, score_evals)`.
pub fn run_method(
    method: Method,
    engine: &dyn crate::kernels::KernelEngine,
    lambda: f64,
    uniform_m: usize,
    rng: &mut crate::rng::Rng,
) -> (crate::leverage::WeightedSet, usize) {
    use crate::baselines as bl;
    match method {
        Method::Bless => {
            let out = crate::bless::bless(engine, lambda, &Default::default(), rng);
            let evals = out.score_evals;
            (out.final_set().clone(), evals)
        }
        Method::BlessR => {
            let out = crate::bless::bless_r(engine, lambda, &Default::default(), rng);
            let evals = out.score_evals;
            (out.final_set().clone(), evals)
        }
        Method::Squeak => {
            let out = bl::squeak(engine, lambda, &Default::default(), rng);
            (out.set, out.score_evals)
        }
        Method::Rrls => {
            let out = bl::rrls(engine, lambda, &Default::default(), rng);
            (out.set, out.score_evals)
        }
        Method::TwoPass => {
            let out = bl::two_pass(engine, lambda, &Default::default(), rng);
            (out.set, out.score_evals)
        }
        Method::Uniform => {
            let out = bl::uniform(engine, lambda, uniform_m, rng);
            (out.set, out.score_evals)
        }
        Method::ExactRls => {
            let out = bl::exact_rls(engine, lambda, uniform_m, rng);
            (out.set, out.score_evals)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for &m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn run_method_dispatches_all() {
        let ds = crate::data::susy_like(150, &mut crate::rng::Rng::seeded(1));
        let eng =
            crate::kernels::NativeEngine::new(ds.x, crate::kernels::Gaussian::new(2.0));
        for &m in Method::all() {
            let mut rng = crate::rng::Rng::seeded(2);
            let (set, _) = run_method(m, &eng, 1e-2, 30, &mut rng);
            set.validate().unwrap();
            assert!(!set.is_empty(), "{} produced empty set", m.name());
        }
    }
}
