//! Table 1 — complexity comparison: measured runtime scaling in `n`
//! (at fixed λ) and in `1/λ` (at fixed n) for every sampler, against the
//! theoretical exponents the paper tabulates.
//!
//! | method    | theory time        | theory |J|   |
//! |-----------|--------------------|--------------|
//! | Uniform   | —                  | 1/λ          |
//! | Exact     | n³                 | d_eff        |
//! | Two-Pass  | n/λ²               | d_eff        |
//! | RRLS      | n·d_eff²           | d_eff        |
//! | SQUEAK    | n·d_eff²           | d_eff        |
//! | BLESS(-R) | (1/λ)·d_eff²       | d_eff        |
//!
//! We report the fitted log-log exponent of time vs n — BLESS/BLESS-R
//! should be ≈0 (n-independent once n > 1/λ), the others ≈1 (and exact ≈3).

use super::fig2::{fig2_scaling, scaling_exponent, Fig2Config};
use super::Method;
use crate::util::table::{fnum, Table};

/// Configuration of the Table-1 scaling measurement.
#[derive(Clone, Debug)]
pub struct Table1Config {
    pub sizes: Vec<usize>,
    pub lambda: f64,
    pub sigma: f64,
    pub seed: u64,
    pub methods: Vec<Method>,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            sizes: vec![1_000, 2_000, 4_000, 8_000],
            lambda: 1e-3,
            sigma: 4.0,
            seed: 0,
            methods: Method::scalable().to_vec(),
        }
    }
}

/// Theoretical n-exponent of each method's runtime at fixed λ
/// (for n beyond the 1/λ crossover).
pub fn theory_exponent(m: Method) -> f64 {
    match m {
        Method::Bless | Method::BlessR => 0.0,
        Method::Uniform => 0.0,
        Method::ExactRls => 3.0,
        Method::TwoPass | Method::Rrls | Method::Squeak => 1.0,
    }
}

/// Run the measurement and produce the Table-1 comparison.
pub fn table1_complexity(cfg: &Table1Config) -> (Table, Table) {
    let f2 = Fig2Config {
        sizes: cfg.sizes.clone(),
        sigma: cfg.sigma,
        lambda: cfg.lambda,
        seed: cfg.seed,
        methods: cfg.methods.clone(),
    };
    let raw = fig2_scaling(&f2);
    let mut summary = Table::new(
        &format!(
            "Table 1: empirical time exponent in n at λ={:.0e} (sizes {:?})",
            cfg.lambda, cfg.sizes
        ),
        &["method", "empirical_exp", "theory_exp", "final_|J|"],
    );
    for &m in &cfg.methods {
        let emp = scaling_exponent(&raw, m);
        let last_j = raw
            .rows
            .iter()
            .rev()
            .find(|r| r[1] == m.name())
            .map(|r| r[4].clone())
            .unwrap_or_default();
        summary.row(&[
            m.name().to_string(),
            fnum(emp),
            fnum(theory_exponent(m)),
            last_j,
        ]);
    }
    (raw, summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponents_reported_for_each_method() {
        let cfg = Table1Config {
            sizes: vec![300, 600],
            lambda: 5e-3,
            methods: vec![Method::Bless, Method::Squeak],
            ..Default::default()
        };
        let (raw, summary) = table1_complexity(&cfg);
        assert_eq!(raw.rows.len(), 4);
        assert_eq!(summary.rows.len(), 2);
        assert_eq!(summary.rows[0][0], "BLESS");
    }

    #[test]
    fn theory_exponents_match_paper() {
        assert_eq!(theory_exponent(Method::Bless), 0.0);
        assert_eq!(theory_exponent(Method::Squeak), 1.0);
        assert_eq!(theory_exponent(Method::ExactRls), 3.0);
    }
}
