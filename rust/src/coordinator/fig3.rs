//! Figure 3 — stability in λ_falkon: classification error after 5 CG
//! iterations across a λ_falkon sweep, FALKON-BLESS vs FALKON-UNI.
//!
//! Paper claim: the BLESS-center model has a *wider* region of λ_falkon
//! within 95% of its best error (i.e. leverage-score centers make the
//! solver less sensitive to under-regularization).

use crate::bless::{bless, BlessConfig};
use crate::data::{classification_error, Dataset};
use crate::falkon::Falkon;
use crate::kernels::KernelEngine;
use crate::leverage::WeightedSet;
use crate::rng::Rng;
use crate::util::table::{fnum, Table};

/// Configuration of the λ-stability sweep.
#[derive(Clone, Debug)]
pub struct Fig3Config {
    pub sigma: f64,
    pub lambda_bless: f64,
    /// λ_falkon sweep grid (log-spaced).
    pub lambdas: Vec<f64>,
    pub iterations: usize,
    pub seed: u64,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config {
            sigma: 4.0,
            lambda_bless: 1e-3,
            lambdas: (0..10).map(|i| 10f64.powf(-1.0 - 0.6 * i as f64)).collect(),
            iterations: 5,
            seed: 0,
        }
    }
}

/// Sweep result: per-λ c-err for both center choices + the width (in
/// decades) of each method's 95%-optimal region.
pub struct Fig3Result {
    pub table: Table,
    pub bless_region_decades: f64,
    pub uni_region_decades: f64,
}

/// Run the sweep.
pub fn fig3_stability(
    engine: &dyn KernelEngine,
    train_y: &[f64],
    test: &Dataset,
    cfg: &Fig3Config,
) -> anyhow::Result<Fig3Result> {
    // centers chosen once per method, reused across the λ sweep
    let mut rng = Rng::seeded(cfg.seed.wrapping_add(11));
    let path = bless(engine, cfg.lambda_bless, &BlessConfig::default(), &mut rng);
    let bless_set = path.final_set().clone();
    let m = bless_set.len();
    let mut rng = Rng::seeded(cfg.seed.wrapping_add(12));
    let uni_idx = rng.sample_without_replacement(engine.n(), m.min(engine.n()));

    let mut table = Table::new(
        &format!(
            "Figure 3: c-err after {} iterations vs λ_falkon (M={})",
            cfg.iterations, m
        ),
        &["lambda", "BLESS_cerr", "UNI_cerr"],
    );
    let mut errs_b = Vec::new();
    let mut errs_u = Vec::new();
    for &lam in &cfg.lambdas {
        let e_b = run_once(engine, train_y, test, &bless_set.with_lambda(lam), lam, cfg)?;
        let uni_set = WeightedSet::uniform(uni_idx.clone(), lam);
        let e_u = run_once(engine, train_y, test, &uni_set, lam, cfg)?;
        errs_b.push(e_b);
        errs_u.push(e_u);
        table.row(&[fnum(lam), fnum(e_b), fnum(e_u)]);
    }
    let width = |errs: &[f64]| -> f64 {
        // width (in decades of λ) of the region within 5% of the best err
        let best = errs.iter().cloned().fold(f64::INFINITY, f64::min);
        let thresh = best * 1.05 + 1e-12;
        let lam_ln: Vec<f64> = cfg.lambdas.iter().map(|l| l.log10()).collect();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for (i, &e) in errs.iter().enumerate() {
            if e <= thresh {
                lo = lo.min(lam_ln[i]);
                hi = hi.max(lam_ln[i]);
            }
        }
        (hi - lo).max(0.0)
    };
    Ok(Fig3Result {
        table,
        bless_region_decades: width(&errs_b),
        uni_region_decades: width(&errs_u),
    })
}

impl WeightedSet {
    /// Copy with a different λ tag (the Figure-3 sweep reuses one center
    /// set across many λ_falkon values).
    pub fn with_lambda(&self, lambda: f64) -> WeightedSet {
        WeightedSet { indices: self.indices.clone(), weights: self.weights.clone(), lambda }
    }
}

fn run_once(
    engine: &dyn KernelEngine,
    train_y: &[f64],
    test: &Dataset,
    set: &WeightedSet,
    lambda: f64,
    cfg: &Fig3Config,
) -> anyhow::Result<f64> {
    let solver = Falkon::new(engine, set, lambda)?;
    let model = solver.fit(train_y, cfg.iterations, None)?;
    let scores = model.predict(engine, &test.x);
    Ok(classification_error(&scores, &test.y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};

    #[test]
    fn sweep_runs_and_regions_nonneg() {
        let mut rng = Rng::seeded(9);
        let ds = susy_like(600, &mut rng);
        let (train, test) = ds.split(0.3, &mut rng);
        let eng = NativeEngine::new(train.x.clone(), Gaussian::new(4.0));
        let cfg = Fig3Config {
            lambdas: vec![1e-2, 1e-3, 1e-4, 1e-5],
            iterations: 4,
            ..Default::default()
        };
        let res = fig3_stability(&eng, &train.y, &test, &cfg).unwrap();
        assert_eq!(res.table.rows.len(), 4);
        assert!(res.bless_region_decades >= 0.0);
        assert!(res.uni_region_decades >= 0.0);
        // errors are valid probabilities
        for r in &res.table.rows {
            let e: f64 = r[1].parse().unwrap();
            assert!((0.0..=1.0).contains(&e));
        }
    }
}
