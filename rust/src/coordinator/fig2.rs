//! Figure 2 — runtime vs dataset size at fixed λ.
//!
//! Paper: n from 1 000 to 70 000, λ = 1e-3; previous algorithms' runtime
//! grows near-linearly in n while BLESS/BLESS-R stay at a constant
//! `O(1/λ)` cost. We reproduce the same sweep (n capped by the one-core
//! budget; the *shape* — flat vs linear — is the claim under test).

use super::{run_method, Method};
use crate::data::susy_like;
use crate::kernels::{Gaussian, NativeEngine};
use crate::rng::Rng;
use crate::util::table::{fnum, Table};
use crate::util::timed;

/// Configuration of the Figure-2 sweep.
#[derive(Clone, Debug)]
pub struct Fig2Config {
    pub sizes: Vec<usize>,
    pub sigma: f64,
    pub lambda: f64,
    pub seed: u64,
    pub methods: Vec<Method>,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            sizes: vec![1_000, 2_000, 4_000, 8_000],
            sigma: 4.0,
            lambda: 1e-3,
            seed: 0,
            methods: Method::scalable().to_vec(),
        }
    }
}

/// Result: one row per (n, method) with wallclock and score-evaluation
/// counts, plus a per-method log-log slope summary appended by the CLI.
pub fn fig2_scaling(cfg: &Fig2Config) -> Table {
    let mut table = Table::new(
        &format!("Figure 2: runtime vs n at λ={:.0e}", cfg.lambda),
        &["n", "method", "time_s", "score_evals", "|J|"],
    );
    for &n in &cfg.sizes {
        let ds = susy_like(n, &mut Rng::seeded(cfg.seed.wrapping_add(n as u64)));
        let eng = NativeEngine::new(ds.x, Gaussian::new(cfg.sigma));
        for &m in &cfg.methods {
            let mut rng = Rng::seeded(cfg.seed ^ 0xF1E2);
            let ((set, evals), secs) =
                timed(|| run_method(m, &eng, cfg.lambda, (1.0 / cfg.lambda) as usize, &mut rng));
            table.row(&[
                n.to_string(),
                m.name().to_string(),
                fnum(secs),
                evals.to_string(),
                set.len().to_string(),
            ]);
        }
    }
    table
}

/// Fit the log-log slope of time vs n for one method from a fig2 table —
/// the Table-1 empirical scaling exponent (≈0 for BLESS, ≈1 for others).
pub fn scaling_exponent(table: &Table, method: Method) -> f64 {
    let pts: Vec<(f64, f64)> = table
        .rows
        .iter()
        .filter(|r| r[1] == method.name())
        .map(|r| {
            let n: f64 = r[0].parse().unwrap();
            let t: f64 = r[2].parse().unwrap();
            (n.ln(), t.max(1e-9).ln())
        })
        .collect();
    assert!(pts.len() >= 2, "need at least two sizes");
    let mx = crate::util::mean(&pts.iter().map(|p| p.0).collect::<Vec<_>>());
    let my = crate::util::mean(&pts.iter().map(|p| p.1).collect::<Vec<_>>());
    let num: f64 = pts.iter().map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = pts.iter().map(|(x, _)| (x - mx) * (x - mx)).sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_and_slopes_differ() {
        let cfg = Fig2Config {
            sizes: vec![300, 600, 1_200],
            lambda: 5e-3,
            methods: vec![Method::Bless, Method::TwoPass],
            ..Default::default()
        };
        let t = fig2_scaling(&cfg);
        assert_eq!(t.rows.len(), 6);
        let s_bless = scaling_exponent(&t, Method::Bless);
        let s_tp = scaling_exponent(&t, Method::TwoPass);
        // Two-Pass must scale strictly worse in n than BLESS
        assert!(
            s_tp > s_bless - 0.2,
            "two-pass slope {s_tp} vs bless {s_bless}"
        );
    }
}
