//! Figure 2 — runtime vs dataset size at fixed λ.
//!
//! Paper: n from 1 000 to 70 000, λ = 1e-3; previous algorithms' runtime
//! grows near-linearly in n while BLESS/BLESS-R stay at a constant
//! `O(1/λ)` cost. We reproduce the same sweep (n capped by the one-core
//! budget; the *shape* — flat vs linear — is the claim under test).

use super::{run_method, Method};
use crate::data::susy_like;
use crate::kernels::{Gaussian, NativeEngine};
use crate::leverage::{parse_estimator, run_estimator};
use crate::rng::Rng;
use crate::util::table::{fnum, Table};
use crate::util::timed;

/// Configuration of the Figure-2 sweep.
#[derive(Clone, Debug)]
pub struct Fig2Config {
    pub sizes: Vec<usize>,
    pub sigma: f64,
    pub lambda: f64,
    pub seed: u64,
    pub methods: Vec<Method>,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            sizes: vec![1_000, 2_000, 4_000, 8_000],
            sigma: 4.0,
            lambda: 1e-3,
            seed: 0,
            methods: Method::scalable().to_vec(),
        }
    }
}

/// Result: one row per (n, method) with wallclock and score-evaluation
/// counts, plus a per-method log-log slope summary appended by the CLI.
pub fn fig2_scaling(cfg: &Fig2Config) -> Table {
    let mut table = Table::new(
        &format!("Figure 2: runtime vs n at λ={:.0e}", cfg.lambda),
        &["n", "method", "time_s", "score_evals", "|J|"],
    );
    for &n in &cfg.sizes {
        let ds = susy_like(n, &mut Rng::seeded(cfg.seed.wrapping_add(n as u64)));
        let eng = NativeEngine::new(ds.x, Gaussian::new(cfg.sigma));
        for &m in &cfg.methods {
            let mut rng = Rng::seeded(cfg.seed ^ 0xF1E2);
            let ((set, evals), secs) =
                timed(|| run_method(m, &eng, cfg.lambda, (1.0 / cfg.lambda) as usize, &mut rng));
            table.row(&[
                n.to_string(),
                m.name().to_string(),
                fnum(secs),
                evals.to_string(),
                set.len().to_string(),
            ]);
        }
    }
    table
}

/// The Figure-2 sweep over estimator-family members instead of
/// samplers: one row per (n, estimator) with wall-clock, metered
/// kernel-entry evaluations and peak dense workspace — how each
/// estimator's *total* cost (not just score evals) scales in `n`.
pub fn fig2_estimator_scaling(cfg: &Fig2Config, specs: &[String]) -> anyhow::Result<Table> {
    let mut table = Table::new(
        &format!("Estimator scaling: cost vs n at λ={:.0e}", cfg.lambda),
        &["n", "estimator", "time_s", "kernel_evals", "peak_MB"],
    );
    for &n in &cfg.sizes {
        let ds = susy_like(n, &mut Rng::seeded(cfg.seed.wrapping_add(n as u64)));
        let eng = NativeEngine::new(ds.x, Gaussian::new(cfg.sigma));
        for spec in specs {
            let est = parse_estimator(spec)
                .ok_or_else(|| anyhow::anyhow!("unknown estimator spec `{spec}`"))?;
            let mut rng = Rng::seeded(cfg.seed ^ 0xE57A ^ n as u64);
            let (res, secs) = timed(|| run_estimator(est.as_ref(), &eng, cfg.lambda, &mut rng));
            let e = res?;
            table.row(&[
                n.to_string(),
                est.name(),
                fnum(secs),
                e.kernel_evals.to_string(),
                fnum(e.peak_bytes as f64 / 1e6),
            ]);
        }
    }
    Ok(table)
}

/// Fit the log-log slope of time vs n for one method from a fig2 table —
/// the Table-1 empirical scaling exponent (≈0 for BLESS, ≈1 for others).
pub fn scaling_exponent(table: &Table, method: Method) -> f64 {
    scaling_exponent_for(table, method.name())
}

/// [`scaling_exponent`] generalized to any row label in column 1 — the
/// estimator-shootout tables put [`crate::leverage::LeverageEstimator`]
/// names there instead of [`Method`] names.
pub fn scaling_exponent_for(table: &Table, name: &str) -> f64 {
    let pts: Vec<(f64, f64)> = table
        .rows
        .iter()
        .filter(|r| r[1] == name)
        .map(|r| {
            let n: f64 = r[0].parse().unwrap();
            let t: f64 = r[2].parse().unwrap();
            (n.ln(), t.max(1e-9).ln())
        })
        .collect();
    assert!(pts.len() >= 2, "need at least two sizes");
    let mx = crate::util::mean(&pts.iter().map(|p| p.0).collect::<Vec<_>>());
    let my = crate::util::mean(&pts.iter().map(|p| p.1).collect::<Vec<_>>());
    let num: f64 = pts.iter().map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = pts.iter().map(|(x, _)| (x - mx) * (x - mx)).sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_and_slopes_differ() {
        let cfg = Fig2Config {
            sizes: vec![300, 600, 1_200],
            lambda: 5e-3,
            methods: vec![Method::Bless, Method::TwoPass],
            ..Default::default()
        };
        let t = fig2_scaling(&cfg);
        assert_eq!(t.rows.len(), 6);
        let s_bless = scaling_exponent(&t, Method::Bless);
        let s_tp = scaling_exponent(&t, Method::TwoPass);
        // Two-Pass must scale strictly worse in n than BLESS
        assert!(
            s_tp > s_bless - 0.2,
            "two-pass slope {s_tp} vs bless {s_bless}"
        );
    }

    #[test]
    fn estimator_sweep_tabulates_costs() {
        let cfg = Fig2Config { sizes: vec![150, 300], lambda: 1e-2, ..Default::default() };
        let specs = vec!["srft:64".to_string(), "rls-nystrom:64".to_string()];
        let t = fig2_estimator_scaling(&cfg, &specs).unwrap();
        assert_eq!(t.rows.len(), 4);
        // kernel evals metered: the sketched path evaluates the full n²
        let evals: f64 = t.rows[0][3].parse().unwrap();
        assert!(evals >= (150 * 150) as f64, "evals {evals}");
        // the generalized slope fit accepts estimator names
        let s = scaling_exponent_for(&t, "srft(s=64)");
        assert!(s.is_finite());
        assert!(fig2_estimator_scaling(&cfg, &["bogus".to_string()]).is_err());
    }
}
