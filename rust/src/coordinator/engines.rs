//! Engine selection: native rust vs the PJRT/Pallas production path.

use crate::kernels::{Gaussian, KernelEngine, NativeEngine};
use crate::linalg::Matrix;
use crate::runtime::{find_artifact_dir, XlaEngine};

/// Which compute backend evaluates kernel blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-rust blocked evaluation (always available).
    Native,
    /// AOT-compiled Pallas tiles via PJRT (requires `make artifacts`).
    Xla,
    /// Prefer XLA, fall back to native when artifacts are missing.
    Auto,
}

impl EngineKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_lowercase().as_str() {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            "auto" => Some(EngineKind::Auto),
            _ => None,
        }
    }
}

/// A built engine (enum so call sites stay object-safe and allocation-free).
pub enum Engine {
    Native(NativeEngine),
    Xla(XlaEngine),
}

impl Engine {
    /// Borrow as the trait object every algorithm consumes.
    pub fn as_dyn(&self) -> &dyn KernelEngine {
        match self {
            Engine::Native(e) => e,
            Engine::Xla(e) => e,
        }
    }

    /// Backend label for logs.
    pub fn label(&self) -> &'static str {
        match self {
            Engine::Native(_) => "native",
            Engine::Xla(_) => "xla",
        }
    }
}

/// Build the requested engine over a dataset.
pub fn build_engine(kind: EngineKind, x: Matrix, kernel: Gaussian) -> anyhow::Result<Engine> {
    match kind {
        EngineKind::Native => Ok(Engine::Native(NativeEngine::new(x, kernel))),
        EngineKind::Xla => {
            let dir = find_artifact_dir()
                .ok_or_else(|| anyhow::anyhow!("artifacts not found — run `make artifacts`"))?;
            Ok(Engine::Xla(XlaEngine::from_artifacts(&dir, x, kernel)?))
        }
        EngineKind::Auto => match find_artifact_dir() {
            Some(dir) => Ok(Engine::Xla(XlaEngine::from_artifacts(&dir, x, kernel)?)),
            None => Ok(Engine::Native(NativeEngine::new(x, kernel))),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::rng::Rng;

    #[test]
    fn native_always_builds() {
        let ds = susy_like(50, &mut Rng::seeded(0));
        let e = build_engine(EngineKind::Native, ds.x, Gaussian::new(2.0)).unwrap();
        assert_eq!(e.label(), "native");
        assert_eq!(e.as_dyn().n(), 50);
    }

    #[test]
    fn auto_prefers_xla_when_artifacts_exist() {
        let ds = susy_like(50, &mut Rng::seeded(1));
        let e = build_engine(EngineKind::Auto, ds.x, Gaussian::new(2.0)).unwrap();
        if find_artifact_dir().is_some() {
            assert_eq!(e.label(), "xla");
        } else {
            assert_eq!(e.label(), "native");
        }
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(EngineKind::parse("XLA"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("bogus"), None);
    }
}
