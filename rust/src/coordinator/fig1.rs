//! Figure 1 — leverage-score relative accuracy (R-ACC).
//!
//! Paper setup: SUSY subset n = 70 000, Gaussian σ = 4, λ = 1e-5, exact
//! scores as reference, 10 repetitions; reports runtime, mean R-ACC and
//! the 5ᵗʰ/95ᵗʰ quantiles per method.
//!
//! Our substitution (DESIGN.md §5): SUSY-like n = 8 000 (exact RLS is
//! O(n³) and this box has one core), λ rescaled to keep d_eff in the same
//! regime. The *statistics* compared are identical.

use super::{run_method, Method};
use crate::kernels::KernelEngine;
use crate::leverage::{
    exact_leverage_scores, parse_estimator, run_estimator, LsGenerator, RAccStats,
};
use crate::rng::Rng;
use crate::util::table::{fnum, Table};
use crate::util::{mean, timed};

/// Configuration of the Figure-1 experiment.
#[derive(Clone, Debug)]
pub struct Fig1Config {
    pub n: usize,
    pub sigma: f64,
    pub lambda: f64,
    pub reps: usize,
    pub seed: u64,
    /// Columns for the Uniform baseline (the other methods size themselves).
    pub uniform_m: usize,
    pub methods: Vec<Method>,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            n: 2_000,
            sigma: 4.0,
            lambda: 1e-4,
            reps: 5,
            seed: 0,
            uniform_m: 400,
            methods: vec![
                Method::Bless,
                Method::BlessR,
                Method::Squeak,
                Method::Uniform,
                Method::Rrls,
                Method::TwoPass,
            ],
        }
    }
}

/// Run the accuracy comparison; returns the Figure-1 table
/// (method, time, mean R-ACC, 5ᵗʰ/95ᵗʰ quantiles, |J|).
///
/// Errors when the exact reference (or a method's generator) cannot
/// factor the regularized kernel matrix — degenerate data, not a bug.
pub fn fig1_accuracy(engine: &dyn KernelEngine, cfg: &Fig1Config) -> anyhow::Result<Table> {
    let n = engine.n();
    // exact reference once (shared across methods and reps)
    let (exact, exact_secs) = timed(|| exact_leverage_scores(engine, cfg.lambda));
    let exact = exact?;
    let mut table = Table::new(
        &format!(
            "Figure 1: R-ACC at λ={:.0e}, n={}, σ={}, {} reps (exact ref: {:.1}s)",
            cfg.lambda, n, cfg.sigma, cfg.reps, exact_secs
        ),
        &["method", "time_s", "R-ACC", "q05", "q95", "|J|"],
    );

    for &m in &cfg.methods {
        let mut times = Vec::new();
        let mut means = Vec::new();
        let mut q05s = Vec::new();
        let mut q95s = Vec::new();
        let mut sizes = Vec::new();
        for rep in 0..cfg.reps {
            let mut rng = Rng::seeded(cfg.seed ^ (rep as u64 + 1) * 0x9E37);
            let ((set, _), secs) =
                timed(|| run_method(m, engine, cfg.lambda, cfg.uniform_m, &mut rng));
            let gen = LsGenerator::new(engine, &set, cfg.lambda)?;
            let approx = gen.scores_all();
            let stats = RAccStats::from_scores(&approx, &exact);
            times.push(secs);
            means.push(stats.mean);
            q05s.push(stats.q05);
            q95s.push(stats.q95);
            sizes.push(set.len() as f64);
        }
        table.row(&[
            m.name().to_string(),
            fnum(mean(&times)),
            fnum(mean(&means)),
            fnum(mean(&q05s)),
            fnum(mean(&q95s)),
            format!("{:.0}", mean(&sizes)),
        ]);
    }
    Ok(table)
}

/// Configuration of the estimator shoot-out — the Figure-1 experiment
/// widened from samplers to the full [`crate::leverage::LeverageEstimator`]
/// family (exact / BLESS / RRLS / count-sketch / SRFT / recursive-RLS
/// Nyström), with cost accounting per estimator.
#[derive(Clone, Debug)]
pub struct ShootoutConfig {
    pub lambda: f64,
    pub reps: usize,
    pub seed: u64,
    /// Estimator spec strings, e.g. `"srft:256"` — see
    /// [`crate::leverage::parse_estimator`].
    pub specs: Vec<String>,
}

impl Default for ShootoutConfig {
    fn default() -> Self {
        ShootoutConfig {
            lambda: 1e-2,
            reps: 3,
            seed: 7,
            specs: ["exact", "bless", "rrls", "count-sketch:256", "srft:256", "rls-nystrom:256"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }
}

/// Run every estimator in `cfg.specs` against the exact reference and
/// tabulate accuracy (mean R-ACC + 5ᵗʰ/95ᵗʰ quantiles of the score
/// ratios), wall-clock, kernel-entry evaluations, and peak dense
/// workspace — the per-estimator rows behind `BENCH_estimators.json`.
pub fn fig1_estimator_shootout(
    engine: &dyn KernelEngine,
    cfg: &ShootoutConfig,
) -> anyhow::Result<Table> {
    let n = engine.n();
    let exact = exact_leverage_scores(engine, cfg.lambda)?;
    let mut table = Table::new(
        &format!(
            "Estimator shoot-out: R-ACC vs cost at λ={:.0e}, n={}, {} reps",
            cfg.lambda, n, cfg.reps
        ),
        &["estimator", "time_s", "R-ACC", "q05", "q95", "kernel_evals", "peak_MB"],
    );
    for spec in &cfg.specs {
        let est = parse_estimator(spec)
            .ok_or_else(|| anyhow::anyhow!("unknown estimator spec `{spec}`"))?;
        let mut times = Vec::new();
        let mut means = Vec::new();
        let mut q05s = Vec::new();
        let mut q95s = Vec::new();
        let mut evals = Vec::new();
        let mut peaks = Vec::new();
        for rep in 0..cfg.reps {
            let mut rng = Rng::seeded(cfg.seed ^ (rep as u64 + 1) * 0x9E37);
            let (res, secs) = timed(|| run_estimator(est.as_ref(), engine, cfg.lambda, &mut rng));
            let e = res?;
            let stats = RAccStats::from_scores(&e.scores, &exact);
            times.push(secs);
            means.push(stats.mean);
            q05s.push(stats.q05);
            q95s.push(stats.q95);
            evals.push(e.kernel_evals as f64);
            peaks.push(e.peak_bytes as f64 / 1e6);
        }
        table.row(&[
            est.name(),
            fnum(mean(&times)),
            fnum(mean(&means)),
            fnum(mean(&q05s)),
            fnum(mean(&q95s)),
            format!("{:.0}", mean(&evals)),
            fnum(mean(&peaks)),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::Gaussian;

    fn default_engine(cfg: &Fig1Config) -> crate::kernels::NativeEngine {
        let ds = susy_like(cfg.n, &mut Rng::seeded(cfg.seed.wrapping_add(77)));
        crate::kernels::NativeEngine::new(ds.x, Gaussian::new(cfg.sigma))
    }

    #[test]
    fn small_fig1_runs_and_has_sane_raccs() {
        let cfg = Fig1Config {
            n: 250,
            reps: 2,
            lambda: 1e-2,
            uniform_m: 60,
            methods: vec![Method::Bless, Method::Uniform],
            ..Default::default()
        };
        let eng = default_engine(&cfg);
        let t = fig1_accuracy(&eng, &cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
        // BLESS mean R-ACC close to 1
        let bless_racc: f64 = t.rows[0][2].parse().unwrap();
        assert!(bless_racc > 0.5 && bless_racc < 2.0, "R-ACC {bless_racc}");
    }

    #[test]
    fn estimator_shootout_tabulates_every_spec() {
        let fig = Fig1Config { n: 150, lambda: 1e-2, ..Default::default() };
        let eng = default_engine(&fig);
        let cfg = ShootoutConfig {
            lambda: 1e-2,
            reps: 1,
            seed: 3,
            specs: vec!["exact".into(), "srft:64".into(), "count-sketch:64".into()],
        };
        let t = fig1_estimator_shootout(&eng, &cfg).unwrap();
        assert_eq!(t.rows.len(), 3);
        // the exact row compares the reference to itself: mean ratio 1
        let racc: f64 = t.rows[0][2].parse().unwrap();
        assert!((racc - 1.0).abs() < 1e-9, "exact R-ACC {racc}");
        // cost columns populated: exact evaluates the full n² kernel block
        let evals: f64 = t.rows[0][5].parse().unwrap();
        assert!(evals >= (150 * 150) as f64, "kernel evals {evals}");
        // unknown specs are an error, not a panic
        let bad = ShootoutConfig { specs: vec!["no-such".into()], ..cfg };
        assert!(fig1_estimator_shootout(&eng, &bad).is_err());
    }
}
