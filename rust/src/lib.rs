//! # BLESS — fast ridge leverage score sampling and optimal kernel learning
//!
//! Production reproduction of *"On Fast Leverage Score Sampling and Optimal
//! Learning"* (Rudi, Calandriello, Carratino, Rosasco — NeurIPS 2018).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1 (Pallas)** — tiled Gaussian-kernel compute kernels, authored in
//!   `python/compile/kernels/` and AOT-lowered to HLO text.
//! * **L2 (JAX)** — the kernel-block / block-matvec compute graphs in
//!   `python/compile/model.py`, lowered once by `python/compile/aot.py`.
//! * **L3 (this crate)** — the paper's algorithmic contribution: the
//!   [`bless`] samplers, the comparison [`baselines`], the [`falkon`]
//!   preconditioned solver, and the experiment [`coordinator`]. The rust
//!   side loads the AOT artifacts through [`runtime`] (PJRT CPU client)
//!   and never touches python at run time.
//!
//! Underneath both stacks sits the **parallel compute core**
//! ([`util::pool`]): one process-wide threadpool (CLI `--threads`,
//! default all cores) that GEMM, Gaussian kernel blocks, triangular
//! solves — and through them BLESS, the baselines, FALKON and the
//! serving batches — dispatch onto. Work is split into fixed blocks
//! whose boundaries never depend on the thread count, so every result
//! is bit-identical to the single-threaded path.
//!
//! FALKON's `K_nM` products additionally run through the
//! **memory-budgeted panel cache** ([`kernels::PanelCache`], CLI
//! `--mem-budget`): row tiles of `K_nM` within the budget are evaluated
//! once per fit and streamed from memory on every CG iteration; tiles
//! beyond it are recomputed — bit-identical at any budget, so training
//! pays for kernel evaluation ~once instead of once per iteration.
//!
//! On top of the training stack sits the **serving tier** ([`serve`]):
//! a fitted model is packaged into a self-contained, checksummed
//! artifact (kernel config + center rows + `α` — no training data
//! needed at inference) in either a human-readable JSON or a raw
//! little-endian binary codec, and served over TCP by a micro-batching,
//! multi-threaded prediction server hosting a registry of named models
//! with hot reload and queue-depth backpressure.
//!
//! ## Quick start: reproduce the paper
//!
//! ```no_run
//! use bless::data::susy_like;
//! use bless::kernels::{Gaussian, NativeEngine};
//! use bless::bless::{bless, BlessConfig};
//! use bless::rng::Rng;
//!
//! let ds = susy_like(2_000, &mut Rng::seeded(0));
//! let engine = NativeEngine::new(ds.x.clone(), Gaussian::new(4.0));
//! let out = bless(&engine, 1e-3, &BlessConfig::default(), &mut Rng::seeded(1));
//! println!("selected {} Nyström centers", out.final_set().indices.len());
//! ```
//!
//! ## Quick start: train → save → serve → predict
//!
//! ```bash
//! repro train --n 8000 --save model.bin         # BLESS + FALKON, saved
//! #   .bin/.bless → binary codec; other extensions → JSON
//! repro convert --in model.bin --out model.json # re-encode either way
//! repro serve --models susy=model.bin,higgs=other.bin \
//!             --port 7878 --workers 4 \
//!             --max-batch 64 --max-queue 1024   # TCP prediction server
//! repro predict --model model.bin \
//!             --query "0.1,-0.4,..."            # offline scoring
//! ```
//!
//! Over the wire the server speaks line-delimited JSON
//! (`{"id":1,"model":"susy","x":[…]}` → `{"id":1,"y":0.83,"cached":false}`);
//! see [`serve::protocol`]. Concurrent single-point requests are
//! coalesced into one kernel-block GEMM per tick by [`serve::batcher`];
//! `{"op":"admin","cmd":"reload",…}` hot-swaps one model without
//! dropping in-flight requests ([`serve::registry`]), and a full model
//! queue sheds load with a structured `overloaded` reply.
//!
//! Everything above is observable through the **observability tier**
//! ([`obs`]): a global registry of counters, gauges, and lock-free
//! log-bucket latency histograms (p50/p95/p99 per model), a span timer
//! over the training pipeline (`train --trace`), and an HTTP scrape
//! endpoint (`serve --metrics-addr` → `GET /metrics` in Prometheus text
//! format, plus `/healthz` and `/varz`). Instrumentation observes and
//! never partitions, so enabling it changes no computed bit.
//!
//! The serve tier is hardened for production failure modes and proven
//! by a **fault-injection harness** ([`faults`], `serve --faults` /
//! `BLESS_FAULTS`): seeded, deterministic chaos at the tier's IO and
//! compute boundaries, against which the server holds per-request
//! deadlines (`deadline_ms` / `--default-deadline`), socket IO
//! timeouts, panic-isolated workers with supervised respawn, a
//! per-model circuit breaker (quarantine + half-open recovery), and
//! crash-safe artifact writes ([`util::fsio`]). With no plan armed the
//! harness is a single relaxed atomic load — serve output stays
//! bit-identical.
//!
//! Closing the loop, the **continuous-training lifecycle tier**
//! ([`lifecycle`], `serve --retrain-every`) keeps a served model fresh
//! as data drifts: crash-resumable checkpointed fits
//! (`train --checkpoint` / `--resume`, the `BLESSCKPT` codec in
//! [`falkon::ckpt`]), warm-started refits ([`falkon::Falkon::refit`]),
//! a holdout-RMSE promotion gate with quarantine for failed candidates,
//! and automatic rollback when a freshly promoted model trips its
//! circuit breaker inside the probation window.
pub mod baselines;
pub mod bless;
pub mod coordinator;
pub mod data;
pub mod falkon;
pub mod faults;
pub mod kernels;
pub mod leverage;
pub mod lifecycle;
pub mod linalg;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod util;
