//! PJRT runtime: load the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and serve them to the L3 hot path.
//!
//! * [`Manifest`] — parsed `artifacts/manifest.json` (tile geometry +
//!   artifact inventory), validated at load time.
//! * [`PjrtRuntime`] — a PJRT CPU client with every artifact compiled
//!   once (`HloModuleProto::from_text_file` → `client.compile`); exposes
//!   typed tile calls.
//! * [`XlaEngine`] — a [`crate::kernels::KernelEngine`] whose kernel
//!   blocks are evaluated by the compiled Pallas/JAX tiles: the
//!   production configuration of the three-layer stack. Python never
//!   runs on this path.

mod engine;
mod pjrt;

pub use engine::XlaEngine;
pub use pjrt::{Manifest, PjrtRuntime};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$BLESS_ARTIFACTS`, or `artifacts/`
/// relative to the current dir or its ancestors (so tests work from the
/// crate root and binaries from anywhere in the repo).
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("BLESS_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
