//! [`XlaEngine`] — the production [`KernelEngine`]: kernel blocks
//! evaluated by the AOT-compiled Pallas tiles through PJRT.
//!
//! Dynamic shapes (`|J|`, `|U_h|`, `n`) are mapped onto the fixed
//! `(T, D)` tile contract by zero-padding: padded feature columns are
//! exact for the RBF kernel (they add 0 to ‖x−y‖²); padded *rows*
//! produce garbage entries that are simply never copied out of the tile.

use std::path::Path;

use super::PjrtRuntime;
use crate::kernels::{Gaussian, KernelEngine};
use crate::linalg::Matrix;

/// Kernel engine backed by PJRT-compiled Pallas tiles.
pub struct XlaEngine {
    runtime: PjrtRuntime,
    kernel: Gaussian,
    /// Original data (f64, for `points()` and out-of-sample queries).
    x: Matrix,
    /// f32 copy padded to the manifest feature dim, row-major.
    xf: Vec<f32>,
    dim: usize,
    tile: usize,
}

impl XlaEngine {
    /// Build from a loaded runtime and a dataset.
    pub fn new(runtime: PjrtRuntime, x: Matrix, kernel: Gaussian) -> anyhow::Result<Self> {
        let dim = runtime.manifest.feature_dim;
        let tile = runtime.manifest.tile;
        anyhow::ensure!(
            x.cols() <= dim,
            "dataset dim {} exceeds artifact feature_dim {dim}",
            x.cols()
        );
        let mut xf = vec![0.0f32; x.rows() * dim];
        for i in 0..x.rows() {
            for (j, &v) in x.row(i).iter().enumerate() {
                xf[i * dim + j] = v as f32;
            }
        }
        Ok(XlaEngine { runtime, kernel, x, xf, dim, tile })
    }

    /// Convenience: load artifacts from `dir` and build the engine.
    pub fn from_artifacts(dir: &Path, x: Matrix, kernel: Gaussian) -> anyhow::Result<Self> {
        Ok(Self::new(PjrtRuntime::load(dir)?, x, kernel)?)
    }

    /// Tile size `T` of the artifact contract.
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Gather `idx` rows (padded f32) into a `(T, D)` tile buffer;
    /// rows beyond `idx.len()` stay zero.
    fn gather_tile(&self, idx: &[usize], out: &mut [f32]) {
        debug_assert!(idx.len() <= self.tile);
        out.fill(0.0);
        for (r, &i) in idx.iter().enumerate() {
            let src = &self.xf[i * self.dim..(i + 1) * self.dim];
            out[r * self.dim..r * self.dim + self.dim].copy_from_slice(src);
        }
    }

    /// Gather rows of an explicit query matrix into a tile buffer.
    fn gather_query_tile(&self, q: &Matrix, rows: std::ops::Range<usize>, out: &mut [f32]) {
        out.fill(0.0);
        for (r, i) in rows.enumerate() {
            for (j, &v) in q.row(i).iter().enumerate() {
                out[r * self.dim + j] = v as f32;
            }
        }
    }

    /// Assemble a kernel block by looping `(T×T)` tile calls.
    fn block_tiled(
        &self,
        row_tiles: &[&[usize]],
        col_tiles: &[&[usize]],
        out: &mut Matrix,
    ) -> anyhow::Result<()> {
        let t = self.tile;
        let gamma = self.kernel.gamma() as f32;
        let mut xbuf = vec![0.0f32; t * self.dim];
        let mut ybuf = vec![0.0f32; t * self.dim];
        let mut row_off = 0;
        for rt in row_tiles {
            self.gather_tile(rt, &mut xbuf);
            let mut col_off = 0;
            for ct in col_tiles {
                self.gather_tile(ct, &mut ybuf);
                let tile_out = self.runtime.rbf_block_tile(&xbuf, &ybuf, gamma)?;
                for (r, _) in rt.iter().enumerate() {
                    let dst = out.row_mut(row_off + r);
                    for (c, _) in ct.iter().enumerate() {
                        dst[col_off + c] = tile_out[r * t + c] as f64;
                    }
                }
                col_off += ct.len();
            }
            row_off += rt.len();
        }
        Ok(())
    }
}

impl KernelEngine for XlaEngine {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn kernel(&self) -> &Gaussian {
        &self.kernel
    }

    fn points(&self) -> &Matrix {
        &self.x
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        let t = self.tile;
        let mut out = Matrix::zeros(rows.len(), cols.len());
        let row_tiles: Vec<&[usize]> = rows.chunks(t).collect();
        let col_tiles: Vec<&[usize]> = cols.chunks(t).collect();
        self.block_tiled(&row_tiles, &col_tiles, &mut out)
            .expect("XLA tile execution failed");
        out
    }

    fn cross_block(&self, q: &Matrix, cols: &[usize]) -> Matrix {
        assert!(q.cols() <= self.dim, "query dim exceeds artifact feature_dim");
        let t = self.tile;
        let gamma = self.kernel.gamma() as f32;
        let mut out = Matrix::zeros(q.rows(), cols.len());
        let mut xbuf = vec![0.0f32; t * self.dim];
        let mut ybuf = vec![0.0f32; t * self.dim];
        let col_tiles: Vec<&[usize]> = cols.chunks(t).collect();
        let mut row_off = 0;
        while row_off < q.rows() {
            let row_end = (row_off + t).min(q.rows());
            self.gather_query_tile(q, row_off..row_end, &mut xbuf);
            let mut col_off = 0;
            for ct in &col_tiles {
                self.gather_tile(ct, &mut ybuf);
                let tile_out = self
                    .runtime
                    .rbf_block_tile(&xbuf, &ybuf, gamma)
                    .expect("XLA tile execution failed");
                for r in 0..(row_end - row_off) {
                    let dst = out.row_mut(row_off + r);
                    for (c, _) in ct.iter().enumerate() {
                        dst[col_off + c] = tile_out[r * t + c] as f64;
                    }
                }
                col_off += ct.len();
            }
            row_off = row_end;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::NativeEngine;
    use crate::rng::Rng;
    use crate::runtime::find_artifact_dir;

    fn engines(n: usize) -> Option<(NativeEngine, XlaEngine)> {
        let dir = find_artifact_dir()?;
        let ds = susy_like(n, &mut Rng::seeded(123));
        let kern = Gaussian::new(2.0);
        let native = NativeEngine::new(ds.x.clone(), kern.clone());
        let xla = XlaEngine::from_artifacts(&dir, ds.x, kern).ok()?;
        Some((native, xla))
    }

    #[test]
    fn xla_block_matches_native_f32_tolerance() {
        let Some((native, xla)) = engines(600) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // sizes straddling tile boundaries: < T, = T, > T
        for (nr, nc) in [(5usize, 7usize), (256, 100), (300, 300)] {
            let rows: Vec<usize> = (0..nr).map(|i| (i * 601) % 600).collect();
            let cols: Vec<usize> = (0..nc).map(|i| (i * 811) % 600).collect();
            let a = native.block(&rows, &cols);
            let b = xla.block(&rows, &cols);
            assert!(
                a.max_abs_diff(&b) < 1e-5,
                "block {nr}x{nc} max diff {}",
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn xla_cross_block_matches_native() {
        let Some((native, xla)) = engines(400) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let q = Matrix::from_fn(30, 18, |i, j| ((i * 18 + j) as f64 * 0.37).sin());
        let cols: Vec<usize> = (0..90).map(|i| (i * 13) % 400).collect();
        let a = native.cross_block(&q, &cols);
        let b = xla.cross_block(&q, &cols);
        assert!(a.max_abs_diff(&b) < 1e-5, "cross diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn xla_streaming_matvec_matches_native() {
        let Some((native, xla)) = engines(500) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let centers: Vec<usize> = (0..40).map(|i| i * 12).collect();
        let v: Vec<f64> = (0..40).map(|i| ((i as f64) * 0.3).cos()).collect();
        let a = native.knm_t_knm_matvec(&centers, &v);
        let b = xla.knm_t_knm_matvec(&centers, &v);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3 * x.abs().max(1.0), "{x} vs {y}");
        }
    }
}
