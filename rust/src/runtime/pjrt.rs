//! PJRT client + compiled-artifact registry.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Parsed `manifest.json`: tile geometry and artifact inventory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tile: usize,
    pub feature_dim: usize,
    pub files: BTreeMap<String, String>,
}

impl Manifest {
    /// Load and validate a manifest from the artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;
        let tile = j
            .get("tile")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'tile'"))?;
        let feature_dim = j
            .get("feature_dim")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'feature_dim'"))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        let mut files = BTreeMap::new();
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("artifact {name} missing 'file'"))?;
            anyhow::ensure!(dir.join(file).exists(), "artifact file {file} missing");
            files.insert(name.clone(), file.to_string());
        }
        anyhow::ensure!(!files.is_empty(), "manifest lists no artifacts");
        Ok(Manifest { tile, feature_dim, files })
    }
}

/// A PJRT CPU client holding every artifact compiled once.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    pub dir: PathBuf,
}

impl PjrtRuntime {
    /// Load + compile all artifacts in `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = BTreeMap::new();
        for (name, file) in &manifest.files {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {file}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(PjrtRuntime { client, executables, manifest, dir: dir.to_path_buf() })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a named artifact on literal inputs; returns the flat f32
    /// payload of the (1-tuple) result.
    pub fn call(&self, name: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<f32>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("read {name}: {e:?}"))
    }

    /// Build a `(rows, cols)` f32 literal from a flat slice.
    pub fn literal_2d(&self, data: &[f32], rows: usize, cols: usize) -> anyhow::Result<xla::Literal> {
        anyhow::ensure!(data.len() == rows * cols, "literal size mismatch");
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    /// Build a 1-D f32 literal.
    pub fn literal_1d(&self, data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// Build an f32 scalar literal.
    pub fn literal_scalar(&self, v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// Evaluate one `(T,D)x(T,D) → (T,T)` RBF tile.
    pub fn rbf_block_tile(
        &self,
        x: &[f32],
        y: &[f32],
        gamma: f32,
    ) -> anyhow::Result<Vec<f32>> {
        let t = self.manifest.tile;
        let d = self.manifest.feature_dim;
        let lx = self.literal_2d(x, t, d)?;
        let ly = self.literal_2d(y, t, d)?;
        let lg = self.literal_scalar(gamma);
        let out = self.call("rbf_block", &[lx, ly, lg])?;
        anyhow::ensure!(out.len() == t * t, "bad tile output size {}", out.len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifact_dir;

    #[test]
    fn manifest_parses_real_artifacts() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.tile >= 64);
        assert!(m.feature_dim >= 2);
        assert!(m.files.contains_key("rbf_block"));
    }

    #[test]
    fn runtime_loads_and_executes_tile() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let rt = PjrtRuntime::load(&dir).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu")
            || rt.platform().to_lowercase().contains("host"));
        let t = rt.manifest.tile;
        let d = rt.manifest.feature_dim;
        // identical x/y rows ⇒ unit diagonal
        let mut x = vec![0.0f32; t * d];
        for (i, v) in x.iter_mut().enumerate() {
            *v = ((i % 17) as f32) * 0.1;
        }
        let k = rt.rbf_block_tile(&x, &x, 0.5).unwrap();
        for i in 0..t {
            assert!((k[i * t + i] - 1.0).abs() < 1e-5, "diag {} = {}", i, k[i * t + i]);
        }
        // symmetric
        for i in 0..8 {
            for j in 0..8 {
                assert!((k[i * t + j] - k[j * t + i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn missing_dir_errors_cleanly() {
        assert!(Manifest::load(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
