//! Poison-tolerant locking helpers.
//!
//! `std::sync` poisons a `Mutex`/`RwLock` when a thread panics while
//! holding it, and `lock().unwrap()` then panics in *every other thread*
//! that touches the lock — one crashed worker wedges the whole serve
//! tier. All serve-tier state guarded by locks here is either
//! plain-old-data (queues of jobs, counter maps, LRU tables) or swapped
//! atomically under the guard, so a panic mid-critical-section cannot
//! leave it logically torn: recovering the guard with
//! [`PoisonError::into_inner`] is safe and keeps every other client
//! serviceable. These helpers centralize that policy so the intent
//! ("this lock survives a panicking peer") reads at the call site.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read-lock an `RwLock`, recovering from poison.
#[inline]
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock an `RwLock`, recovering from poison.
#[inline]
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Block on a condvar, recovering the guard from poison on wake.
#[inline]
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Block on a condvar with a timeout, recovering from poison on wake.
#[inline]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn poisoned_mutex_is_still_lockable() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7, "helper must see through the poison");
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn poisoned_rwlock_is_still_usable() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read(&l).len(), 3);
        write(&l).push(4);
        assert_eq!(read(&l).len(), 4);
    }

    #[test]
    fn condvar_wait_survives_a_poisoned_mutex() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        // poison the mutex first
        {
            let p3 = Arc::clone(&pair);
            let _ = std::thread::spawn(move || {
                let _g = p3.0.lock().unwrap();
                panic!("poison it");
            })
            .join();
        }
        let waker = std::thread::spawn(move || {
            *lock(&p2.0) = true;
            p2.1.notify_all();
        });
        let (mut g, _) = wait_timeout(&pair.1, lock(&pair.0), Duration::from_secs(5));
        while !*g {
            g = wait(&pair.1, g);
        }
        waker.join().unwrap();
    }
}
