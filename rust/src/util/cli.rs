//! Tiny CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and defaults. Used by `rust/src/main.rs`
//! and the example binaries.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — skips nothing.
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Positional argument `i`.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// All positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a clear message on a
    /// malformed value (user error should fail loudly).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid value for --{key}: {v:?} ({e:?})")),
        }
    }

    /// usize option.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_parse(key, default)
    }

    /// f64 option.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get_parse(key, default)
    }

    /// u64 option (seeds).
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get_parse(key, default)
    }

    /// Boolean flag presence.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_styles() {
        let a = parse("--n 100 --lambda=1e-3 run --verbose");
        assert_eq!(a.get_usize("n", 0), 100);
        assert!((a.get_f64("lambda", 0.0) - 1e-3).abs() < 1e-15);
        assert_eq!(a.pos(0), Some("run"));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn defaults_when_absent() {
        let a = parse("cmd");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_str("name", "x"), "x");
        assert_eq!(a.get_u64("seed", 5), 5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --n 3");
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn malformed_value_panics() {
        parse("--n abc").get_usize("n", 0);
    }
}
