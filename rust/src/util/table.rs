//! Result tables: aligned console output + markdown/CSV export, used by
//! the experiment coordinator to regenerate the paper's tables/figures as
//! text series.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-oriented results table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format heterogeneous cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn to_console(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], w: &[usize]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &w));
        let _ = writeln!(out, "{}", "-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &w));
        }
        out
    }

    /// GitHub-flavored markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    /// CSV rendering (no quoting — cells are numeric/simple by construction).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }

    /// Write the CSV form to `path` (creating parent dirs).
    pub fn save_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a float with engineering-friendly precision.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1e4 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_formats() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3.5, &"x"]);
        let c = t.to_console();
        assert!(c.contains("demo") && c.contains("bb"));
        let m = t.to_markdown();
        assert!(m.contains("| a | bb |") && m.contains("| 3.5 | x |"));
        let csv = t.to_csv();
        assert!(csv.starts_with("a,bb\n1,2\n"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(12345.0), "1.234e4");
        assert_eq!(fnum(0.5), "0.5000");
        assert_eq!(fnum(1e-5), "1.000e-5");
    }
}
