//! The shared compute threadpool: deterministic data-parallel loops for
//! the GEMM / kernel-block / triangular-solve hot paths.
//!
//! Every training-side hot loop in this crate — blocked GEMM, Gaussian
//! kernel-block evaluation, the `K_nM` column-block products inside
//! BLESS/RRLS/SQUEAK, FALKON's preconditioner and CG iterations — is a
//! loop over *independent blocks* that write disjoint slices of one
//! output buffer. This module parallelizes exactly that shape and
//! nothing else:
//!
//! * **One process, one thread policy.** The pool width is a single
//!   process-global knob ([`set_threads`], read by [`threads`]), set
//!   once by the CLI `--threads` flag (default: all available cores) or
//!   by `serve`'s [`crate::serve::ServeConfig::threads`]. Library code
//!   never spawns its own ad-hoc compute threads.
//! * **Deterministic by construction.** Work is split into *fixed-size*
//!   blocks whose boundaries depend only on the problem shape, never on
//!   the thread count; each block performs the identical floating-point
//!   sequence the serial code would, and blocks write disjoint output
//!   ranges. Parallel results are therefore **bit-identical** to the
//!   1-thread path (asserted by `tests/parallel_determinism.rs`).
//! * **Work-stealing-free.** Workers pull the next block index from one
//!   shared atomic counter — no per-worker deques, no stealing, no
//!   re-ordering of anything observable.
//! * **Scoped, not persistent.** [`par_for`] dispatches a crew of scoped
//!   threads per call (`std::thread::scope`) rather than parking a
//!   persistent pool: the blocked kernels it serves run for hundreds of
//!   microseconds to seconds per call, so a scoped spawn (tens of µs) is
//!   noise, and in exchange closures may borrow the stack freely (no
//!   `'static` bound), worker panics propagate to the caller exactly
//!   like serial panics, and there is no shutdown/teardown state to get
//!   wrong.
//! * **Nested-use safe.** A `par_for` issued from inside a pool worker
//!   (e.g. a parallel GEMM called from a parallelized outer loop) runs
//!   inline on that worker instead of spawning a second crew, so nesting
//!   cannot oversubscribe or deadlock.
//!
//! Call sites choose between [`par_for`] (block indices; the caller
//! handles disjointness, e.g. strided column blocks) and
//! [`par_chunks_mut`] (contiguous chunks of a mutable slice; disjointness
//! by construction).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Configured pool width; 0 means "default to available parallelism".
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// Serializes in-crate tests that mutate the process-global width, so
/// concurrent test threads don't observe each other's settings.
#[cfg(test)]
pub(crate) static CONFIG_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

// Process-lifetime dispatch counters, exported through `/metrics` and
// `train --trace`. Observability only: nothing in the pool reads them
// back, so they cannot perturb partitioning or scheduling.
static DISPATCHES: AtomicU64 = AtomicU64::new(0);
static INLINE_RUNS: AtomicU64 = AtomicU64::new(0);
static BLOCKS_RUN: AtomicU64 = AtomicU64::new(0);

/// Cumulative [`par_for`] activity since process start.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `par_for` calls that spawned a crew of scoped threads.
    pub dispatches: u64,
    /// `par_for` calls that ran inline (width 1, one block, or nested).
    pub inline_runs: u64,
    /// Total blocks executed across all calls.
    pub blocks_run: u64,
}

/// Snapshot the cumulative dispatch counters.
pub fn stats() -> PoolStats {
    PoolStats {
        dispatches: DISPATCHES.load(Ordering::Relaxed),
        inline_runs: INLINE_RUNS.load(Ordering::Relaxed),
        blocks_run: BLOCKS_RUN.load(Ordering::Relaxed),
    }
}

thread_local! {
    /// Set while the current thread is executing blocks for a `par_for`,
    /// so nested dispatches run inline instead of spawning a new crew.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Number of hardware threads available to this process (≥ 1).
pub fn available() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Set the process-global pool width. `0` restores the default
/// (= [`available`]). Takes effect for every subsequent [`par_for`];
/// in-flight dispatches are unaffected.
pub fn set_threads(n: usize) {
    CONFIGURED.store(n, Ordering::SeqCst);
}

/// The raw configured pool width: whatever was last passed to
/// [`set_threads`] (`0` = default). Lets callers that temporarily
/// override the width (e.g. the serve tier) restore the exact prior
/// setting, preserving "unset" as unset.
pub fn configured_threads() -> usize {
    CONFIGURED.load(Ordering::SeqCst)
}

/// The current pool width (≥ 1): the value set by [`set_threads`], or
/// [`available`] when unset.
pub fn threads() -> usize {
    match CONFIGURED.load(Ordering::SeqCst) {
        0 => available(),
        n => n,
    }
}

/// Restores the thread-local nesting flag even if a block panics.
struct NestGuard(bool);

impl NestGuard {
    fn enter() -> NestGuard {
        NestGuard(IN_POOL.with(|c| c.replace(true)))
    }
}

impl Drop for NestGuard {
    fn drop(&mut self) {
        let prev = self.0;
        IN_POOL.with(|c| c.set(prev));
    }
}

/// Run `f(block)` for every `block` in `0..blocks`, distributing blocks
/// over the pool via a shared atomic counter.
///
/// `f` must treat distinct block indices as fully independent units that
/// touch disjoint output state — that is what makes the parallel result
/// bit-identical to running `for b in 0..blocks { f(b) }` serially.
/// Runs inline (in ascending block order) when the pool width is 1,
/// when there is a single block, or when called from inside another
/// `par_for`. A panic in any block propagates to the caller; the pool
/// is stateless, so later calls are unaffected.
pub fn par_for(blocks: usize, f: impl Fn(usize) + Sync) {
    if blocks == 0 {
        return;
    }
    let crew = threads().min(blocks);
    BLOCKS_RUN.fetch_add(blocks as u64, Ordering::Relaxed);
    if crew <= 1 || IN_POOL.with(|c| c.get()) {
        INLINE_RUNS.fetch_add(1, Ordering::Relaxed);
        let _guard = NestGuard::enter();
        for b in 0..blocks {
            f(b);
        }
        return;
    }
    DISPATCHES.fetch_add(1, Ordering::Relaxed);
    let next = AtomicUsize::new(0);
    let fref = &f;
    let nref = &next;
    std::thread::scope(|s| {
        for _ in 1..crew {
            s.spawn(move || {
                let _guard = NestGuard::enter();
                loop {
                    let b = nref.fetch_add(1, Ordering::Relaxed);
                    if b >= blocks {
                        break;
                    }
                    fref(b);
                }
            });
        }
        // the dispatching thread works too (crew of N = N-1 spawns)
        let _guard = NestGuard::enter();
        loop {
            let b = next.fetch_add(1, Ordering::Relaxed);
            if b >= blocks {
                break;
            }
            f(b);
        }
    });
}

/// Raw-pointer wrapper so a `par_for` closure can hand disjoint regions
/// of one buffer to different workers. The *user* of this type asserts
/// disjointness; keep every use next to a SAFETY comment.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `f(chunk_index, chunk)` over consecutive `chunk_len`-sized pieces
/// of `data` in parallel (the last chunk may be shorter). Chunk
/// boundaries depend only on `data.len()` and `chunk_len`, so the
/// partition — and with it the floating-point result — is independent of
/// the thread count.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = data.len();
    if len == 0 {
        return;
    }
    let blocks = len.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    par_for(blocks, |b| {
        let s = b * chunk_len;
        let e = (s + chunk_len).min(len);
        // SAFETY: `[s, e)` ranges are pairwise disjoint across block
        // indices and lie inside `data`, which is exclusively borrowed
        // for the whole dispatch; each block touches only its own range.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(s), e - s) };
        f(b, chunk);
    });
}

/// [`par_chunks_mut`] with an explicit dispatch gate: when `parallel` is
/// `false` (e.g. the problem is below a call site's work threshold) the
/// same chunks run inline on the calling thread in ascending order —
/// identical partition, identical floating-point sequence, identical
/// bits — without touching the pool. Keeping both branches behind one
/// helper means a call site cannot accidentally give the serial and
/// parallel paths different partitions.
pub fn par_chunks_mut_gated<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    parallel: bool,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    if parallel {
        par_chunks_mut(data, chunk_len, f);
    } else {
        for (b, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(b, chunk);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn par_for_runs_every_block_exactly_once() {
        let n = 97;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for(n, |b| {
            counts[b].fetch_add(1, Ordering::SeqCst);
        });
        for (b, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "block {b} ran a wrong number of times");
        }
        // zero blocks is a no-op
        par_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn par_chunks_mut_covers_the_slice_disjointly() {
        let mut data = vec![usize::MAX; 1003];
        par_chunks_mut(&mut data, 64, |blk, chunk| {
            for v in chunk.iter_mut() {
                *v = blk;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i / 64, "element {i} written by the wrong chunk");
        }
    }

    #[test]
    fn gated_serial_and_parallel_paths_agree() {
        let fill = |parallel: bool| {
            let mut data = vec![0usize; 517];
            par_chunks_mut_gated(&mut data, 32, parallel, |blk, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = blk * 1000 + i;
                }
            });
            data
        };
        assert_eq!(fill(false), fill(true));
    }

    #[test]
    fn nested_par_for_runs_inline_and_completes() {
        let total = AtomicUsize::new(0);
        par_for(4, |_| {
            // nested dispatch: must not deadlock or oversubscribe
            par_for(5, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn worker_panic_propagates_and_pool_stays_usable() {
        let result = std::panic::catch_unwind(|| {
            par_for(8, |b| {
                if b == 5 {
                    panic!("boom in block 5");
                }
            });
        });
        assert!(result.is_err(), "panic in a block must reach the caller");
        // stateless: the next dispatch works normally
        let ran = AtomicUsize::new(0);
        par_for(6, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn dispatch_counters_are_monotone() {
        let before = stats();
        par_for(12, |_| {});
        let after = stats();
        assert!(after.blocks_run >= before.blocks_run + 12);
        assert!(
            after.dispatches + after.inline_runs > before.dispatches + before.inline_runs,
            "a par_for call must count as either a dispatch or an inline run"
        );
    }

    #[test]
    fn thread_count_configuration_round_trips() {
        let _g = CONFIG_TEST_LOCK.lock().unwrap();
        let before = CONFIGURED.load(Ordering::SeqCst);
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert_eq!(threads(), available());
        assert!(threads() >= 1);
        CONFIGURED.store(before, Ordering::SeqCst);
    }
}
