//! Miniature property-based testing framework (no `proptest` offline).
//!
//! Provides seeded generators over the crate's own [`Rng`] and a
//! `for_all`-style runner that reports the failing case index + seed so a
//! failure is reproducible. No shrinking — cases are kept small instead.
//!
//! ```
//! use bless::util::prop::{for_all, Gen};
//! for_all(64, 0xC0FFEE, |g| {
//!     let v = g.vec_f64(1..20, -10.0..10.0);
//!     let s: f64 = v.iter().sum();
//!     assert!(s.is_finite());
//! });
//! ```

use crate::rng::Rng;
use std::ops::Range;

/// Case generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Uniform usize in `range`.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.end > range.start);
        range.start + self.rng.below(range.end - range.start)
    }

    /// Uniform f64 in `range`.
    pub fn f64_in(&mut self, range: Range<f64>) -> f64 {
        range.start + self.rng.next_f64() * (range.end - range.start)
    }

    /// Log-uniform f64 in `range` (both endpoints positive) — the natural
    /// distribution for regularization parameters λ.
    pub fn f64_log_in(&mut self, range: Range<f64>) -> f64 {
        assert!(range.start > 0.0 && range.end > range.start);
        (self.f64_in(range.start.ln()..range.end.ln())).exp()
    }

    /// Standard normal.
    pub fn gaussian(&mut self) -> f64 {
        self.rng.gaussian()
    }

    /// Bernoulli.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// Vector of uniform f64, random length in `len`.
    pub fn vec_f64(&mut self, len: Range<usize>, range: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(range.clone())).collect()
    }

    /// Vector of positive weights (at least one strictly positive).
    pub fn weights(&mut self, len: Range<usize>) -> Vec<f64> {
        let mut w = self.vec_f64(len, 0.0..1.0);
        if w.iter().all(|&v| v == 0.0) {
            w[0] = 1.0;
        }
        w
    }

    /// Access to the underlying RNG for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` generated inputs. Panics (bubbling the property's
/// own assertion) with the case number and derived seed on failure.
pub fn for_all(cases: usize, seed: u64, mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::seeded(case_seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Seed-sensitivity harness for randomized estimators: asserts that
/// `run` is a pure function of its seed (same seed ⇒ bitwise-identical
/// output) and that distinct seeds actually change the output (the
/// randomness is live, not vestigial). Returns the two distinct-seed
/// outputs so the caller can apply its own accuracy gates to both.
pub fn check_seed_sensitivity(
    seed_a: u64,
    seed_b: u64,
    run: impl Fn(u64) -> Vec<f64>,
) -> (Vec<f64>, Vec<f64>) {
    assert_ne!(seed_a, seed_b, "need two distinct seeds");
    let first = run(seed_a);
    let replay = run(seed_a);
    assert_eq!(first.len(), replay.len(), "same-seed reruns changed length");
    for (i, (x, y)) in first.iter().zip(&replay).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "same-seed rerun diverged at index {i}: {x} vs {y}"
        );
    }
    let other = run(seed_b);
    assert_eq!(first.len(), other.len(), "seed change altered output length");
    assert!(
        first.iter().zip(&other).any(|(x, y)| x.to_bits() != y.to_bits()),
        "distinct seeds produced bitwise-identical output — RNG not threaded through"
    );
    (first, other)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_stay_in_range() {
        for_all(100, 1, |g| {
            let u = g.usize_in(3..10);
            assert!((3..10).contains(&u));
            let f = g.f64_in(-1.0..1.0);
            assert!((-1.0..1.0).contains(&f));
            let l = g.f64_log_in(1e-6..1e-1);
            assert!((1e-6..1e-1).contains(&l));
            let v = g.vec_f64(1..5, 0.0..2.0);
            assert!(!v.is_empty() && v.len() < 5);
        });
    }

    #[test]
    fn weights_never_all_zero() {
        for_all(50, 2, |g| {
            let w = g.weights(1..8);
            assert!(w.iter().sum::<f64>() > 0.0);
        });
    }

    #[test]
    fn seed_sensitivity_accepts_honest_randomness() {
        let run = |seed: u64| {
            let mut rng = Rng::seeded(seed);
            (0..8).map(|_| rng.next_f64()).collect::<Vec<_>>()
        };
        let (a, b) = check_seed_sensitivity(1, 2, run);
        assert_eq!(a.len(), 8);
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic(expected = "bitwise-identical")]
    fn seed_sensitivity_rejects_ignored_seed() {
        check_seed_sensitivity(1, 2, |_| vec![0.25, 0.5]);
    }

    #[test]
    #[should_panic]
    fn failing_property_propagates() {
        for_all(10, 3, |g| {
            let v = g.usize_in(0..100);
            assert!(v < 101); // passes
            assert!(g.case < 5, "fail on later cases");
        });
    }
}
