//! Crash-safe file writes: temp file + fsync + atomic rename.
//!
//! `std::fs::write` truncates the destination before writing, so a crash
//! (or power cut) mid-write leaves a *torn* file under the final name —
//! exactly what a model-artifact loader must never see. [`atomic_write`]
//! instead stages the bytes in a uniquely named temp file in the same
//! directory, fsyncs the data to disk, then renames over the
//! destination: POSIX `rename(2)` is atomic within a filesystem, so any
//! reader observes either the complete old file or the complete new one,
//! never a prefix. On Unix the parent directory is fsynced afterwards so
//! the rename itself survives a crash.
//!
//! A crash between stage and rename strands a `.tmp-…` file next to the
//! destination; it is never picked up by loaders (the final name was
//! untouched) and the next successful write of the same destination
//! reuses nothing — stale temps are cleaned up opportunistically by
//! [`atomic_write`] on failure and are safe to delete at any time.

use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-process counter so concurrent writers of the same destination
/// never collide on a temp name.
static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` atomically: stage in a same-directory temp
/// file, fsync, rename into place, then (Unix) fsync the directory. The
/// destination never exists in a partially written state.
pub fn atomic_write(path: impl AsRef<Path>, bytes: &[u8]) -> anyhow::Result<()> {
    let path = path.as_ref();
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => {
            std::fs::create_dir_all(p)?;
            p.to_path_buf()
        }
        _ => std::path::PathBuf::from("."),
    };
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("cannot atomically write {}: no file name", path.display()))?
        .to_string_lossy()
        .into_owned();
    let tmp = parent.join(format!(
        ".{file_name}.tmp-{}-{}",
        std::process::id(),
        TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));

    let staged = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        // data must be durable *before* the rename makes it visible:
        // rename-then-sync can expose an empty file after a crash
        f.sync_all()?;
        Ok(())
    })();
    if let Err(e) = staged {
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("staging {}: {e}", tmp.display());
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("renaming {} into place: {e}", path.display());
    }
    // make the rename itself durable (the directory entry lives in the
    // parent); non-Unix platforms don't expose directory fsync
    #[cfg(unix)]
    {
        if let Ok(dir) = std::fs::File::open(&parent) {
            let _ = dir.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("bless-fsio-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_land_and_replace_atomically() {
        let dir = tmp_dir("basic");
        let path = dir.join("artifact.bin");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer payload");
        // no stray temp files remain after successful writes
        let strays: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp-"))
            .collect();
        assert!(strays.is_empty(), "leftover temps: {strays:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn a_stale_temp_never_shadows_the_destination() {
        let dir = tmp_dir("stale");
        let path = dir.join("model.json");
        atomic_write(&path, b"good").unwrap();
        // simulate a crash that died between stage and rename
        std::fs::write(dir.join(".model.json.tmp-999-0"), b"torn garb").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"good");
        atomic_write(&path, b"better").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"better");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_writers_leave_one_complete_file() {
        let dir = tmp_dir("race");
        let path = dir.join("contended.bin");
        let threads: Vec<_> = (0..8u8)
            .map(|t| {
                let path = path.clone();
                std::thread::spawn(move || {
                    let payload = vec![t; 4096];
                    for _ in 0..20 {
                        atomic_write(&path, &payload).unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // whatever writer won, the file is a complete 4096-byte payload
        // of a single byte value — never an interleaving
        let got = std::fs::read(&path).unwrap();
        assert_eq!(got.len(), 4096);
        assert!(got.windows(2).all(|w| w[0] == w[1]), "torn write observed");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pathological_destinations_error_cleanly() {
        assert!(atomic_write(std::path::Path::new("/"), b"x").is_err());
    }
}
