//! Cross-cutting utilities: bench harness, CLI parsing, property testing,
//! result tables, poison-tolerant locking, crash-safe file writes, and
//! the shared compute threadpool. [`bench`], [`cli`], [`json`] and
//! [`prop`] replace `criterion`, `clap` and `proptest` (none of which
//! exist in the offline crate registry); [`pool`] is the process-wide
//! thread policy every parallel kernel in [`crate::linalg`] and
//! [`crate::kernels`] dispatches through; [`sync`] and [`fsio`] carry
//! the serve tier's robustness policies (a panicked worker must not
//! wedge a lock, a crashed save must not tear an artifact).

pub mod bench;
pub mod cli;
pub mod fsio;
pub mod json;
pub mod pool;
pub mod prop;
pub mod sync;
pub mod table;

use std::time::Instant;

/// Measure the wall-clock seconds of a closure, returning `(result, secs)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Quantile of a sample (linear interpolation between order statistics).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a sample.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_sample() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_std() {
        let xs = vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
