//! Minimal criterion-style benchmark harness.
//!
//! `criterion` is not in the offline registry; this module provides the
//! subset the repo needs: named benchmarks with warm-up, repeated timed
//! iterations, and mean/median/σ reporting, plus a `black_box` to defeat
//! constant folding. Bench binaries are declared with `harness = false`
//! in `Cargo.toml` and run under `cargo bench`.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    fn fmt_time(s: f64) -> String {
        if s < 1e-6 {
            format!("{:8.1} ns", s * 1e9)
        } else if s < 1e-3 {
            format!("{:8.2} µs", s * 1e6)
        } else if s < 1.0 {
            format!("{:8.2} ms", s * 1e3)
        } else {
            format!("{:8.3} s ", s)
        }
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {}  (median {}, σ {}, {} iters)",
            self.name,
            Self::fmt_time(self.mean_s),
            Self::fmt_time(self.median_s),
            Self::fmt_time(self.std_s),
            self.iters
        )
    }
}

/// A group of benchmarks sharing warm-up / iteration policy.
pub struct Bencher {
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    target_secs: f64,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            target_secs: 2.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Harness with a per-benchmark time budget (seconds).
    pub fn with_budget(target_secs: f64) -> Self {
        Bencher { target_secs, ..Default::default() }
    }

    /// Quick harness for cheap micro-benchmarks.
    pub fn quick() -> Self {
        Bencher { warmup_iters: 3, min_iters: 10, max_iters: 1000, target_secs: 0.5, ..Default::default() }
    }

    /// Run `f` repeatedly and record stats under `name`.
    /// The closure's return value is black-boxed so work is not elided.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let budget_start = Instant::now();
        while times.len() < self.min_iters
            || (budget_start.elapsed().as_secs_f64() < self.target_secs
                && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            name: name.to_string(),
            iters: times.len(),
            mean_s: super::mean(&times),
            median_s: super::quantile(&times, 0.5),
            std_s: super::std_dev(&times),
            min_s: times[0],
            max_s: *times.last().unwrap(),
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn summary(&self, title: &str) {
        println!("\n=== {title} ===");
        for s in &self.results {
            println!("{}", s.report());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_stats() {
        let mut b = Bencher { warmup_iters: 1, min_iters: 3, max_iters: 5, target_secs: 0.01, ..Default::default() };
        let s = b.bench("noop", || 1 + 1).clone();
        assert_eq!(s.name, "noop");
        assert!(s.iters >= 3);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn black_box_passes_value() {
        assert_eq!(black_box(7), 7);
    }
}
