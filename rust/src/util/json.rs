//! Minimal JSON parser (the offline registry has no `serde`).
//!
//! Supports the full JSON grammar minus exotic number forms; used to read
//! `artifacts/manifest.json` and to write experiment result files.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric content as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// Array content.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object content.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if *n == 0.0 && n.is_sign_negative() {
                    // `0.0 as i64` would drop the sign bit; -0.0 must
                    // survive a Display→parse round trip bit-exactly
                    write!(f, "-0")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{e}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        // accumulate raw bytes: the input is UTF-8, and copying the
        // bytes through (rather than `byte as char`, which decodes
        // Latin-1 and mangles multi-byte sequences) keeps non-ASCII
        // content intact
        let mut out: Vec<u8> = Vec::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(String::from_utf8(out)?),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            let ch = char::from_u32(code).unwrap_or('\u{fffd}');
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "tile": 256, "feature_dim": 32,
            "artifacts": {
                "rbf_block": {"file": "rbf_block.hlo.txt",
                              "inputs": [{"shape": [256, 32], "dtype": "float32"}]}
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("tile").unwrap().as_usize(), Some(256));
        let art = j.get("artifacts").unwrap().get("rbf_block").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("rbf_block.hlo.txt"));
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb\"cA""#).unwrap(),
            Json::Str("a\nb\"cA".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'single': 1}").is_err());
    }

    #[test]
    fn display_round_trips() {
        let doc = r#"{"a": [1, 2.5, "x"], "b": {"c": null, "d": false}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_ascii_strings_survive_parse_and_display() {
        let j = Json::parse(r#""données – ümlaut 数据""#).unwrap();
        assert_eq!(j.as_str(), Some("données – ümlaut 数据"));
        // and the Display form re-parses to the same string
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn negative_zero_round_trips_bit_exactly() {
        // f64 PartialEq can't tell -0.0 from 0.0; compare the bits
        let neg = Json::Num(-0.0);
        assert_eq!(neg.to_string(), "-0");
        match Json::parse(&neg.to_string()).unwrap() {
            Json::Num(v) => assert_eq!(v.to_bits(), (-0.0f64).to_bits()),
            other => panic!("expected number, got {other:?}"),
        }
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
