//! **BLESS** and **BLESS-R** — the paper's primary contribution
//! (Algorithms 1 and 2): bottom-up leverage-score sampling along a
//! decreasing regularization path `λ₀ > λ₁ > … > λ_H = λ`.
//!
//! Both algorithms maintain a weighted column set `(J_h, A_h)` that is an
//! accurate leverage-score *generator* at scale `λ_h` (Eq. 2 with constant
//! `t`), using only `O(min(1/λ_h, n))` score evaluations per level — never
//! a pass over all `n` points until `1/λ ≥ n`. The whole **path** of
//! generators is returned (Thm. 1 holds for every level simultaneously),
//! which is what makes λ cross-validation cheap downstream.
//!
//! The per-level compute — the `K_{J,J}` factorization and the batched
//! candidate scoring through [`crate::leverage::LsGenerator`] — runs on
//! the shared [`crate::util::pool`], so multi-core machines sample in a
//! fraction of the serial wall-clock with bit-identical output.

mod alg1;
mod alg2;

pub use alg1::{bless, BlessConfig};
pub use alg2::{bless_r, BlessRConfig};

use crate::leverage::WeightedSet;

/// Output of one path level `h`.
#[derive(Clone, Debug)]
pub struct LevelOutput {
    /// Regularization at this level (`λ_h`).
    pub lambda: f64,
    /// The weighted set `(J_h, A_h)` — weights are the Eq. (3) `A` matrix.
    pub set: WeightedSet,
    /// Estimated effective dimension `d_h ≈ d_eff(λ_h)`.
    pub d_est: f64,
    /// Number of candidate points touched at this level (`R_h` for
    /// Alg. 1, `|U_h|` for Alg. 2).
    pub candidates: usize,
}

/// Full output: the regularization path of weighted sets.
#[derive(Clone, Debug)]
pub struct BlessPath {
    pub levels: Vec<LevelOutput>,
    /// Total leverage-score evaluations performed (cost accounting for
    /// the Table-1 / Figure-2 experiments).
    pub score_evals: usize,
}

impl BlessPath {
    /// The set at the final (smallest-λ) level.
    pub fn final_set(&self) -> &WeightedSet {
        &self.levels.last().expect("path has at least one level").set
    }

    /// The level whose λ is closest (in log-space) to the query — the
    /// cross-validation entry point the paper advertises (§2.4).
    pub fn level_for(&self, lambda: f64) -> &LevelOutput {
        self.levels
            .iter()
            .min_by(|a, b| {
                let da = (a.lambda.ln() - lambda.ln()).abs();
                let db = (b.lambda.ln() - lambda.ln()).abs();
                da.partial_cmp(&db).unwrap()
            })
            .expect("path has at least one level")
    }
}

/// Geometric λ path from `λ₀` down to `λ`, with ratio at most `q`
/// (steps are equalized in log-space so `λ_H = λ` exactly).
pub(crate) fn lambda_path(lambda0: f64, lambda: f64, q: f64) -> Vec<f64> {
    assert!(lambda0 > 0.0 && lambda > 0.0 && q > 1.0);
    if lambda >= lambda0 {
        return vec![lambda];
    }
    let h = ((lambda0 / lambda).ln() / q.ln()).ceil().max(1.0) as usize;
    let ratio = (lambda / lambda0).powf(1.0 / h as f64);
    let mut path: Vec<f64> = (1..h).map(|i| lambda0 * ratio.powi(i as i32)).collect();
    path.push(lambda); // exact endpoint, no float drift
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_endpoints_and_monotone() {
        let p = lambda_path(1.0, 1e-3, 2.0);
        assert_eq!(*p.last().unwrap(), 1e-3);
        for w in p.windows(2) {
            assert!(w[1] < w[0]);
            assert!(w[0] / w[1] <= 2.0 + 1e-9);
        }
        assert!(p[0] < 1.0);
    }

    #[test]
    fn degenerate_path() {
        assert_eq!(lambda_path(1.0, 2.0, 2.0), vec![2.0]);
    }

    #[test]
    fn path_length_matches_log_ratio() {
        let p = lambda_path(1.0, 1e-6, 2.0);
        let h = ((1e6f64).ln() / (2.0f64).ln()).ceil() as usize;
        assert_eq!(p.len(), h);
    }
}
