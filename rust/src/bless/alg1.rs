//! Algorithm 1 — **BLESS**: bottom-up leverage-score sampling *with*
//! replacement (multinomial resampling of a uniform candidate pool).
//!
//! Per level the candidate scores run through one [`LsGenerator`], which
//! gathers the dictionary rows `X[J_{h-1}]` once (the cached-center path
//! of [`crate::kernels::Centers`]) and reuses them for the `K_JJ`
//! factorization and the whole `K_{J,U_h}` score batch.

use super::{lambda_path, BlessPath, LevelOutput};
use crate::kernels::KernelEngine;
use crate::leverage::{LsGenerator, WeightedSet};
use crate::rng::Rng;

/// Parameters of Algorithm 1.
///
/// The paper's Theorem-1 constants (`q₁ ≳ 5κ²q₂/q`, `q₂ ≳ 12q·…·log(12Hn/δ)`)
/// are worst-case; the experiments (and ours, see `benches/ablation_q2.rs`)
/// show small constants already give mean R-ACC ≈ 1.05. These defaults are
/// tuned to reproduce Figure 1's accuracy/time trade-off.
#[derive(Clone, Debug)]
pub struct BlessConfig {
    /// Path step `q > 1`: `λ_h = λ_{h-1}/q`.
    pub q: f64,
    /// Candidate oversampling: `R_h = min(q₁·κ²/λ_h, n)`.
    pub q1: f64,
    /// Selection oversampling: `M_h = q₂·d_h`.
    pub q2: f64,
    /// Starting regularization `λ₀` (default `κ²`, i.e. `t = 1` in Thm. 1).
    pub lambda0: Option<f64>,
    /// Floor on `M_h` — keeps the very first levels from degenerating to
    /// one or two columns where the multinomial estimate is noisy.
    pub min_m: usize,
}

impl Default for BlessConfig {
    fn default() -> Self {
        BlessConfig { q: 2.0, q1: 6.0, q2: 4.0, lambda0: None, min_m: 8 }
    }
}

/// Run BLESS (Algorithm 1) down to regularization `lambda`.
///
/// Returns the whole path of weighted sets `(J_h, A_h)` for
/// `λ_h = λ₀/q^h`, the last of which is the requested `λ`.
pub fn bless(
    engine: &dyn KernelEngine,
    lambda: f64,
    cfg: &BlessConfig,
    rng: &mut Rng,
) -> BlessPath {
    let n = engine.n();
    assert!(n > 0, "empty dataset");
    assert!(lambda > 0.0, "lambda must be positive");
    let kappa_sq = engine.kappa_sq();
    let lambda0 = cfg.lambda0.unwrap_or(kappa_sq);
    let path = lambda_path(lambda0, lambda, cfg.q);

    // J_0 = ∅, A_0 = [] — the empty generator scores ℓ̃_∅ = K_ii/(λn).
    let mut current = WeightedSet { indices: vec![], weights: vec![], lambda: lambda0 };
    let mut levels = Vec::with_capacity(path.len());
    let mut score_evals = 0usize;

    for (h, &lambda_h) in path.iter().enumerate() {
        // zero-padded so the span profile lists levels in order
        let _level = crate::obs::span(&format!("bless.level{h:02}"));
        // Step 4-5: uniform candidate pool U_h, R_h = q1·min(κ²/λ_h, n).
        let r_h = ((cfg.q1 * kappa_sq / lambda_h).ceil() as usize).clamp(1, n);
        let u_h = rng.uniform_indices(n, r_h);

        // Step 6: approximate scores of the candidates w.r.t. (J_{h-1}, A_{h-1}).
        let gen = {
            let _s = crate::obs::span("factor");
            LsGenerator::new(engine, &current, lambda_h).expect("BLESS generator must factor")
        };
        let scores = {
            let _s = crate::obs::span("scores");
            gen.scores(&u_h)
        };
        score_evals += u_h.len();

        // Step 7-8: selection probabilities and d_h estimate.
        let total: f64 = scores.iter().sum();
        let d_h = (n as f64 / r_h as f64) * total;
        let m_h = ((cfg.q2 * d_h).ceil() as usize).max(cfg.min_m).min(n.max(cfg.min_m));

        // Step 9: multinomial sampling with replacement from U_h.
        let picks = rng.multinomial(&scores, m_h);

        // Step 10: A_h = (R_h·M_h/n) · diag(p_{j_1}, …, p_{j_M}).
        let coeff = (r_h as f64) * (m_h as f64) / (n as f64);
        let mut indices = Vec::with_capacity(m_h);
        let mut weights = Vec::with_capacity(m_h);
        for &k in &picks {
            indices.push(u_h[k]);
            weights.push(coeff * scores[k] / total);
        }
        let mreg = crate::obs::metrics::global();
        mreg.counter("bless_levels_total").inc();
        mreg.counter("bless_score_evals_total").add(u_h.len() as u64);
        mreg.counter("bless_samples_total").add(indices.len() as u64);

        current = WeightedSet { indices, weights, lambda: lambda_h };
        levels.push(LevelOutput {
            lambda: lambda_h,
            set: current.clone(),
            d_est: d_h,
            candidates: r_h,
        });
    }
    BlessPath { levels, score_evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{exact_leverage_scores, effective_dimension, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(31));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn produces_full_path() {
        let eng = engine(300);
        let out = bless(&eng, 1e-2, &BlessConfig::default(), &mut Rng::seeded(1));
        assert!(!out.levels.is_empty());
        assert_eq!(*out.levels.last().map(|l| &l.lambda).unwrap(), 1e-2);
        // λ decreasing along the path
        for w in out.levels.windows(2) {
            assert!(w[1].lambda < w[0].lambda);
        }
        // every level has a valid weighted set
        for l in &out.levels {
            l.set.validate().unwrap();
            assert!(l.set.indices.iter().all(|&i| i < 300));
        }
        assert!(out.score_evals > 0);
    }

    #[test]
    fn final_scores_accurate() {
        // End-to-end accuracy: ℓ̃_{J_H} within a multiplicative band of the
        // exact scores — the Thm. 1(a) guarantee, with practical constants.
        let eng = engine(400);
        let lambda = 5e-3;
        let out = bless(&eng, lambda, &BlessConfig::default(), &mut Rng::seeded(2));
        let gen = LsGenerator::new(&eng, out.final_set(), lambda).unwrap();
        let approx = gen.scores_all();
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let stats = RAccStats::from_scores(&approx, &exact);
        assert!(
            stats.mean > 0.6 && stats.mean < 1.8,
            "mean R-ACC {} out of band",
            stats.mean
        );
        assert!(stats.q05 > 0.35, "5th quantile {} too low", stats.q05);
        assert!(stats.q95 < 3.0, "95th quantile {} too high", stats.q95);
    }

    #[test]
    fn set_size_tracks_effective_dimension() {
        // Thm. 1(b): |J_h| ≤ q₂·d_eff(λ_h) up to constants.
        let eng = engine(400);
        let lambda = 1e-2;
        let cfg = BlessConfig::default();
        let out = bless(&eng, lambda, &cfg, &mut Rng::seeded(3));
        let deff = effective_dimension(&exact_leverage_scores(&eng, lambda).unwrap());
        let m = out.final_set().len() as f64;
        assert!(
            m <= 4.0 * cfg.q2 * deff + cfg.min_m as f64,
            "|J| = {m} vs q2·deff = {}",
            cfg.q2 * deff
        );
        // d_est in the right ballpark
        let d_est = out.levels.last().unwrap().d_est;
        assert!(d_est > 0.2 * deff && d_est < 5.0 * deff, "d_est {d_est} vs deff {deff}");
    }

    #[test]
    fn deterministic_with_seed() {
        let eng = engine(200);
        let a = bless(&eng, 1e-2, &BlessConfig::default(), &mut Rng::seeded(7));
        let b = bless(&eng, 1e-2, &BlessConfig::default(), &mut Rng::seeded(7));
        assert_eq!(a.final_set().indices, b.final_set().indices);
    }

    #[test]
    fn candidates_bounded_by_q1_over_lambda() {
        let eng = engine(500);
        let out = bless(&eng, 1e-1, &BlessConfig::default(), &mut Rng::seeded(8));
        for l in &out.levels {
            let bound = (6.0 / l.lambda).ceil() as usize;
            assert!(l.candidates <= bound.min(500));
        }
    }
}
