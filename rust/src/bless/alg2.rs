//! Algorithm 2 — **BLESS-R**: bottom-up leverage-score sampling *without*
//! replacement, via a single round of rejection sampling per column.
//!
//! Instead of materializing the candidate pool and a multinomial, BLESS-R
//! thins `[n]` with a Bernoulli(β_h) pre-filter (the cheap uniform stage)
//! and then accepts each survivor `j` with probability `p_{h,j}/β_h`
//! where `p_{h,j} = min(q₂·ℓ̃_{J_{h-1}}(x_j, λ_{h-1}), 1)`, so that the
//! unconditional acceptance probability is exactly `p_{h,j}` — leverage
//! score sampling without ever touching most of the data.
//!
//! Like Algorithm 1, each level's survivor scores flow through one
//! [`LsGenerator`] whose dictionary rows are gathered once per level
//! (the [`crate::kernels::Centers`] cached-center path).

use super::{lambda_path, BlessPath, LevelOutput};
use crate::kernels::KernelEngine;
use crate::leverage::{LsGenerator, WeightedSet};
use crate::rng::Rng;

/// Parameters of Algorithm 2.
#[derive(Clone, Debug)]
pub struct BlessRConfig {
    /// Path step `q > 1`.
    pub q: f64,
    /// Oversampling constant `q₂`: acceptance `p = min(q₂·ℓ̃, 1)` and
    /// pre-filter `β_h = min(q₂·κ²/(λ_h n), 1)`.
    pub q2: f64,
    /// Starting regularization `λ₀` (default `κ²`).
    pub lambda0: Option<f64>,
    /// Floor on `|J_h|`: if rejection sampling returns fewer columns, the
    /// level is topped up with uniform draws (keeps early levels stable).
    pub min_m: usize,
}

impl Default for BlessRConfig {
    fn default() -> Self {
        BlessRConfig { q: 2.0, q2: 4.0, lambda0: None, min_m: 8 }
    }
}

/// Run BLESS-R (Algorithm 2) down to regularization `lambda`.
pub fn bless_r(
    engine: &dyn KernelEngine,
    lambda: f64,
    cfg: &BlessRConfig,
    rng: &mut Rng,
) -> BlessPath {
    let n = engine.n();
    assert!(n > 0, "empty dataset");
    assert!(lambda > 0.0, "lambda must be positive");
    let kappa_sq = engine.kappa_sq();
    let lambda0 = cfg.lambda0.unwrap_or(kappa_sq);
    let path = lambda_path(lambda0, lambda, cfg.q);

    let mut current = WeightedSet { indices: vec![], weights: vec![], lambda: lambda0 };
    let mut levels = Vec::with_capacity(path.len());
    let mut score_evals = 0usize;
    let mut lambda_prev = lambda0;

    for (h, &lambda_h) in path.iter().enumerate() {
        // zero-padded so the span profile lists levels in order
        let _level = crate::obs::span(&format!("bless.level{h:02}"));
        // Step 4-7: Bernoulli(β_h) pre-filter of all n columns.
        let beta_h = (cfg.q2 * kappa_sq / (lambda_h * n as f64)).min(1.0);
        let mut u_h: Vec<usize> = Vec::new();
        for i in 0..n {
            if rng.bernoulli(beta_h) {
                u_h.push(i);
            }
        }

        // Step 9-12: acceptance probabilities from the *previous* level's
        // generator at λ_{h-1} (Alg. 2 line 10 uses λ_{h-1}).
        let gen = {
            let _s = crate::obs::span("factor");
            LsGenerator::new(engine, &current, lambda_prev).expect("BLESS-R generator must factor")
        };
        let scores = {
            let _s = crate::obs::span("scores");
            gen.scores(&u_h)
        };
        score_evals += u_h.len();

        let mut indices = Vec::new();
        let mut weights = Vec::new();
        for (k, &j) in u_h.iter().enumerate() {
            let p_hj = (cfg.q2 * scores[k]).min(1.0);
            let accept = (p_hj / beta_h).min(1.0);
            if rng.bernoulli(accept) {
                indices.push(j);
                weights.push(p_hj);
            }
        }

        // Degenerate-level guard: top up with uniform columns at weight 1.
        // Membership is tracked in a bitvec — O(1) per draw instead of the
        // O(m) `indices.contains` scan (O(m²) per level) — with the exact
        // same accept/reject decisions, so the RNG draw sequence is
        // unchanged (the rejection-sampled `indices` are duplicate-free).
        let floor = cfg.min_m.min(n);
        if indices.len() < floor {
            let mut seen = vec![false; n];
            for &j in &indices {
                seen[j] = true;
            }
            while indices.len() < floor {
                let j = rng.below(n);
                if !seen[j] {
                    seen[j] = true;
                    indices.push(j);
                    weights.push(1.0);
                }
            }
        }

        let mreg = crate::obs::metrics::global();
        mreg.counter("bless_levels_total").inc();
        mreg.counter("bless_score_evals_total").add(u_h.len() as u64);
        mreg.counter("bless_samples_total").add(indices.len() as u64);

        let d_est: f64 = weights.iter().sum::<f64>() / cfg.q2;
        current = WeightedSet { indices, weights, lambda: lambda_h };
        levels.push(LevelOutput {
            lambda: lambda_h,
            set: current.clone(),
            d_est,
            candidates: u_h.len(),
        });
        lambda_prev = lambda_h;
    }
    BlessPath { levels, score_evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::susy_like;
    use crate::kernels::{Gaussian, NativeEngine};
    use crate::leverage::{effective_dimension, exact_leverage_scores, RAccStats};

    fn engine(n: usize) -> NativeEngine {
        let ds = susy_like(n, &mut Rng::seeded(41));
        NativeEngine::new(ds.x, Gaussian::new(2.0))
    }

    #[test]
    fn indices_distinct_without_replacement() {
        let eng = engine(300);
        let out = bless_r(&eng, 1e-2, &BlessRConfig::default(), &mut Rng::seeded(1));
        for l in &out.levels {
            let mut idx = l.set.indices.clone();
            idx.sort_unstable();
            let before = idx.len();
            idx.dedup();
            assert_eq!(idx.len(), before, "duplicates at λ={}", l.lambda);
            l.set.validate().unwrap();
        }
    }

    #[test]
    fn final_scores_accurate() {
        let eng = engine(400);
        let lambda = 5e-3;
        let out = bless_r(&eng, lambda, &BlessRConfig::default(), &mut Rng::seeded(2));
        let gen = LsGenerator::new(&eng, out.final_set(), lambda).unwrap();
        let approx = gen.scores_all();
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let stats = RAccStats::from_scores(&approx, &exact);
        assert!(
            stats.mean > 0.6 && stats.mean < 1.8,
            "mean R-ACC {} out of band",
            stats.mean
        );
        assert!(stats.q05 > 0.35 && stats.q95 < 3.0, "quantiles {stats:?}");
    }

    #[test]
    fn set_size_tracks_effective_dimension() {
        let eng = engine(400);
        let lambda = 1e-2;
        let cfg = BlessRConfig::default();
        let out = bless_r(&eng, lambda, &cfg, &mut Rng::seeded(3));
        let deff = effective_dimension(&exact_leverage_scores(&eng, lambda).unwrap());
        let m = out.final_set().len() as f64;
        // Thm. 1(b) shape: |J| = O(q2·deff)
        assert!(m <= 6.0 * cfg.q2 * deff + cfg.min_m as f64, "|J| = {m}, deff = {deff}");
    }

    #[test]
    fn acceptance_never_exceeds_prefilter_population() {
        let eng = engine(200);
        let out = bless_r(&eng, 1e-1, &BlessRConfig::default(), &mut Rng::seeded(4));
        for l in &out.levels {
            assert!(l.set.len() <= l.candidates + BlessRConfig::default().min_m);
        }
    }

    #[test]
    fn deterministic_with_seed() {
        let eng = engine(200);
        let a = bless_r(&eng, 1e-2, &BlessRConfig::default(), &mut Rng::seeded(7));
        let b = bless_r(&eng, 1e-2, &BlessRConfig::default(), &mut Rng::seeded(7));
        assert_eq!(a.final_set().indices, b.final_set().indices);
    }
}
