//! Continuous-training lifecycle: supervised retrain → gate → promote
//! → probation → (maybe) rollback, against a live serving registry.
//!
//! The serving tier ([`crate::serve`]) treats a model as immutable once
//! loaded; this module closes the loop for data that drifts. One
//! *cycle* ([`run_cycle`]):
//!
//! ```text
//!        trainer() ── catch_unwind ──▶ candidate ModelArtifact
//!            │ panic / Err                  │
//!            ▼                              ▼
//!     TrainFailed                 HoldoutGate::evaluate
//!   (incumbent untouched)         RMSE(candidate) ≤ RMSE(incumbent)+tol?
//!                                    │ no                │ yes
//!                                    ▼                   ▼
//!                             GateRejected           promote:
//!                       (candidate quarantined       retain incumbent at
//!                        to <path>.rejected-N,       <path>.prev, swap the
//!                        incumbent untouched)        entry, reset breaker
//!                                                        │
//!                                                        ▼
//!                                                 probation window:
//!                                                 breaker trips? ──yes──▶
//!                                                        │ no      rollback
//!                                                        ▼         (swap the
//!                                                    Promoted      retained
//!                                                                  incumbent
//!                                                                  back)
//! ```
//!
//! Invariants the chaos tier (`tests/lifecycle_soak.rs`) proves:
//!
//! * The incumbent **never stops serving**: a retrain panic
//!   (`train.panic`), a trainer error, a gate failure (`gate.fail`) or
//!   a post-promotion rollback all leave (or restore) the predictor
//!   that was serving before the cycle started.
//! * Every artifact write is an atomic replace
//!   ([`crate::util::fsio::atomic_write`] inside
//!   [`ModelArtifact::save`]), so a crash mid-promotion never leaves a
//!   torn file on the reload path.
//! * Everything is observable: per-entry `promotions` / `rollbacks`
//!   counters ride the `stats` wire verb, and the process-wide
//!   `lifecycle_*` counters plus the `lifecycle_model_generation` gauge
//!   render on `/metrics` and `/varz`.
//!
//! [`RetrainScheduler`] runs cycles on a period (`serve
//! --retrain-every`), feeding each one a caller-supplied trainer —
//! typically a warm-started [`crate::falkon::Falkon::refit`] on freshly
//! drifted data.

use crate::linalg::Matrix;
use crate::serve::model_store::{ModelArtifact, Predictor};
use crate::serve::registry::ModelEntry;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The validation gate a retrained candidate must pass before it may
/// replace the incumbent: held-out RMSE no worse than the incumbent's
/// plus an absolute `tolerance`.
pub struct HoldoutGate {
    /// Held-out query rows (one per row, entry dimension columns).
    queries: Matrix,
    /// Ground-truth targets, one per query row.
    targets: Vec<f64>,
    /// Absolute RMSE slack: the candidate passes when
    /// `rmse(candidate) <= rmse(incumbent) + tolerance`.
    tolerance: f64,
}

/// What [`HoldoutGate::evaluate`] decided, with the numbers behind it.
#[derive(Clone, Debug)]
pub struct GateDecision {
    /// Whether the candidate may be promoted.
    pub pass: bool,
    /// Candidate RMSE on the holdout set.
    pub candidate_rmse: f64,
    /// Incumbent RMSE on the holdout set.
    pub incumbent_rmse: f64,
    /// True when the `gate.fail` chaos point forced this rejection.
    pub injected: bool,
}

impl HoldoutGate {
    /// Build a gate; the holdout set must be non-empty and consistent.
    pub fn new(queries: Matrix, targets: Vec<f64>, tolerance: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(queries.rows() > 0, "holdout set must not be empty");
        anyhow::ensure!(
            queries.rows() == targets.len(),
            "holdout rows {} != targets {}",
            queries.rows(),
            targets.len()
        );
        anyhow::ensure!(
            tolerance.is_finite() && tolerance >= 0.0,
            "gate tolerance must be finite and non-negative (got {tolerance})"
        );
        Ok(HoldoutGate { queries, targets, tolerance })
    }

    /// Rows in the holdout set.
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    /// Whether the holdout set is empty (never true after `new`).
    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Score both predictors on the holdout set and decide. This is the
    /// firing site of the `gate.fail` chaos point: when armed it forces
    /// a rejection, proving the refuse-and-quarantine path without
    /// needing a genuinely bad model.
    pub fn evaluate(
        &self,
        incumbent: &Predictor,
        candidate: &Predictor,
    ) -> anyhow::Result<GateDecision> {
        let inc = incumbent.predict_batch(&self.queries)?;
        let cand = candidate.predict_batch(&self.queries)?;
        let incumbent_rmse = crate::data::rmse(&inc, &self.targets);
        let candidate_rmse = crate::data::rmse(&cand, &self.targets);
        let mut pass =
            candidate_rmse.is_finite() && candidate_rmse <= incumbent_rmse + self.tolerance;
        let injected = crate::faults::fire(crate::faults::FaultPoint::GateFail);
        if injected {
            pass = false;
        }
        Ok(GateDecision { pass, candidate_rmse, incumbent_rmse, injected })
    }
}

/// Knobs for one retrain cycle.
#[derive(Clone, Debug)]
pub struct LifecycleConfig {
    /// Where the serving artifact lives. Promotion atomically replaces
    /// this file with the candidate, retains the incumbent at
    /// `<path>.prev`, and quarantines gate-rejected candidates at
    /// `<path>.rejected-<n>` — so a restart always reloads whatever is
    /// actually serving.
    pub artifact_path: PathBuf,
    /// How long a freshly promoted model stays on probation: any
    /// breaker trip inside this window rolls the promotion back.
    pub probation: Duration,
    /// How often the probation watch polls the breaker.
    pub poll: Duration,
}

impl LifecycleConfig {
    /// Defaults: 5s probation polled every 20ms.
    pub fn new(artifact_path: impl Into<PathBuf>) -> Self {
        LifecycleConfig {
            artifact_path: artifact_path.into(),
            probation: Duration::from_secs(5),
            poll: Duration::from_millis(20),
        }
    }
}

/// How one [`run_cycle`] ended.
#[derive(Debug)]
pub enum CycleOutcome {
    /// The trainer panicked or returned an error — the incumbent was
    /// never touched.
    TrainFailed {
        /// The panic payload or error message.
        reason: String,
    },
    /// The candidate failed the holdout gate (or the `gate.fail` chaos
    /// point fired) — refused before any swap, artifact quarantined.
    GateRejected {
        /// The decision with both RMSE values.
        decision: GateDecision,
        /// Where the rejected candidate was parked for post-mortem
        /// (None when the quarantine write itself failed).
        quarantined_to: Option<PathBuf>,
    },
    /// The candidate passed, was promoted, and survived probation.
    Promoted {
        /// The promoted artifact — the caller's next incumbent.
        artifact: ModelArtifact,
        /// The gate decision that admitted it.
        decision: GateDecision,
    },
    /// The candidate was promoted but its breaker tripped inside the
    /// probation window; the retained incumbent is serving again.
    RolledBack {
        /// The gate decision that (wrongly, in hindsight) admitted it.
        decision: GateDecision,
        /// Breaker trips observed during probation.
        trips: u64,
    },
}

fn lifecycle_counter(name: &'static str) -> std::sync::Arc<crate::obs::metrics::Counter> {
    crate::obs::metrics::global().counter(name)
}

/// Run one supervised retrain cycle against a live registry entry.
///
/// `incumbent` must be the artifact the entry is currently serving —
/// it is what a rollback swaps back and what `<path>.prev` retains.
/// `stop` aborts the probation watch early (treating the promotion as
/// final) so a server shutdown is never blocked behind a long window.
///
/// The trainer runs under `catch_unwind` with the `train.panic` chaos
/// point armed in front of it: a panicking retrain is contained to
/// this cycle and the incumbent keeps serving.
pub fn run_cycle(
    entry: &ModelEntry,
    incumbent: &ModelArtifact,
    trainer: impl FnOnce() -> anyhow::Result<ModelArtifact>,
    gate: &HoldoutGate,
    cfg: &LifecycleConfig,
    stop: &AtomicBool,
) -> CycleOutcome {
    lifecycle_counter("lifecycle_retrains_started_total").inc();

    let candidate = match catch_unwind(AssertUnwindSafe(|| {
        if crate::faults::fire(crate::faults::FaultPoint::TrainPanic) {
            panic!("injected train.panic fault");
        }
        trainer()
    })) {
        Ok(Ok(artifact)) => artifact,
        Ok(Err(e)) => return train_failed(entry, e.to_string()),
        Err(payload) => {
            let reason = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            return train_failed(entry, format!("retrain panicked: {reason}"));
        }
    };

    // same guard the reload path enforces: a candidate that changed the
    // input dimension can never be swapped under live traffic
    if candidate.d() != entry.dim() {
        return train_failed(
            entry,
            format!(
                "candidate input dimension {} != serving dimension {}",
                candidate.d(),
                entry.dim()
            ),
        );
    }

    let incumbent_pred = entry.predictor();
    let candidate_pred = Predictor::new(&candidate);
    let decision = match gate.evaluate(&incumbent_pred, &candidate_pred) {
        Ok(d) => d,
        Err(e) => return train_failed(entry, format!("gate evaluation failed: {e}")),
    };

    if !decision.pass {
        let n = lifecycle_counter("lifecycle_retrains_gate_rejected_total");
        n.inc();
        let quarantine =
            PathBuf::from(format!("{}.rejected-{}", cfg.artifact_path.display(), n.get()));
        let quarantined_to = match candidate.save(&quarantine) {
            Ok(()) => {
                eprintln!(
                    "warning: retrained candidate for {:?} failed the gate \
                     (rmse {:.6} vs incumbent {:.6} + tol); quarantined at {}",
                    entry.name(),
                    decision.candidate_rmse,
                    decision.incumbent_rmse,
                    quarantine.display()
                );
                Some(quarantine)
            }
            Err(e) => {
                eprintln!("warning: could not quarantine rejected candidate: {e}");
                None
            }
        };
        return CycleOutcome::GateRejected { decision, quarantined_to };
    }

    // Promote. Retain the incumbent first: the rollback path (and a
    // post-crash operator) needs it after artifact_path is overwritten.
    let prev_path = PathBuf::from(format!("{}.prev", cfg.artifact_path.display()));
    if let Err(e) = incumbent.save(&prev_path) {
        eprintln!("warning: could not retain incumbent at {}: {e}", prev_path.display());
    }
    if let Err(e) = candidate.save(&cfg.artifact_path) {
        eprintln!(
            "warning: could not persist promoted artifact at {}: {e}",
            cfg.artifact_path.display()
        );
    }
    entry.swap(&candidate);
    // the breaker's failure streak belonged to the replaced predictor
    entry.breaker.reset();
    entry.stats.promotions.fetch_add(1, Ordering::Relaxed);
    lifecycle_counter("lifecycle_retrains_promoted_total").inc();
    let generation = crate::obs::metrics::global().gauge("lifecycle_model_generation");
    generation.add(1);

    // Probation: the gate scored held-out accuracy, not serving health.
    // If the breaker trips now, the promotion was wrong — undo it.
    let trips_before = entry.breaker.trips();
    let t0 = Instant::now();
    while t0.elapsed() < cfg.probation && !stop.load(Ordering::SeqCst) {
        std::thread::sleep(cfg.poll.min(Duration::from_millis(100)).max(Duration::from_millis(1)));
        let trips = entry.breaker.trips();
        if trips > trips_before {
            entry.swap(incumbent);
            entry.breaker.reset();
            entry.stats.rollbacks.fetch_add(1, Ordering::Relaxed);
            lifecycle_counter("lifecycle_retrains_rolled_back_total").inc();
            generation.add(-1);
            if let Err(e) = incumbent.save(&cfg.artifact_path) {
                eprintln!(
                    "warning: could not restore incumbent artifact at {}: {e}",
                    cfg.artifact_path.display()
                );
            }
            eprintln!(
                "warning: promotion of {:?} rolled back — breaker tripped {} time(s) \
                 within the {:?} probation window",
                entry.name(),
                trips - trips_before,
                cfg.probation
            );
            return CycleOutcome::RolledBack { decision, trips: trips - trips_before };
        }
    }
    CycleOutcome::Promoted { artifact: candidate, decision }
}

fn train_failed(entry: &ModelEntry, reason: String) -> CycleOutcome {
    lifecycle_counter("lifecycle_retrains_failed_total").inc();
    eprintln!(
        "warning: retrain cycle for {:?} failed — incumbent keeps serving: {reason}",
        entry.name()
    );
    CycleOutcome::TrainFailed { reason }
}

/// Background retrain scheduler (`serve --retrain-every`): runs
/// [`run_cycle`] on a period against one registry entry, threading the
/// incumbent artifact from cycle to cycle. Dropping the scheduler (or
/// calling [`stop`](Self::stop)) ends the loop promptly — the sleep is
/// sliced and the probation watch honours the same flag.
pub struct RetrainScheduler {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl RetrainScheduler {
    /// Start retraining `entry` every `every`. `initial` must be the
    /// artifact the entry currently serves; `trainer(cycle)` produces
    /// candidate number `cycle` (1-based) — typically a warm-started
    /// refit on freshly drifted data.
    pub fn start(
        entry: Arc<ModelEntry>,
        initial: ModelArtifact,
        every: Duration,
        mut trainer: impl FnMut(u64) -> anyhow::Result<ModelArtifact> + Send + 'static,
        gate: HoldoutGate,
        cfg: LifecycleConfig,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            let mut incumbent = initial;
            let mut cycle = 0u64;
            'outer: loop {
                // sliced sleep so stop() never waits out a long period
                let t0 = Instant::now();
                while t0.elapsed() < every {
                    if flag.load(Ordering::SeqCst) {
                        break 'outer;
                    }
                    std::thread::sleep(
                        (every - t0.elapsed()).min(Duration::from_millis(50)),
                    );
                }
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                cycle += 1;
                let outcome =
                    run_cycle(&entry, &incumbent, || trainer(cycle), &gate, &cfg, &flag);
                if let CycleOutcome::Promoted { artifact, .. } = outcome {
                    incumbent = artifact;
                }
            }
        });
        RetrainScheduler { stop, thread: Some(thread) }
    }

    /// Signal the loop to end and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RetrainScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::{ModelSpec, Registry, RegistryConfig};
    use std::sync::atomic::AtomicU64;

    fn artifact(scale: f64) -> ModelArtifact {
        ModelArtifact {
            sigma: 1.5,
            centers: Matrix::from_fn(5, 3, |i, j| ((i * 3 + j) as f64 * 0.37).sin()),
            alpha: (0..5).map(|i| scale * (0.3 + i as f64 * 0.11)).collect(),
            trained_n: 5,
            dataset: "unit".to_string(),
        }
    }

    fn entry_with(threshold: u32) -> Arc<ModelEntry> {
        let cfg = RegistryConfig {
            breaker_threshold: threshold,
            breaker_cooldown: Duration::from_secs(3600),
            ..RegistryConfig::default()
        };
        let reg = Registry::new(
            vec![ModelSpec { name: "m".to_string(), artifact: artifact(1.0), source: None }],
            cfg,
        )
        .unwrap();
        reg.get("m").unwrap()
    }

    /// Holdout targets equal to a given artifact's own predictions, so
    /// that artifact gates at RMSE 0 against them.
    fn gate_matching(art: &ModelArtifact, tolerance: f64) -> HoldoutGate {
        let queries = Matrix::from_fn(8, 3, |i, j| ((i * 3 + j) as f64 * 0.21).cos());
        let targets = Predictor::new(art).predict_batch(&queries).unwrap();
        HoldoutGate::new(queries, targets, tolerance).unwrap()
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("bless-lifecycle-{tag}-{}.bin", std::process::id()))
    }

    fn cleanup(path: &PathBuf) {
        std::fs::remove_file(path).ok();
        std::fs::remove_file(format!("{}.prev", path.display())).ok();
        if let Some(dir) = path.parent() {
            let stem = path.file_name().unwrap().to_string_lossy().to_string();
            if let Ok(entries) = std::fs::read_dir(dir) {
                for e in entries.flatten() {
                    if e.file_name().to_string_lossy().starts_with(&(stem.clone() + ".rejected")) {
                        std::fs::remove_file(e.path()).ok();
                    }
                }
            }
        }
    }

    fn quick(path: &PathBuf) -> LifecycleConfig {
        LifecycleConfig {
            artifact_path: path.clone(),
            probation: Duration::from_millis(60),
            poll: Duration::from_millis(5),
        }
    }

    #[test]
    fn gate_scores_and_validates() {
        let inc = artifact(1.0);
        let gate = gate_matching(&inc, 1e-9);
        let inc_pred = Predictor::new(&inc);
        // identical candidate: rmse 0 on both sides, passes
        let d = gate.evaluate(&inc_pred, &Predictor::new(&artifact(1.0))).unwrap();
        assert!(d.pass, "{d:?}");
        assert!(d.candidate_rmse < 1e-12);
        assert!(!d.injected);
        // a 5x-scaled candidate is much worse than tolerance allows
        let d = gate.evaluate(&inc_pred, &Predictor::new(&artifact(5.0))).unwrap();
        assert!(!d.pass, "{d:?}");
        assert!(d.candidate_rmse > d.incumbent_rmse);
        // bad construction
        assert!(HoldoutGate::new(Matrix::zeros(0, 3), vec![], 0.1).is_err());
        assert!(HoldoutGate::new(Matrix::zeros(2, 3), vec![0.0], 0.1).is_err());
        assert!(HoldoutGate::new(Matrix::zeros(1, 3), vec![0.0], -1.0).is_err());
        assert!(HoldoutGate::new(Matrix::zeros(1, 3), vec![0.0], f64::NAN).is_err());
    }

    #[test]
    fn promotion_swaps_persists_and_survives_probation() {
        let entry = entry_with(0);
        let incumbent = artifact(1.0);
        let better = artifact(2.0);
        // targets match the *candidate*: the incumbent gates worse
        let gate = gate_matching(&better, 1e-9);
        let path = tmp("promote");
        let cfg = quick(&path);
        let stop = AtomicBool::new(false);

        let q = [0.1, -0.2, 0.3];
        let want = Predictor::new(&better).predict_one(&q).unwrap();
        let outcome = run_cycle(
            &entry,
            &incumbent,
            || Ok(artifact(2.0)),
            &gate,
            &cfg,
            &stop,
        );
        match outcome {
            CycleOutcome::Promoted { ref decision, .. } => {
                assert!(decision.candidate_rmse <= decision.incumbent_rmse);
            }
            other => panic!("expected promotion, got {other:?}"),
        }
        assert_eq!(entry.version(), 2, "promotion must swap the entry");
        assert_eq!(entry.stats.promotions.load(Ordering::Relaxed), 1);
        assert_eq!(entry.stats.rollbacks.load(Ordering::Relaxed), 0);
        let got = entry.predictor().predict_one(&q).unwrap();
        assert_eq!(got.to_bits(), want.to_bits(), "candidate must be serving");
        // artifact_path now holds the candidate, .prev the incumbent
        assert_eq!(ModelArtifact::load(&path).unwrap().alpha, better.alpha);
        let prev = PathBuf::from(format!("{}.prev", path.display()));
        assert_eq!(ModelArtifact::load(&prev).unwrap().alpha, incumbent.alpha);
        cleanup(&path);
    }

    #[test]
    fn gate_rejection_quarantines_and_keeps_incumbent() {
        let entry = entry_with(0);
        let incumbent = artifact(1.0);
        let gate = gate_matching(&incumbent, 1e-9);
        let path = tmp("reject");
        let cfg = quick(&path);
        let stop = AtomicBool::new(false);

        let outcome =
            run_cycle(&entry, &incumbent, || Ok(artifact(5.0)), &gate, &cfg, &stop);
        let quarantined = match outcome {
            CycleOutcome::GateRejected { quarantined_to, decision } => {
                assert!(!decision.pass);
                quarantined_to.expect("quarantine file must be written")
            }
            other => panic!("expected rejection, got {other:?}"),
        };
        assert_eq!(entry.version(), 1, "a rejected candidate must never swap in");
        assert_eq!(entry.stats.promotions.load(Ordering::Relaxed), 0);
        assert!(!path.exists(), "artifact_path must be untouched by a rejection");
        // the quarantined artifact is intact for post-mortem
        assert_eq!(ModelArtifact::load(&quarantined).unwrap().alpha, artifact(5.0).alpha);
        std::fs::remove_file(&quarantined).ok();
        cleanup(&path);
    }

    #[test]
    fn train_panic_and_train_error_leave_incumbent_serving() {
        let entry = entry_with(0);
        let incumbent = artifact(1.0);
        let gate = gate_matching(&incumbent, 1e-9);
        let path = tmp("panic");
        let cfg = quick(&path);
        let stop = AtomicBool::new(false);

        let outcome = run_cycle(
            &entry,
            &incumbent,
            || panic!("synthetic trainer crash"),
            &gate,
            &cfg,
            &stop,
        );
        match outcome {
            CycleOutcome::TrainFailed { reason } => {
                assert!(reason.contains("synthetic trainer crash"), "got {reason}");
            }
            other => panic!("expected TrainFailed, got {other:?}"),
        }
        let outcome = run_cycle(
            &entry,
            &incumbent,
            || anyhow::bail!("no data this cycle"),
            &gate,
            &cfg,
            &stop,
        );
        assert!(matches!(outcome, CycleOutcome::TrainFailed { .. }));
        // a candidate with the wrong dimension is refused up front
        let wrong_d = ModelArtifact {
            sigma: 1.5,
            centers: Matrix::from_fn(5, 4, |i, j| (i + j) as f64),
            alpha: vec![0.1; 5],
            trained_n: 5,
            dataset: "unit".to_string(),
        };
        let outcome = run_cycle(&entry, &incumbent, || Ok(wrong_d), &gate, &cfg, &stop);
        match outcome {
            CycleOutcome::TrainFailed { reason } => {
                assert!(reason.contains("dimension"), "got {reason}");
            }
            other => panic!("expected TrainFailed, got {other:?}"),
        }
        assert_eq!(entry.version(), 1, "incumbent untouched through all three failures");
        cleanup(&path);
    }

    #[test]
    fn breaker_trip_in_probation_rolls_back() {
        let entry = entry_with(2);
        let incumbent = artifact(1.0);
        let better = artifact(2.0);
        let gate = gate_matching(&better, 1e-9);
        let path = tmp("rollback");
        let cfg = LifecycleConfig {
            artifact_path: path.clone(),
            probation: Duration::from_secs(10), // the trip ends it early
            poll: Duration::from_millis(2),
        };
        let stop = AtomicBool::new(false);

        // trip the breaker shortly after the promotion lands
        let trip_entry = Arc::clone(&entry);
        let tripper = std::thread::spawn(move || {
            let t0 = Instant::now();
            while trip_entry.version() < 2 {
                assert!(t0.elapsed() < Duration::from_secs(10), "promotion never landed");
                std::thread::sleep(Duration::from_millis(2));
            }
            trip_entry.breaker.record_failure();
            trip_entry.breaker.record_failure(); // threshold 2 → trip
        });

        let q = [0.1, -0.2, 0.3];
        let want = Predictor::new(&incumbent).predict_one(&q).unwrap();
        let outcome =
            run_cycle(&entry, &incumbent, || Ok(artifact(2.0)), &gate, &cfg, &stop);
        tripper.join().unwrap();
        match outcome {
            CycleOutcome::RolledBack { trips, .. } => assert!(trips >= 1),
            other => panic!("expected rollback, got {other:?}"),
        }
        assert_eq!(entry.version(), 3, "swap in + swap back");
        assert_eq!(entry.stats.promotions.load(Ordering::Relaxed), 1);
        assert_eq!(entry.stats.rollbacks.load(Ordering::Relaxed), 1);
        assert!(!entry.breaker.is_open(), "rollback must reset the breaker");
        let got = entry.predictor().predict_one(&q).unwrap();
        assert_eq!(got.to_bits(), want.to_bits(), "incumbent must be serving again");
        // artifact_path was restored to the incumbent for restart safety
        assert_eq!(ModelArtifact::load(&path).unwrap().alpha, incumbent.alpha);
        cleanup(&path);
    }

    #[test]
    fn scheduler_runs_cycles_and_stops_cleanly() {
        let entry = entry_with(0);
        let initial = artifact(1.0);
        // every candidate matches the holdout targets exactly, so each
        // cycle promotes and the version keeps climbing
        let better = artifact(2.0);
        let gate = gate_matching(&better, 1e-9);
        let path = tmp("sched");
        let cfg = LifecycleConfig {
            artifact_path: path.clone(),
            probation: Duration::from_millis(5),
            poll: Duration::from_millis(1),
        };
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = Arc::clone(&calls);
        let sched = RetrainScheduler::start(
            Arc::clone(&entry),
            initial,
            Duration::from_millis(20),
            move |_cycle| {
                calls2.fetch_add(1, Ordering::Relaxed);
                Ok(artifact(2.0))
            },
            gate,
            cfg,
        );
        let t0 = Instant::now();
        while entry.stats.promotions.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(30), "scheduler never promoted twice");
            std::thread::sleep(Duration::from_millis(5));
        }
        sched.stop();
        let after = calls.load(Ordering::Relaxed);
        assert!(after >= 2, "trainer must have run, got {after}");
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(calls.load(Ordering::Relaxed), after, "stop must end the loop");
        cleanup(&path);
    }
}
