//! Determinism of the parallel compute core: every parallel kernel must
//! produce **bit-identical** output to the 1-thread path, because the
//! pool partitions work into fixed blocks whose boundaries and
//! per-element floating-point order never depend on the thread count.
//!
//! Since the micro-kernel dispatch tier, the contract is **per ISA**:
//! bits may differ between the scalar and AVX2 backends (accuracy-gated
//! in `tests/isa_dispatch.rs`), but within one backend the thread count
//! must never change a single bit. Every sweep here therefore runs under
//! each backend the host supports (see [`for_each_isa`]).
//!
//! Tests in this binary mutate the process-global pool width and the
//! process-global ISA selection, so they serialize through one mutex.

use bless::data::susy_like;
use bless::falkon::{CheckpointSpec, Falkon, FitOptions, Preconditioner};
use bless::kernels::{Gaussian, KernelEngine, NativeEngine, PanelCache, DEFAULT_ROW_TILE};
use bless::leverage::{LsGenerator, WeightedSet};
use bless::linalg::{self, MatMul, Matrix};
use bless::rng::Rng;
use bless::util::pool;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serialize tests that flip the global thread count.
fn lock() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` at the given pool width, restoring the default afterwards.
fn at_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    pool::set_threads(n);
    let out = f();
    pool::set_threads(0);
    out
}

/// Run the whole thread-count sweep `f` under every micro-kernel backend
/// this host supports — always scalar, plus AVX2 where available — then
/// restore auto-detection. `BLESS_ISA=scalar` in CI exercises the same
/// scalar path at the process level; this helper additionally covers the
/// SIMD backend in-process on capable hosts.
fn for_each_isa(f: impl Fn(linalg::Isa)) {
    for isa in [linalg::Isa::Scalar, linalg::Isa::Avx2] {
        if linalg::set_isa(isa).is_ok() {
            f(isa);
        }
    }
    linalg::set_isa_from_str("auto").unwrap();
}

fn bits_of(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn gemm_bit_identical_across_thread_counts() {
    let _g = lock();
    // full-mantissa values and sizes above every dispatch threshold
    let a = Matrix::from_fn(200, 150, |i, j| ((i * 150 + j) as f64 * 0.618).sin() * 2.0);
    let b = Matrix::from_fn(150, 130, |i, j| ((i * 130 + j) as f64 * 1.414).cos() * 0.5);
    for_each_isa(|isa| {
        let serial = at_threads(1, || linalg::gemm(&a, &b));
        for t in [2usize, 4, 8] {
            let par = at_threads(t, || linalg::gemm(&a, &b));
            assert_eq!(
                bits_of(serial.as_slice()),
                bits_of(par.as_slice()),
                "gemm diverged at {t} threads ({})",
                isa.name()
            );
        }
    });
}

#[test]
fn gemm_tn_and_matvecs_bit_identical() {
    let _g = lock();
    let a = Matrix::from_fn(300, 280, |i, j| ((i * 280 + j) as f64 * 0.37).sin());
    let b = Matrix::from_fn(300, 90, |i, j| ((i * 90 + j) as f64 * 0.73).cos());
    let x: Vec<f64> = (0..280).map(|i| ((i * i) as f64 * 0.11).sin()).collect();
    let u: Vec<f64> = (0..300).map(|i| (i as f64 * 0.29).cos()).collect();
    let run = || (MatMul::tn().run(&a, &b), linalg::matvec(&a, &x), linalg::matvec_t(&a, &u));
    for_each_isa(|isa| {
        let (tn1, mv1, mt1) = at_threads(1, run);
        for t in [2usize, 4] {
            let (tnp, mvp, mtp) = at_threads(t, run);
            let tag = isa.name();
            assert_eq!(bits_of(tn1.as_slice()), bits_of(tnp.as_slice()), "gemm tn @ {t} ({tag})");
            assert_eq!(bits_of(&mv1), bits_of(&mvp), "matvec @ {t} ({tag})");
            assert_eq!(bits_of(&mt1), bits_of(&mtp), "matvec_t @ {t} ({tag})");
        }
    });
}

#[test]
fn solve_lower_matrix_bit_identical() {
    let _g = lock();
    // a well-conditioned lower-triangular factor and a wide RHS (wider
    // than the parallel path's column block)
    let n = 120;
    let l = Matrix::from_fn(n, n, |i, j| {
        if j > i {
            0.0
        } else if i == j {
            2.0 + ((i * 7) % 5) as f64 * 0.25
        } else {
            (((i * 13 + j * 5) % 9) as f64 - 4.0) * 0.05
        }
    });
    let b = Matrix::from_fn(n, 700, |i, j| ((i * 700 + j) as f64 * 0.21).sin());
    for_each_isa(|isa| {
        let serial = at_threads(1, || linalg::solve_lower_matrix(&l, &b));
        for t in [2usize, 4] {
            let par = at_threads(t, || linalg::solve_lower_matrix(&l, &b));
            assert_eq!(
                bits_of(serial.as_slice()),
                bits_of(par.as_slice()),
                "solve_lower_matrix diverged at {t} threads ({})",
                isa.name()
            );
        }
    });
}

#[test]
fn kernel_block_and_fused_matvec_bit_identical() {
    let _g = lock();
    let ds = susy_like(600, &mut Rng::seeded(11));
    let eng = NativeEngine::new(ds.x, Gaussian::new(3.0));
    let rows: Vec<usize> = (0..500).collect();
    let cols: Vec<usize> = (0..120).map(|i| i * 5).collect();
    let v: Vec<f64> = (0..120).map(|i| ((i as f64) * 0.17).sin()).collect();
    for_each_isa(|isa| {
        let (blk1, fused1) =
            at_threads(1, || (eng.block(&rows, &cols), eng.knm_t_knm_matvec(&cols, &v)));
        for t in [2usize, 4, 8] {
            let (blkp, fusedp) =
                at_threads(t, || (eng.block(&rows, &cols), eng.knm_t_knm_matvec(&cols, &v)));
            assert_eq!(
                bits_of(blk1.as_slice()),
                bits_of(blkp.as_slice()),
                "kernel block diverged at {t} threads ({})",
                isa.name()
            );
            assert_eq!(bits_of(&fused1), bits_of(&fusedp), "fused CG matvec @ {t}");
        }
    });
}

/// Deterministic, exactly-symmetric, diagonally-dominant SPD test matrix
/// (shared with the factorization benches).
fn spd(n: usize) -> Matrix {
    Matrix::spd_probe(n)
}

#[test]
fn cholesky_bit_identical_across_thread_counts() {
    let _g = lock();
    // sizes straddling the NB=96 panel boundary, plus a multi-panel one
    for &n in &[95usize, 96, 97, 513] {
        let a = spd(n);
        for_each_isa(|isa| {
            let serial = at_threads(1, || linalg::cholesky(&a).expect("SPD"));
            for t in [2usize, 4, 8] {
                let par = at_threads(t, || linalg::cholesky(&a).expect("SPD"));
                assert_eq!(
                    bits_of(serial.l().as_slice()),
                    bits_of(par.l().as_slice()),
                    "cholesky n={n} diverged at {t} threads ({})",
                    isa.name()
                );
            }
        });
    }
}

#[test]
fn triangular_tier_solves_bit_identical() {
    let _g = lock();
    let n = 260;
    let a = spd(n);
    let b = Matrix::from_fn(n, 600, |i, j| ((i * 600 + j) as f64 * 0.17).sin());
    let run = || {
        let f = linalg::cholesky(&a).expect("SPD");
        let lt = f.solve_lt_matrix(&b);
        let fused = f.solve_matrix(&b);
        (lt, fused)
    };
    for_each_isa(|isa| {
        let (lt1, fu1) = at_threads(1, run);
        for t in [2usize, 4, 8] {
            let (ltp, fup) = at_threads(t, run);
            let tag = isa.name();
            assert_eq!(
                bits_of(lt1.as_slice()),
                bits_of(ltp.as_slice()),
                "solve_lt_matrix @ {t} ({tag})"
            );
            assert_eq!(
                bits_of(fu1.as_slice()),
                bits_of(fup.as_slice()),
                "solve_matrix @ {t} ({tag})"
            );
        }
    });
}

#[test]
fn preconditioner_build_and_applies_bit_identical() {
    let _g = lock();
    let ds = susy_like(400, &mut Rng::seeded(23));
    let eng = NativeEngine::new(ds.x, Gaussian::new(3.0));
    let m = 130; // straddles the NB-panel remainder inside the factor
    let idx: Vec<usize> = (0..m).map(|i| i * 3).collect();
    let kmm = eng.block(&idx, &idx);
    let weights: Vec<f64> = (0..m).map(|i| 0.5 + (i % 9) as f64 * 0.25).collect();
    let v: Vec<f64> = (0..m).map(|i| ((i as f64) * 0.37).sin()).collect();
    let run = || {
        let p = Preconditioner::new(&kmm, &weights, 400, 1e-3).expect("precond");
        (p.apply_b(&v), p.apply_bt(&v), p.solve_lt(&v))
    };
    for_each_isa(|isa| {
        let (b1, bt1, lt1) = at_threads(1, run);
        for t in [2usize, 4, 8] {
            let (bp, btp, ltp) = at_threads(t, run);
            let tag = isa.name();
            assert_eq!(bits_of(&b1), bits_of(&bp), "apply_b @ {t} threads ({tag})");
            assert_eq!(bits_of(&bt1), bits_of(&btp), "apply_bt @ {t} threads ({tag})");
            assert_eq!(bits_of(&lt1), bits_of(&ltp), "solve_lt @ {t} threads ({tag})");
        }
    });
}

#[test]
fn ls_generator_scores_bit_identical() {
    let _g = lock();
    let ds = susy_like(600, &mut Rng::seeded(31));
    let eng = NativeEngine::new(ds.x, Gaussian::new(3.0));
    let lambda = 1e-3;
    let set = WeightedSet::uniform((0..150).map(|i| i * 4).collect(), lambda);
    let batch: Vec<usize> = (0..600).collect();
    let run = || {
        let gen = LsGenerator::new(&eng, &set, lambda).expect("generator");
        (gen.scores(&batch), gen.scores_all())
    };
    for_each_isa(|isa| {
        let (s1, a1) = at_threads(1, run);
        for t in [2usize, 4, 8] {
            let (sp, ap) = at_threads(t, run);
            let tag = isa.name();
            assert_eq!(bits_of(&s1), bits_of(&sp), "scores @ {t} threads ({tag})");
            assert_eq!(bits_of(&a1), bits_of(&ap), "scores_all @ {t} threads ({tag})");
        }
    });
}

#[test]
fn falkon_training_and_predictions_bit_identical() {
    let _g = lock();
    let mut rng = Rng::seeded(42);
    let ds = susy_like(600, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let centers = Rng::seeded(7).sample_without_replacement(train.n(), 80);
    let lambda = 1e-3;
    let set = WeightedSet::uniform(centers, lambda);

    let fit_once = || {
        let eng = NativeEngine::new(train.x.clone(), Gaussian::new(3.0));
        let model = Falkon::new(&eng, &set, lambda).unwrap().fit(&train.y, 6, None).unwrap();
        let preds = model.predict(&eng, &test.x);
        (model.alpha, preds)
    };
    for_each_isa(|isa| {
        let (alpha1, preds1) = at_threads(1, fit_once);
        for t in [2usize, 4] {
            let (alphap, predsp) = at_threads(t, fit_once);
            let tag = isa.name();
            assert_eq!(bits_of(&alpha1), bits_of(&alphap), "FALKON α @ {t} threads ({tag})");
            assert_eq!(bits_of(&preds1), bits_of(&predsp), "predictions @ {t} threads ({tag})");
        }
    });
}

/// Span tracing must be observation-only: the full BLESS → FALKON →
/// predict pipeline produces bit-identical numbers with tracing on and
/// off, while the traced run still yields a non-trivial profile.
#[test]
fn tracing_on_and_off_bit_identical() {
    let _g = lock();
    let mut rng = Rng::seeded(55);
    let ds = susy_like(500, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);

    let fit_once = || {
        let mut rng = Rng::seeded(5);
        let eng = NativeEngine::new(train.x.clone(), Gaussian::new(3.0));
        let path = bless::bless::bless(&eng, 1e-3, &bless::bless::BlessConfig::default(), &mut rng);
        let model =
            Falkon::new(&eng, path.final_set(), 1e-5).unwrap().fit(&train.y, 6, None).unwrap();
        let preds = model.predict(&eng, &test.x);
        (model.alpha, preds)
    };

    let (alpha_off, preds_off) = at_threads(4, fit_once); // spans disabled (default)
    bless::obs::span::reset();
    bless::obs::span::set_enabled(true);
    let (alpha_on, preds_on) = at_threads(4, fit_once);
    bless::obs::span::set_enabled(false);
    let profile = bless::obs::span::profile();
    bless::obs::span::reset();

    assert_eq!(bits_of(&alpha_off), bits_of(&alpha_on), "tracing changed FALKON α");
    assert_eq!(bits_of(&preds_off), bits_of(&preds_on), "tracing changed predictions");
    assert!(!profile.is_empty(), "traced run produced no spans");
    assert!(profile.get("falkon.fit").is_some(), "missing falkon.fit span");
    assert!(profile.get("falkon.fit/cg_iter").is_some(), "missing CG iteration span");
}

#[test]
fn panel_cache_bit_identical_across_threads_and_budgets() {
    let _g = lock();
    // multi-tile shape so a partial budget mixes cached + streamed tiles
    let n = DEFAULT_ROW_TILE + 300;
    let ds = susy_like(n, &mut Rng::seeded(13));
    let eng = NativeEngine::new(ds.x, Gaussian::new(3.0));
    let centers: Vec<usize> = (0..70).map(|i| i * 17).collect();
    let m = centers.len();
    let d = eng.points().cols();
    let partial_budget = m * (d + 2) * 8 + DEFAULT_ROW_TILE * m * 8; // 1 of 2 tiles
    let v: Vec<f64> = (0..m).map(|i| ((i as f64) * 0.29).sin()).collect();
    let u: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.011).cos()).collect();

    let sweep = |budget: usize| {
        let cache = PanelCache::new(&eng, &centers, budget);
        (cache.knm_matvec(&v), cache.knm_t_matvec(&u), cache.knm_t_knm_matvec(&v))
    };
    for_each_isa(|isa| {
        let (y1, z1, f1) = at_threads(1, || sweep(0));
        for t in [1usize, 2, 4, 8] {
            for budget in [0usize, partial_budget, usize::MAX] {
                let (yp, zp, fp) = at_threads(t, || sweep(budget));
                let tag = isa.name();
                assert_eq!(bits_of(&y1), bits_of(&yp), "K·v @ {t}, budget {budget} ({tag})");
                assert_eq!(bits_of(&z1), bits_of(&zp), "Kᵀ·u @ {t}, budget {budget} ({tag})");
                assert_eq!(bits_of(&f1), bits_of(&fp), "KᵀK·v @ {t}, budget {budget} ({tag})");
            }
        }
    });
}

/// A fit killed mid-run and resumed from its `BLESSCKPT` checkpoint must
/// reproduce the uninterrupted fit bit-for-bit — at every thread width,
/// under every ISA backend, and regardless of which width wrote the
/// checkpoint versus which one resumed it (the checkpoint captures the
/// complete CG state between iterations, and iteration arithmetic is
/// thread-invariant).
#[test]
fn checkpoint_resume_bit_identical_across_thread_counts() {
    let _g = lock();
    let mut rng = Rng::seeded(42);
    let ds = susy_like(600, &mut rng);
    let (train, _test) = ds.split(0.25, &mut rng);
    let centers = Rng::seeded(7).sample_without_replacement(train.n(), 80);
    let lambda = 1e-3;
    let set = WeightedSet::uniform(centers, lambda);
    let dir = std::env::temp_dir().join(format!("bless-ckpt-det-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let eng = NativeEngine::new(train.x.clone(), Gaussian::new(3.0));
    let solver = Falkon::new(&eng, &set, lambda).unwrap();
    let fit_ckpt = |solver: &Falkon<'_>, t: usize, path: &std::path::Path, resume: bool| {
        solver
            .fit_opts(
                &train.y,
                t,
                None,
                FitOptions {
                    tol: 0.0,
                    warm_start: None,
                    checkpoint: Some(CheckpointSpec {
                        path: path.to_path_buf(),
                        every: 2,
                        resume,
                    }),
                },
            )
            .unwrap()
    };

    for_each_isa(|isa| {
        let tag = isa.name();
        // the reference: one uninterrupted 10-iteration fit at 1 thread
        let full = at_threads(1, || solver.fit(&train.y, 10, None).unwrap());
        for t in [1usize, 2, 4] {
            // "kill" after 6 iterations at width t, resume to 10 at the
            // same width...
            let path = dir.join(format!("det-{tag}-{t}.ckpt"));
            at_threads(t, || fit_ckpt(&solver, 6, &path, false));
            let resumed = at_threads(t, || fit_ckpt(&solver, 10, &path, true));
            assert_eq!(
                resumed.iterations.first().map(|s| s.iter),
                Some(7),
                "must resume at iteration 7, not cold-start ({tag}, {t} threads)"
            );
            assert_eq!(
                bits_of(&full.alpha),
                bits_of(&resumed.alpha),
                "resumed α diverged from uninterrupted fit at {t} threads ({tag})"
            );
            // ...and resume a checkpoint written at a *different* width:
            // 1-thread writer, t-thread resumer
            let cross = dir.join(format!("det-{tag}-cross-{t}.ckpt"));
            at_threads(1, || fit_ckpt(&solver, 6, &cross, false));
            let crossed = at_threads(t, || fit_ckpt(&solver, 10, &cross, true));
            assert_eq!(
                bits_of(&full.alpha),
                bits_of(&crossed.alpha),
                "cross-width resume diverged at {t} threads ({tag})"
            );
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn qr_factorization_bit_identical_across_thread_counts() {
    let _g = lock();
    // shapes straddling the NB=32 QR panel boundary, plus a multi-panel
    // tall one — the sketched-solve shapes of leverage/sketch.rs
    for &(m, k) in &[(95usize, 95usize), (96, 64), (97, 96), (513, 97)] {
        let a = Matrix::from_fn(m, k, |i, j| {
            ((i * k + j) as f64 * 0.61803).sin() + if i == j { 2.0 } else { 0.0 }
        });
        let run = || {
            let f = linalg::qr(a.clone());
            (f.r(), f.thin_q())
        };
        for_each_isa(|isa| {
            let (r1, q1) = at_threads(1, run);
            for t in [2usize, 4, 8] {
                let (rp, qp) = at_threads(t, run);
                let tag = isa.name();
                assert_eq!(
                    bits_of(r1.as_slice()),
                    bits_of(rp.as_slice()),
                    "qr R ({m},{k}) diverged at {t} threads ({tag})"
                );
                assert_eq!(
                    bits_of(q1.as_slice()),
                    bits_of(qp.as_slice()),
                    "qr Q ({m},{k}) diverged at {t} threads ({tag})"
                );
            }
        });
    }
}

#[test]
fn estimator_family_scores_bit_identical_across_thread_counts() {
    let _g = lock();
    let ds = susy_like(400, &mut Rng::seeded(61));
    let eng = NativeEngine::new(ds.x, Gaussian::new(3.0));
    let lambda = 1e-2;
    for spec in ["count-sketch:96", "srft:96", "rls-nystrom:96"] {
        let run = || {
            let est = bless::leverage::parse_estimator(spec).expect(spec);
            est.scores(&eng, lambda, &mut Rng::seeded(13)).expect(spec)
        };
        for_each_isa(|isa| {
            let s1 = at_threads(1, run);
            for t in [2usize, 4, 8] {
                let sp = at_threads(t, run);
                assert_eq!(
                    bits_of(&s1),
                    bits_of(&sp),
                    "{spec} diverged at {t} threads ({})",
                    isa.name()
                );
            }
        });
    }
}

#[test]
fn falkon_cached_and_streamed_paths_bit_identical_across_threads() {
    let _g = lock();
    let mut rng = Rng::seeded(77);
    let n = DEFAULT_ROW_TILE + 250;
    let ds = susy_like(n, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let centers = Rng::seeded(9).sample_without_replacement(train.n(), 64);
    let lambda = 1e-3;
    let set = WeightedSet::uniform(centers, lambda);

    let fit_at = |budget: usize| {
        let eng = NativeEngine::new(train.x.clone(), Gaussian::new(3.0));
        let model = Falkon::with_budget(&eng, &set, lambda, budget)
            .unwrap()
            .fit(&train.y, 5, None)
            .unwrap();
        let preds = model.predict(&eng, &test.x);
        (model.alpha, preds)
    };
    for_each_isa(|isa| {
        let (alpha1, preds1) = at_threads(1, || fit_at(0));
        for t in [1usize, 2, 4, 8] {
            for budget in [0usize, usize::MAX] {
                let (alphap, predsp) = at_threads(t, || fit_at(budget));
                let tag = isa.name();
                assert_eq!(
                    bits_of(&alpha1),
                    bits_of(&alphap),
                    "FALKON α diverged at {t} threads, budget {budget} ({tag})"
                );
                assert_eq!(
                    bits_of(&preds1),
                    bits_of(&predsp),
                    "predictions diverged at {t} threads, budget {budget} ({tag})"
                );
            }
        }
    });
}
