//! End-to-end determinism of the memory-budgeted `K_nM` panel cache:
//! FALKON training and prediction must be **bit-identical** whether the
//! panel is fully streamed (`--mem-budget 0`), partially cached (budget
//! covers only a prefix of the row tiles), or fully materialized —
//! because cached tiles hold exactly the bytes the streaming evaluator
//! produces and the tile partition never depends on the budget.

use bless::data::susy_like;
use bless::falkon::Falkon;
use bless::kernels::{Gaussian, KernelEngine, NativeEngine, PanelCache, DEFAULT_ROW_TILE};
use bless::leverage::WeightedSet;
use bless::rng::Rng;

fn bits_of(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A multi-tile problem: n crosses two tile boundaries so a partial
/// budget genuinely mixes cached and recomputed tiles.
fn setup() -> (NativeEngine, Vec<f64>, WeightedSet, usize) {
    let n = 2 * DEFAULT_ROW_TILE + 400; // 3 tiles: full, full, partial
    let mut rng = Rng::seeded(404);
    let ds = susy_like(n, &mut rng);
    let eng = NativeEngine::new(ds.x, Gaussian::new(4.0));
    let centers = rng.sample_without_replacement(n, 96);
    let m = centers.len();
    (eng, ds.y, WeightedSet::uniform(centers, 1e-4), m)
}

/// Budget that caches exactly `tiles` leading tiles for `m` centers.
fn budget_for_tiles(tiles: usize, m: usize, d: usize) -> usize {
    m * (d + 2) * 8 + tiles * DEFAULT_ROW_TILE * m * 8
}

#[test]
fn falkon_bitwise_identical_across_budgets() {
    let (eng, y, set, m) = setup();
    let d = eng.points().cols();
    let fit_at = |budget: usize| {
        let solver = Falkon::with_budget(&eng, &set, 1e-4, budget).unwrap();
        let model = solver.fit(&y, 8, None).unwrap();
        let train_preds = model.predict(&eng, eng.points());
        (solver.panel().plan().cached_tiles, model.alpha, train_preds)
    };

    let (t0, alpha0, preds0) = fit_at(0);
    assert_eq!(t0, 0, "budget 0 must stream everything");
    let (t1, alpha1, preds1) = fit_at(budget_for_tiles(1, m, d));
    assert_eq!(t1, 1, "partial budget must cache exactly one tile");
    let (t2, alpha2, preds2) = fit_at(usize::MAX);
    assert_eq!(t2, 3, "unbounded budget must cache all tiles");

    for (label, alpha, preds) in
        [("partial", &alpha1, &preds1), ("unbounded", &alpha2, &preds2)]
    {
        assert_eq!(bits_of(&alpha0), bits_of(alpha), "α diverged on the {label} budget");
        assert_eq!(
            bits_of(&preds0),
            bits_of(preds),
            "training predictions diverged on the {label} budget"
        );
    }
}

#[test]
fn panel_matvecs_bitwise_identical_across_budgets() {
    let (eng, _y, set, m) = setup();
    let d = eng.points().cols();
    let v: Vec<f64> = (0..m).map(|i| ((i as f64) * 0.37).sin()).collect();
    let u: Vec<f64> = (0..eng.n()).map(|i| ((i as f64) * 0.013).cos()).collect();
    let reference = PanelCache::new(&eng, &set.indices, 0);
    let r_knm = reference.knm_matvec(&v);
    let r_t = reference.knm_t_matvec(&u);
    let r_fused = reference.knm_t_knm_matvec(&v);
    for tiles in [1usize, 2, 3] {
        let cache = PanelCache::new(&eng, &set.indices, budget_for_tiles(tiles, m, d));
        assert_eq!(cache.plan().cached_tiles, tiles);
        assert_eq!(bits_of(&r_knm), bits_of(&cache.knm_matvec(&v)), "K·v @ {tiles} tiles");
        assert_eq!(bits_of(&r_t), bits_of(&cache.knm_t_matvec(&u)), "Kᵀ·u @ {tiles} tiles");
        assert_eq!(
            bits_of(&r_fused),
            bits_of(&cache.knm_t_knm_matvec(&v)),
            "KᵀK·v @ {tiles} tiles"
        );
    }
}

#[test]
fn cached_panel_stops_paying_for_kernel_evaluations() {
    let (eng, y, set, _m) = setup();
    let iters = 6;

    let streamed = Falkon::with_budget(&eng, &set, 1e-4, 0).unwrap();
    streamed.fit(&y, iters, None).unwrap();
    let s = streamed.panel().stats();

    let cached = Falkon::with_budget(&eng, &set, 1e-4, usize::MAX).unwrap();
    cached.fit(&y, iters, None).unwrap();
    let c = cached.panel().stats();

    let panel_entries = (eng.n() * streamed.m()) as u64;
    // streaming: one RHS pass + one pass per CG iteration
    assert_eq!(s.entries_evaluated, (iters as u64 + 1) * panel_entries);
    assert_eq!(c.entries_evaluated, panel_entries, "cached path must evaluate once");
    assert_eq!(c.streamed, 0);
    assert!(c.cached_hits > 0);
}
