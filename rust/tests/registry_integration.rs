//! End-to-end tests of serving tier v2 (ISSUE 2 acceptance criteria):
//! two models served from one process, hot reload under concurrent
//! traffic with zero failed in-flight requests, and queue-depth
//! backpressure answering a structured `overloaded` reply.

mod common;

use bless::linalg::Matrix;
use bless::rng::Rng;
use bless::serve::{self, Client, ModelArtifact, ModelSpec, Predictor, ServeConfig};
use common::with_timeout;
use std::sync::Arc;
use std::time::Duration;

/// A deterministic synthetic artifact; different seeds/scales give
/// models with visibly different predictions.
fn artifact(seed: u64, m: usize, d: usize, scale: f64) -> ModelArtifact {
    let mut rng = Rng::seeded(seed);
    ModelArtifact {
        sigma: 2.5,
        centers: Matrix::from_fn(m, d, |_, _| rng.gaussian()),
        alpha: (0..m).map(|_| rng.gaussian() * scale).collect(),
        trained_n: m,
        dataset: format!("registry-it-{seed}"),
    }
}

fn queries(seed: u64, n: usize, d: usize) -> Vec<Vec<f64>> {
    let mut rng = Rng::seeded(seed);
    (0..n).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect()
}

/// Two named models in one process: traffic routes by name, admin lists
/// both, and a hot reload swaps model "a" mid-traffic while every
/// in-flight and subsequent request succeeds (zero failures).
#[test]
fn two_models_and_hot_reload_under_traffic_with_zero_failures() {
    with_timeout(120, || {
        const D: usize = 6;
        let a_v1 = artifact(1, 40, D, 1.0);
        let a_v2 = artifact(2, 50, D, 1.0); // different M too: a real swap
        let b = artifact(3, 30, D, 0.5);

        // the replacement artifact is hot-reloaded from a *binary* file
        let v2_path = std::env::temp_dir()
            .join(format!("bless-registry-it-v2-{}.bin", std::process::id()));
        a_v2.save(&v2_path).unwrap();

        let qs = Arc::new(queries(9, 24, D));
        let expect_a1: Vec<f64> =
            qs.iter().map(|q| Predictor::new(&a_v1).predict_one(q).unwrap()).collect();
        let expect_a2: Vec<f64> =
            qs.iter().map(|q| Predictor::new(&a_v2).predict_one(q).unwrap()).collect();
        let expect_b: Vec<f64> =
            qs.iter().map(|q| Predictor::new(&b).predict_one(q).unwrap()).collect();
        let expect_a1 = Arc::new(expect_a1);
        let expect_a2 = Arc::new(expect_a2);
        let expect_b = Arc::new(expect_b);

        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(2)
            .max_batch(16)
            .linger(Duration::from_millis(1))
            .cache_capacity(0) // keep served-value provenance unambiguous
            .max_queue(0)
            .build()
            .unwrap();
        let specs = vec![
            ModelSpec { name: "a".to_string(), artifact: a_v1, source: None },
            ModelSpec { name: "b".to_string(), artifact: b, source: None },
        ];
        let handle = serve::start_registry(specs, &cfg).unwrap();
        let addr = handle.addr();

        const CLIENTS: usize = 6;
        const PER_CLIENT: usize = 60;
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let qs = Arc::clone(&qs);
            let (e_a1, e_a2, e_b) =
                (Arc::clone(&expect_a1), Arc::clone(&expect_a2), Arc::clone(&expect_b));
            joins.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for k in 0..PER_CLIENT {
                    let row = (c * 13 + k * 5) % qs.len();
                    let id = (c * PER_CLIENT + k) as u64;
                    let model = if k % 2 == 0 { "a" } else { "b" };
                    // every request must succeed — a dropped or errored
                    // reply during the reload fails the test here
                    let (y, _cached) = client.predict_on(model, id, &qs[row]).unwrap();
                    if model == "b" {
                        assert!(
                            (y - e_b[row]).abs() <= 1e-10,
                            "model b drifted: {y} vs {}",
                            e_b[row]
                        );
                    } else {
                        // model "a" is hot-reloaded mid-traffic: every
                        // answer must belong to exactly v1 or v2
                        let (d1, d2) = ((y - e_a1[row]).abs(), (y - e_a2[row]).abs());
                        assert!(
                            d1 <= 1e-10 || d2 <= 1e-10,
                            "model a answered neither version: {y} (v1 {}, v2 {})",
                            e_a1[row],
                            e_a2[row]
                        );
                    }
                }
            }));
        }

        // hot-swap model "a" while the client fleet is mid-flight
        std::thread::sleep(Duration::from_millis(40));
        let mut admin = Client::connect(addr).unwrap();
        assert_eq!(admin.admin_list().unwrap(), vec!["a".to_string(), "b".to_string()]);
        let version = admin.admin_reload("a", v2_path.to_str()).unwrap();
        assert_eq!(version, 2);

        for j in joins {
            j.join().unwrap();
        }
        std::fs::remove_file(&v2_path).ok();

        // zero failed requests under the swap
        let stats = handle.stats();
        assert_eq!(stats.requests, (CLIENTS * PER_CLIENT) as u64);
        assert_eq!(stats.errors, 0, "hot reload must not fail in-flight requests");
        assert_eq!(stats.shed, 0);
        assert_eq!(stats.reloads, 1);

        // and the swap is actually visible: "a" now answers with v2
        let q = &qs[0];
        let (y, _) = admin.predict_on("a", 999, q).unwrap();
        assert_eq!(
            y.to_bits(),
            expect_a2[0].to_bits(),
            "post-reload prediction should be exactly v2's"
        );
        // per-model counters saw the routed traffic
        let a_stats = handle.model_stats("a").unwrap();
        let b_stats = handle.model_stats("b").unwrap();
        assert_eq!(a_stats.requests + b_stats.requests, stats.requests + 1);
        assert_eq!(a_stats.reloads, 1);
        assert_eq!(b_stats.reloads, 0);
        handle.shutdown();
    });
}

/// A full per-model queue sheds load with a structured `overloaded`
/// reply — and only for the overloaded model; its neighbour keeps
/// serving from the same process.
#[test]
fn queue_cap_sheds_one_model_without_touching_the_other() {
    with_timeout(120, || {
        const D: usize = 4;
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(1_500))
            .cache_capacity(0)
            .max_queue(1)
            .build()
            .unwrap();
        let specs = vec![
            ModelSpec { name: "a".to_string(), artifact: artifact(5, 10, D, 1.0), source: None },
            ModelSpec { name: "b".to_string(), artifact: artifact(6, 10, D, 1.0), source: None },
        ];
        let handle = serve::start_registry(specs, &cfg).unwrap();
        let addr = handle.addr();

        // request 1 sits in model a's queue through the worker's linger
        // window; request 2 arrives while a's depth cap (1) is reached
        let blocker = std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.predict_on("a", 1, &[0.1, 0.2, 0.3, 0.4]).unwrap()
        });
        // sync on observed server state (the request counter bumps just
        // before the enqueue), then a short grace period — the long
        // linger window keeps request 1 queued far beyond this point
        let t0 = std::time::Instant::now();
        while handle.model_stats("a").map(|s| s.requests).unwrap_or(0) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "blocker request never arrived");
            std::thread::sleep(Duration::from_millis(2));
        }
        std::thread::sleep(Duration::from_millis(100));
        let mut client = Client::connect(addr).unwrap();
        let err = client.predict_on("a", 2, &[0.5, 0.6, 0.7, 0.8]).unwrap_err().to_string();
        assert!(err.contains("[overloaded]"), "expected structured shed, got: {err}");

        // model b has its own queue and workers: unaffected
        let (yb, _) = client.predict_on("b", 3, &[0.0, 0.0, 0.0, 0.0]).unwrap();
        assert!(yb.is_finite());

        // the queued request on a still completed fine
        let (ya, _) = blocker.join().unwrap();
        assert!(ya.is_finite());

        assert_eq!(handle.model_stats("a").unwrap().shed, 1);
        assert_eq!(handle.model_stats("b").unwrap().shed, 0);
        let total = handle.stats();
        assert_eq!(total.shed, 1);
        assert_eq!(total.errors, 0, "shed load is backpressure, not an error");
        handle.shutdown();
    });
}
